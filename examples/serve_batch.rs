//! Sharded batched-inference serving demo: concurrent clients score
//! nanoBabyLM sentences and request greedy continuations against a
//! (optionally pretrained) opt-mini model; a router fans requests out
//! to `--workers` backend-owning shards (round-robin or least-pending
//! dispatch), each shard dynamically batching its scoring requests,
//! and the fleet reports merged latency / throughput / occupancy.
//!
//!     cargo run --release --example serve_batch [-- --requests 96 \
//!         --clients 6 --workers 4 --dispatch least-pending \
//!         --ckpt runs/train_tiny/dyad_it]

use anyhow::{ensure, Result};
use dyad_repro::data::{sample_sentences, Grammar, Tokenizer};
use dyad_repro::runtime::BackendKind;
use dyad_repro::serve::{DispatchPolicy, Request, Router, ServeConfig, ServeStats};
use dyad_repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 96)?;
    let n_clients = args.usize_or("clients", 6)?;
    let cfg = ServeConfig {
        backend: args.str_or("backend", "native").parse::<BackendKind>()?,
        artifacts_dir: args.str_or("artifacts", "artifacts").into(),
        arch: args.str_or("arch", "opt-mini"),
        variant: args.str_or("variant", "dyad_it"),
        checkpoint_dir: args.str_opt("ckpt").map(Into::into),
        max_batch: args.usize_or("max-batch", 8)?,
        window_ms: args.u64_or("window-ms", 4)?,
        seed: 7,
        n_workers: args.usize_or("workers", 2)?,
        dispatch: args.str_or("dispatch", "round-robin").parse::<DispatchPolicy>()?,
    };
    println!(
        "serving {}/{} on {} worker(s), {} dispatch (max_batch={}, window={}ms), \
         {} requests from {} clients",
        cfg.arch,
        cfg.variant,
        cfg.n_workers.max(1),
        cfg.dispatch.name(),
        cfg.max_batch,
        cfg.window_ms,
        n_requests,
        n_clients
    );
    let router = Router::start(cfg);

    let grammar = Grammar::new();
    let tokenizer = Tokenizer::from_words(&grammar.vocabulary());
    let sentences = sample_sentences(n_requests, 11);

    // xtask:allow(thread_spawn): example client threads simulating
    // concurrent callers — not kernel parallelism.
    std::thread::scope(|scope| {
        for chunk in sentences.chunks(n_requests.div_ceil(n_clients).max(1)) {
            let tx = router.sender();
            scope.spawn(move || {
                for toks in chunk {
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    tx.send(Request::Score { tokens: toks.clone(), resp: rtx.into() })
                        .expect("router alive");
                    rrx.recv().expect("response").expect("score ok");
                }
            });
        }
    });

    // a couple of generation requests through the same fleet
    let prompt = tokenizer.encode(&["the".into(), "dog".into()]);
    let gen = router.generate(prompt, 8)?;
    println!(
        "greedy continuation of \"the dog\": {:?}",
        tokenizer.decode(&gen)
    );

    let fleet = router.stats()?;
    println!("\n{}", fleet.render());
    let per_worker = router.worker_stats();
    println!("{}", ServeStats::render_workers(&per_worker));
    // fleet stats conserve the per-worker counts — the same contract
    // tests/serve_test.rs pins
    let shard_sum: usize = per_worker.iter().flatten().map(|s| s.requests()).sum();
    ensure!(
        shard_sum == fleet.requests(),
        "stats not conserved: shards {} vs fleet {}",
        shard_sum,
        fleet.requests()
    );
    router.shutdown()?;
    Ok(())
}

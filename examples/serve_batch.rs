//! Batched-inference serving demo: concurrent clients score nanoBabyLM
//! sentences and request greedy continuations against a (optionally
//! pretrained) opt-mini model; the server dynamically batches scoring
//! requests and reports latency / throughput / occupancy.
//!
//!     cargo run --release --example serve_batch [-- --requests 96 \
//!         --clients 6 --ckpt runs/train_tiny/dyad_it]

use anyhow::Result;
use dyad_repro::data::{Grammar, Tokenizer};
use dyad_repro::runtime::BackendKind;
use dyad_repro::serve::{Request, ServeConfig, ServerHandle};
use dyad_repro::util::cli::Args;
use dyad_repro::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 96)?;
    let n_clients = args.usize_or("clients", 6)?;
    let cfg = ServeConfig {
        backend: args.str_or("backend", "native").parse::<BackendKind>()?,
        artifacts_dir: args.str_or("artifacts", "artifacts").into(),
        arch: args.str_or("arch", "opt-mini"),
        variant: args.str_or("variant", "dyad_it"),
        checkpoint_dir: args.str_opt("ckpt").map(Into::into),
        max_batch: args.usize_or("max-batch", 8)?,
        window_ms: args.u64_or("window-ms", 4)?,
        seed: 7,
    };
    println!(
        "serving {}/{} (max_batch={}, window={}ms), {} requests from {} clients",
        cfg.arch, cfg.variant, cfg.max_batch, cfg.window_ms, n_requests, n_clients
    );
    let server = ServerHandle::start(cfg);

    let grammar = Grammar::new();
    let tokenizer = Tokenizer::from_words(&grammar.vocabulary());
    let mut rng = Rng::new(11);
    let sentences: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| tokenizer.encode_sentence(&grammar.sentence(&mut rng)))
        .collect();

    std::thread::scope(|scope| {
        for chunk in sentences.chunks(n_requests.div_ceil(n_clients).max(1)) {
            let tx = server.sender();
            scope.spawn(move || {
                for toks in chunk {
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    tx.send(Request::Score { tokens: toks.clone(), resp: rtx })
                        .expect("server alive");
                    rrx.recv().expect("response").expect("score ok");
                }
            });
        }
    });

    // a couple of generation requests through the same server
    let prompt = tokenizer.encode(&["the".into(), "dog".into()]);
    let gen = server.generate(prompt, 8)?;
    println!(
        "greedy continuation of \"the dog\": {:?}",
        tokenizer.decode(&gen)
    );

    let stats = server.stats()?;
    println!("\n{}", stats.render());
    server.shutdown()?;
    Ok(())
}

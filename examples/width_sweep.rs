//! Figure 6 interactively: DYAD vs DENSE ff speedup as model width
//! grows (6-layer-capped OPT-like architecture in the paper; here the
//! ff geometry sweeps d -> 4d directly). Runs on the native backend by
//! default; set REPRO_BACKEND=xla after `make artifacts` for PJRT.
//!
//!     cargo run --release --example width_sweep

use anyhow::Result;
use dyad_repro::bench_support::{backend_from_env, ff_timing, BenchOpts};

fn main() -> Result<()> {
    let backend = backend_from_env()?;
    let opts = BenchOpts { warmup: 2, reps: 5, seed: 3 };
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "width", "dense(ms)", "dyad4(ms)", "dyad8(ms)", "x4", "x8"
    );
    for width in [256usize, 512, 1024, 2048] {
        let geo = format!("width{width}");
        let dense = ff_timing(backend.as_ref(), &geo, "dense", opts)?;
        let d4 = ff_timing(backend.as_ref(), &geo, "dyad_it", opts)?;
        let d8 = ff_timing(backend.as_ref(), &geo, "dyad_it_8", opts)?;
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>12.3} {:>10.2} {:>10.2}",
            width,
            dense.total_ms,
            d4.total_ms,
            d8.total_ms,
            dense.total_ms / d4.total_ms,
            dense.total_ms / d8.total_ms
        );
    }
    println!("\npaper shape: speedup grows with width (Figure 6).");
    Ok(())
}

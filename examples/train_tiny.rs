//! End-to-end driver (the DESIGN.md §E2E run): pretrain the opt-mini
//! transformer on nanoBabyLM under both DENSE and DYAD-IT ff layers,
//! log the loss curves, then run the zero-shot minimal-pair suite —
//! the smallest honest replica of the paper's core experiment.
//!
//!     cargo run --release --example train_tiny [-- --steps 240]
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use dyad_repro::config::TrainConfig;
use dyad_repro::coordinator::{checkpoint::CheckpointManager, MetricsLogger, Trainer};
use dyad_repro::data::{Grammar, Tokenizer};
use dyad_repro::eval;
use dyad_repro::runtime::{open_backend, Backend, BackendKind};
use dyad_repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.usize_or("steps", 240)?;
    // LM pretraining runs artifact-free on the default native backend
    // (layer-module autodiff); pass --backend xla for the PJRT path.
    let backend = open_backend(
        args.str_or("backend", "native").parse::<BackendKind>()?,
        std::path::Path::new(&args.str_or("artifacts", "artifacts")),
    )?;
    let grammar = Grammar::new();
    let tokenizer = Tokenizer::from_words(&grammar.vocabulary());

    let mut summaries = Vec::new();
    for variant in ["dense", "dyad_it"] {
        println!("\n================ {variant} ================");
        let cfg = TrainConfig {
            arch: "opt-mini".into(),
            variant: variant.into(),
            steps,
            lr: 1e-3,
            warmup_steps: steps / 10,
            corpus_tokens: 200_000,
            out_dir: format!("runs/train_tiny/{variant}").into(),
            ..TrainConfig::default()
        };
        let mut log = MetricsLogger::to_dir(&cfg.out_dir)?;
        log.quiet = false;
        let report = Trainer::new(cfg.clone()).run(backend.as_ref(), &mut log)?;

        // zero-shot minimal pairs on the fresh checkpoint
        let train_spec = backend.manifest().artifact(&cfg.train_artifact(8))?.clone();
        let state =
            CheckpointManager::new(&cfg.out_dir).load_state(backend.as_ref(), &train_spec)?;
        let score_art = backend.load(&cfg.artifact("score"))?;
        let blimp = eval::blimp::evaluate(
            backend.as_ref(),
            score_art.as_ref(),
            &state,
            &tokenizer,
            40,
            9,
        )?;
        println!(
            "{variant}: loss {:.3} -> {:.3} (valid {:.3}), BLIMP mean {:.3}, \
             {} params, {:.0} ms/call",
            report.first_loss,
            report.final_loss,
            report.valid_loss,
            blimp.mean,
            report.params,
            report.ms_per_call.mean
        );
        summaries.push((variant, report, blimp));
    }

    println!("\n================ comparison ================");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "variant", "first_loss", "final_loss", "valid_loss", "BLIMP", "params",
        "ms/call"
    );
    for (v, r, b) in &summaries {
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>12} {:>12.0}",
            v, r.first_loss, r.final_loss, r.valid_loss, b.mean, r.params,
            r.ms_per_call.mean
        );
    }
    if summaries.len() == 2 {
        let (_, rd, bd) = &summaries[0];
        let (_, ry, by) = &summaries[1];
        println!(
            "\npaper-shape check: DYAD quality >= 90% of DENSE? \
             valid-loss ratio {:.3} (lower=better), BLIMP ratio {:.3}, \
             param ratio {:.3}, time ratio {:.3}",
            ry.valid_loss / rd.valid_loss,
            by.mean / bd.mean,
            ry.params as f64 / rd.params as f64,
            ry.ms_per_call.mean / rd.ms_per_call.mean
        );
    }
    Ok(())
}

//! §3.4.5 vision probe: MNIST-style digit classification with DENSE vs
//! DYAD-IT hidden layers (procedural digits; DESIGN.md §6).
//!
//!     cargo run --release --example mnist [-- --steps 200]

use anyhow::Result;
use dyad_repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    dyad_repro::eval::mnist_probe::run(
        &args.str_or("artifacts", "artifacts"),
        args.usize_or("steps", 200)?,
        args.str_opt("variant"),
        args.u64_or("seed", 5)?,
    )
}

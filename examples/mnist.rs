//! §3.4.5 vision probe: MNIST-style digit classification with DENSE vs
//! DYAD-IT hidden layers (procedural digits; DESIGN.md §6). Trains on
//! the native backend by default — no artifacts needed.
//!
//!     cargo run --release --example mnist [-- --steps 200 --backend native]

use anyhow::Result;
use dyad_repro::runtime::{open_backend, BackendKind};
use dyad_repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let backend = open_backend(
        args.str_or("backend", "native").parse::<BackendKind>()?,
        std::path::Path::new(&args.str_or("artifacts", "artifacts")),
    )?;
    dyad_repro::eval::mnist_probe::run(
        backend.as_ref(),
        args.usize_or("steps", 200)?,
        args.str_opt("variant"),
        args.u64_or("seed", 5)?,
    )
}

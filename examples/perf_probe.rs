//! L3 perf probe: quantifies the native execution layer's two hot-path
//! design choices:
//!
//! 1. **Thread scaling of the fused DYAD kernel** — the same fused
//!    forward at 1/2/4/max worker threads (row-panel parallelism).
//! 2. **Fused schedule vs oracle** — the blocked in-place kernel
//!    against `dyad::math::dyad_matmul` (per-block gather + temporary
//!    buffers) at the OPT-125m ff geometry.
//!
//!     cargo run --release --example perf_probe

use anyhow::Result;
use dyad_repro::dyad::kernel::{dyad_fused_with_threads, num_threads};
use dyad_repro::dyad::{dyad_matmul, DyadDims, Variant};
use dyad_repro::util::rng::Rng;
use dyad_repro::util::stats::Summary;
use dyad_repro::util::timer::Timer;

fn time_ms<F: FnMut()>(reps: usize, mut f: F) -> Summary {
    f(); // warmup
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_ms());
    }
    Summary::of(&samples)
}

fn main() -> Result<()> {
    // OPT-125m fc1 geometry: 768 -> 3072, 512-token minibatch, n_dyad 4
    let dims = DyadDims::new(4, 768, 3072)?;
    let nb = 512;
    let mut rng = Rng::new(3);
    let wl: Vec<f32> = (0..dims.component_params()).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let wu: Vec<f32> = (0..dims.component_params()).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let x: Vec<f32> = (0..dims.f_in() * nb).map(|_| rng.uniform(-1.0, 1.0)).collect();

    // --- 1. thread scaling ---------------------------------------------
    let max = num_threads();
    println!("fused DYAD forward, 768->3072 x {nb} cols (max {max} threads):");
    let mut base = f64::NAN;
    for threads in [1usize, 2, 4, max] {
        if threads > max {
            continue;
        }
        let s = time_ms(5, || {
            std::hint::black_box(dyad_fused_with_threads(
                &wl, &wu, &x, dims, Variant::It, nb, None, threads,
            ));
        });
        if threads == 1 {
            base = s.p50;
        }
        println!(
            "  {threads:>2} threads: {:8.2} ms  ({:.2}x vs 1 thread)",
            s.p50,
            base / s.p50
        );
    }

    // --- 2. fused vs oracle --------------------------------------------
    let oracle = time_ms(5, || {
        std::hint::black_box(dyad_matmul(&wl, &wu, &x, dims, Variant::It, nb, None));
    });
    let fused = time_ms(5, || {
        std::hint::black_box(dyad_fused_with_threads(
            &wl, &wu, &x, dims, Variant::It, nb, None, max,
        ));
    });
    println!(
        "\noracle (single-thread, gather + temps): {:8.2} ms\n\
         fused  (blocked, in-place, {max} threads): {:8.2} ms\n\
         speedup: {:.2}x",
        oracle.p50,
        fused.p50,
        oracle.p50 / fused.p50
    );
    Ok(())
}

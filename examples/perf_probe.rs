//! L3 perf probe (EXPERIMENTS.md §Perf): quantifies the coordinator's
//! two hot-path design choices:
//!
//! 1. **K-microbatch amortization** — one train_k8 call vs eight
//!    train_k1 calls (the host round-trip of training state happens
//!    once vs eight times).
//! 2. **Literal staging overhead** — `Loaded::run` (host tensors
//!    converted every call) vs `run_literals` (pre-staged), on the
//!    score artifact.
//!
//!     cargo run --release --example perf_probe

use anyhow::Result;
use dyad_repro::bench_support::{bench_artifact, synth_input, BenchOpts};
use dyad_repro::runtime::{tensor_to_literal, Engine};
use dyad_repro::util::rng::Rng;
use dyad_repro::util::stats::Summary;
use dyad_repro::util::timer::Timer;

fn main() -> Result<()> {
    let engine = Engine::from_dir("artifacts")?;
    let opts = BenchOpts { warmup: 1, reps: 5, seed: 42 };

    // --- 1. K amortization ---------------------------------------------
    let k1 = bench_artifact(&engine, "opt-mini/dense/train_k1", opts)?;
    let k8 = bench_artifact(&engine, "opt-mini/dense/train_k8", opts)?;
    println!("train_k1: {:8.1} ms/call  -> 8 steps = {:8.1} ms", k1.mean, 8.0 * k1.mean);
    println!("train_k8: {:8.1} ms/call  -> 8 steps = {:8.1} ms", k8.mean, k8.mean);
    println!(
        "K-amortization saving: {:.1}% ({:.1} ms of state round-trip per 8 steps)",
        100.0 * (1.0 - k8.mean / (8.0 * k1.mean)),
        8.0 * k1.mean - k8.mean
    );

    // --- 2. literal staging --------------------------------------------
    let art = engine.load("opt-mini/dense/score")?;
    let mut rng = Rng::new(1);
    let tensors: Vec<_> = art
        .spec
        .inputs
        .iter()
        .map(|io| synth_input(io, &mut rng))
        .collect();
    let lits: Vec<xla::Literal> = tensors
        .iter()
        .zip(&art.spec.inputs)
        .map(|(t, s)| tensor_to_literal(t, s))
        .collect::<Result<_>>()?;
    let _ = art.run(&tensors)?; // warmup
    let mut conv = Vec::new();
    let mut pre = Vec::new();
    for _ in 0..8 {
        let t = Timer::start();
        let _ = art.run(&tensors)?;
        conv.push(t.elapsed_ms());
        let t = Timer::start();
        let _ = art.run_literals(&lits)?;
        pre.push(t.elapsed_ms());
    }
    let (c, p) = (Summary::of(&conv), Summary::of(&pre));
    println!(
        "\nscore via run (convert each call):  {:8.1} ms\n\
         score via run_literals (pre-staged): {:8.1} ms\n\
         staging overhead avoided: {:.1} ms/call ({:.1}%)",
        c.mean,
        p.mean,
        c.mean - p.mean,
        100.0 * (c.mean - p.mean) / c.mean
    );
    Ok(())
}

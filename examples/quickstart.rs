//! Quickstart: open a backend and run the DYAD vs DENSE ff module.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the whole public API surface in ~60 lines: open a
//! backend (native by default — no artifacts needed; set
//! `REPRO_BACKEND=xla` after `make artifacts` for PJRT), inspect the
//! manifest, execute an artifact with typed host tensors, and compare
//! DYAD's wall clock against the dense baseline at the paper's
//! OPT-125m ff geometry.

use anyhow::Result;
use dyad_repro::bench_support::{backend_from_env, bench_artifact, BenchOpts};
use dyad_repro::runtime::{Backend, Executable};
use dyad_repro::tensor::Tensor;
use dyad_repro::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Open the execution backend.
    let backend = backend_from_env()?;
    println!("platform: {}", backend.platform());
    println!("artifacts in manifest: {}", backend.manifest().artifacts.len());

    // 2. Execute one artifact by hand: the MNIST hidden path (the two
    //    DYAD swap-site linears).
    let art = backend.load("mnist/dyad_it/hidden_fwd")?;
    let mut rng = Rng::new(0);
    let inputs: Vec<Tensor> = art
        .spec()
        .inputs
        .iter()
        .map(|io| {
            let n: usize = io.shape.iter().product();
            Tensor::from_f32(
                &io.shape,
                (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            )
            .unwrap()
        })
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let out = art.run(&refs)?;
    println!(
        "mnist/dyad_it/hidden_fwd: h shape {:?}, first values {:?}",
        out[0].shape,
        &out[0].as_f32()?[..4]
    );

    // 3. The headline comparison (paper Table 1): ff module at the
    //    true OPT-125m width, DENSE vs DYAD-IT vs DYAD-IT-8.
    let opts = BenchOpts { warmup: 2, reps: 5, seed: 1 };
    let dense = bench_artifact(backend.as_ref(), "ff/opt125m-ff/dense/fwd", opts)?;
    let dyad = bench_artifact(backend.as_ref(), "ff/opt125m-ff/dyad_it/fwd", opts)?;
    let dyad8 = bench_artifact(backend.as_ref(), "ff/opt125m-ff/dyad_it_8/fwd", opts)?;
    println!("\nff forward @ OPT-125m geometry (768 -> 3072), 512 tokens:");
    println!("  dense      {:8.2} ms   1.00x", dense.mean);
    println!(
        "  dyad_it    {:8.2} ms   {:.2}x",
        dyad.mean,
        dense.mean / dyad.mean
    );
    println!(
        "  dyad_it_8  {:8.2} ms   {:.2}x",
        dyad8.mean,
        dense.mean / dyad8.mean
    );
    Ok(())
}

//! Quickstart: load AOT artifacts and run the DYAD vs DENSE ff module.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the whole public API surface in ~60 lines: open the
//! engine, inspect the manifest, execute an artifact with typed host
//! tensors, and compare DYAD's wall clock against the dense baseline
//! at the paper's OPT-125m ff geometry.

use anyhow::Result;
use dyad_repro::bench_support::{bench_artifact, BenchOpts};
use dyad_repro::runtime::Engine;
use dyad_repro::tensor::Tensor;
use dyad_repro::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Open the artifact directory (built once by `make artifacts`).
    let engine = Engine::from_dir("artifacts")?;
    println!("platform: {}", engine.platform());
    println!("artifacts in manifest: {}", engine.manifest.artifacts.len());

    // 2. Execute one artifact by hand: the small Pallas DYAD-IT kernel.
    let art = engine.load("pallas/dyad_it_small")?;
    let mut rng = Rng::new(0);
    let inputs: Vec<Tensor> = art
        .spec
        .inputs
        .iter()
        .map(|io| {
            let n: usize = io.shape.iter().product();
            Tensor::from_f32(
                &io.shape,
                (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            )
            .unwrap()
        })
        .collect();
    let out = art.run(&inputs)?;
    println!(
        "pallas dyad_it: y shape {:?}, first values {:?}",
        out[0].shape,
        &out[0].as_f32()?[..4]
    );

    // 3. The headline comparison (paper Table 1): ff module at the
    //    true OPT-125m width, DENSE vs DYAD-IT vs DYAD-IT-8.
    let opts = BenchOpts { warmup: 2, reps: 5, seed: 1 };
    let dense = bench_artifact(&engine, "ff/opt125m-ff/dense/fwd", opts)?;
    let dyad = bench_artifact(&engine, "ff/opt125m-ff/dyad_it/fwd", opts)?;
    let dyad8 = bench_artifact(&engine, "ff/opt125m-ff/dyad_it_8/fwd", opts)?;
    println!("\nff forward @ OPT-125m geometry (768 -> 3072), 512 tokens:");
    println!("  dense      {:8.2} ms   1.00x", dense.mean);
    println!(
        "  dyad_it    {:8.2} ms   {:.2}x",
        dyad.mean,
        dense.mean / dyad.mean
    );
    println!(
        "  dyad_it_8  {:8.2} ms   {:.2}x",
        dyad8.mean,
        dense.mean / dyad8.mean
    );
    Ok(())
}

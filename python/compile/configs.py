"""Architecture / artifact configuration presets.

The paper's models (OPT-125m/350m, Pythia-160m) are GPU-scale; this
reproduction runs on one CPU core, so (DESIGN.md §6):

* **ff-micro geometries** use the *paper's true widths* (768→3072 etc.) —
  the ff-module timing tables (T1/T5/T10, F6/F7, CAT ablation) are
  measured at the real layer sizes the paper reports;
* **whole-model presets** (`*-mini`, `*-mid`) keep the architecture shape
  (pre-LN decoder, tied embeddings, GELU ff, learned positions; Pythia =
  parallel residual) at CPU-trainable scale for the quality tables
  (T2/T3/T6-8/T12) and whole-model timing (T4/T9).

Every DENSE-vs-DYAD comparison uses the same preset, the same data and
the same training loop — the paper's comparison structure.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    name: str
    vocab: int
    d_model: int
    d_ff: int
    n_layers: int
    n_heads: int
    seq: int
    parallel_residual: bool = False  # Pythia-style

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class VariantConfig:
    """ff-layer variant: how the two ff linear layers are realised.

    ``layer_schedule`` (paper §4 future work: "a heterogeneous mix of
    DYAD variants to approximate different ff layers"): when set, layer
    ``l`` uses ``layer_schedule[l % len(layer_schedule)]`` as its
    dyad_variant instead of the homogeneous ``dyad_variant``.
    """

    name: str  # dense | dyad_it | dyad_ot | dyad_dt | dyad_it_cat | dyad_it_8
    kind: str  # "dense" | "dyad"
    dyad_variant: str = "it"  # it|ot|dt|it_cat
    n_dyad: int = 4
    layer_schedule: tuple = ()

    def variant_for_layer(self, layer: int) -> str:
        if self.layer_schedule:
            return self.layer_schedule[layer % len(self.layer_schedule)]
        return self.dyad_variant


VARIANTS = {
    "dense": VariantConfig("dense", "dense"),
    "dyad_it": VariantConfig("dyad_it", "dyad", "it", 4),
    "dyad_ot": VariantConfig("dyad_ot", "dyad", "ot", 4),
    "dyad_dt": VariantConfig("dyad_dt", "dyad", "dt", 4),
    "dyad_it_cat": VariantConfig("dyad_it_cat", "dyad", "it_cat", 4),
    "dyad_it_8": VariantConfig("dyad_it_8", "dyad", "it", 8),
    # §4 future work: heterogeneous mix — cycle IT/OT/DT across layers.
    "dyad_hetero": VariantConfig(
        "dyad_hetero", "dyad", "it", 4, layer_schedule=("it", "ot", "dt")
    ),
}

ARCHS = {
    # CPU-trainable presets for quality + whole-model timing.
    "opt-mini": ArchConfig("opt-mini", vocab=512, d_model=256, d_ff=1024,
                           n_layers=4, n_heads=8, seq=128),
    "pythia-mini": ArchConfig("pythia-mini", vocab=512, d_model=256, d_ff=1024,
                              n_layers=4, n_heads=8, seq=128,
                              parallel_residual=True),
    "opt-mid": ArchConfig("opt-mid", vocab=512, d_model=384, d_ff=1536,
                          n_layers=6, n_heads=8, seq=128),
}

# ff-micro geometries: (d_model, d_ff, tokens-per-minibatch). Widths are
# the paper's true model widths; token counts scaled for 1-core wallclock.
FF_GEOMETRIES = {
    "opt125m-ff": (768, 3072, 512),
    "opt350m-ff": (1024, 4096, 256),
    "pythia160m-ff": (768, 3072, 512),
}

# Figure 6 width sweep: 6-layer OPT-like at growing width; we sweep the
# ff geometry (d, 4d) directly. Paper sweeps to 4096; 2048 is the largest
# width with tolerable 1-core bench time (documented in EXPERIMENTS.md).
WIDTH_SWEEP = [256, 512, 1024, 2048]
WIDTH_SWEEP_TOKENS = 128

# Training batch geometry for whole-model artifacts.
TRAIN_BATCH = 8          # sequences per microbatch
TRAIN_MICROBATCHES = 8   # K: optimizer steps per PJRT call (train_step_k8)
EVAL_BATCH = 8           # sequences per score/features call

# MNIST probe (§3.4.5): 784 -> 256 -> 256 -> 10 MLP; hidden layers are the
# dense/dyad swap site (final 256->10 stays dense: 10 % n_dyad != 0,
# paper appendix §5.1 would zero-pad; keeping it dense isolates the swap).
MNIST_HIDDEN = 256
MNIST_BATCH = 64
MNIST_CLASSES = 10
MNIST_IN = 784

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0

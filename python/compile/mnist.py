"""L2: MNIST-probe MLP (paper §3.4.5) — dense vs DYAD hidden layers.

784 -> 256 -> 256 -> 10 with ReLU; the two hidden linears are the
DENSE/DYAD swap site (784 and 256 are divisible by n_dyad=4; the 10-way
head stays dense — see configs.py). Adam-in-graph train step with a K
microbatch scan, mirroring the LM train step.
"""

import math

import jax
import jax.numpy as jnp

from . import configs
from .configs import VariantConfig
from .kernels.dyad import dyad_linear_row, dyad_param_shapes
from .kernels.dense import dense_linear_row


def _linear_specs(prefix, f_in, f_out, variant: VariantConfig):
    if variant.kind == "dense":
        k = 1.0 / math.sqrt(f_in)
        return [
            (f"{prefix}.w", (f_out, f_in), {"kind": "uniform", "bound": k}),
            (f"{prefix}.b", (f_out,), {"kind": "uniform", "bound": k}),
        ]
    s = dyad_param_shapes(variant.n_dyad, f_in, f_out)
    k = s["init_bound"]
    return [
        (f"{prefix}.wl", s["wl"], {"kind": "uniform", "bound": k}),
        (f"{prefix}.wu", s["wu"], {"kind": "uniform", "bound": k}),
        (f"{prefix}.b", (f_out,), {"kind": "uniform", "bound": k}),
    ]


def mnist_param_specs(variant: VariantConfig):
    h = configs.MNIST_HIDDEN
    kh = 1.0 / math.sqrt(h)
    return (
        _linear_specs("fc1", configs.MNIST_IN, h, variant)
        + _linear_specs("fc2", h, h, variant)
        + [
            ("head.w", (configs.MNIST_CLASSES, h), {"kind": "uniform", "bound": kh}),
            ("head.b", (configs.MNIST_CLASSES,), {"kind": "uniform", "bound": kh}),
        ]
    )


def _as_dict(flat, specs):
    return {name: arr for (name, _, _), arr in zip(specs, flat)}


def _linear(p, prefix, x, variant: VariantConfig):
    if variant.kind == "dense" or prefix == "head":
        return dense_linear_row(x, p[f"{prefix}.w"], p[f"{prefix}.b"])
    return dyad_linear_row(
        x, p[f"{prefix}.wl"], p[f"{prefix}.wu"], p[f"{prefix}.b"],
        variant=variant.dyad_variant,
    )


def mlp_logits(flat, x, variant: VariantConfig):
    specs = mnist_param_specs(variant)
    p = _as_dict(flat, specs)
    h = jax.nn.relu(_linear(p, "fc1", x, variant))
    h = jax.nn.relu(_linear(p, "fc2", h, variant))
    return dense_linear_row(h, p["head.w"], p["head.b"])


def mnist_loss(flat, x, labels, variant):
    logits = mlp_logits(flat, x, variant)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def make_mnist_train_step(variant, k_micro, batch):
    """fn(params.., m.., v.., step, lr, images (K,B,784), labels (K,B))."""
    specs = mnist_param_specs(variant)
    n = len(specs)

    def train_step(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step, lr = args[3 * n], args[3 * n + 1]
        images, labels = args[3 * n + 2], args[3 * n + 3]

        def one(carry, xy):
            params, m, v, step = carry
            x, y = xy
            loss, grads = jax.value_and_grad(mnist_loss)(params, x, y, variant)
            step = step + 1.0
            b1, b2, eps = configs.ADAM_B1, configs.ADAM_B2, configs.ADAM_EPS
            m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
            v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads)]
            ms, vs = 1.0 / (1.0 - b1**step), 1.0 / (1.0 - b2**step)
            params = [
                p - lr * (mi * ms) / (jnp.sqrt(vi * vs) + eps)
                for p, mi, vi in zip(params, m, v)
            ]
            return (params, m, v, step), loss

        (params, m, v, step), losses = jax.lax.scan(
            one, (params, m, v, step), (images, labels)
        )
        return tuple(params) + tuple(m) + tuple(v) + (step, losses)

    return train_step


def make_mnist_accuracy(variant, batch):
    """fn(params.., images (B,784), labels (B,)) -> (n_correct,)."""
    n = len(mnist_param_specs(variant))

    def accuracy(*args):
        params, x, y = list(args[:n]), args[n], args[n + 1]
        pred = jnp.argmax(mlp_logits(params, x, variant), axis=-1)
        return (jnp.sum((pred == y).astype(jnp.int32)),)

    return accuracy


def make_mnist_hidden_fwd(variant, batch):
    """fn(params.., x (B,784)) -> hidden activations: the MLP's 'ff-only'
    path (both swap-site linears + ReLUs, no head) for §3.4.5 timing."""
    specs = mnist_param_specs(variant)
    n = len(specs)

    def hidden_fwd(*args):
        params, x = list(args[:n]), args[n]
        p = _as_dict(params, specs)
        h = jax.nn.relu(_linear(p, "fc1", x, variant))
        h = jax.nn.relu(_linear(p, "fc2", h, variant))
        return (h,)

    return hidden_fwd

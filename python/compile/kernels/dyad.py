"""Efficient DYAD implementations: jnp-einsum (L2 path) and Pallas (L1).

Two interchangeable execution paths, both validated against ``ref.py``:

* ``dyad_matmul`` / ``dyad_linear_row`` — jnp batched-matmul/einsum forms,
  the exact 3-D-tensor schedule of paper Eqs 3-10. These lower to single
  ``dot_general`` ops with a batch dimension and are what the AOT'd model
  artifacts use (XLA fuses them; interpret-mode Pallas would lower to
  while-loops and distort every timing table — DESIGN.md §7).
* ``dyad_matmul_pallas`` — the same schedule expressed as a Pallas kernel
  with the block structure in the BlockSpecs: grid over ``n_dyad``, the
  BLOCKTRANS permutation an ``index_map`` over a free reshape-view (the
  TPU analogue of the paper's stride-swap, Eq 9), and the -CAT fusion a
  single ``2*n_dyad`` grid.

Variants: ``it`` | ``ot`` | ``dt`` | ``it_cat`` (paper §2.2, §2.4, §3.4.3).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VARIANTS = ("it", "ot", "dt", "it_cat")


def dyad_param_shapes(n_dyad: int, f_in: int, f_out: int):
    """Parameter shapes + init bound for a DYAD layer of (f_out, f_in).

    Both components store (n_dyad, n_out, n_in) blocks; init is
    U(-k, k) with k = 1/sqrt(n_in * n_dyad) = 1/sqrt(f_in), matching the
    paper's reference implementation (§2.3) and nn.Linear.
    """
    if f_in % n_dyad or f_out % n_dyad:
        raise ValueError(
            f"f_in={f_in}, f_out={f_out} must be divisible by n_dyad={n_dyad}"
            " (paper §5.1: pad up otherwise)"
        )
    n_in, n_out = f_in // n_dyad, f_out // n_dyad
    k = 1.0 / math.sqrt(f_in)
    return {
        "wl": (n_dyad, n_out, n_in),
        "wu": (n_dyad, n_out, n_in),
        "init_bound": k,
    }


# ---------------------------------------------------------------------------
# Column-major (paper-convention) jnp implementation
# ---------------------------------------------------------------------------


def _split_views(x, n_dyad, n_in):
    """The two 3-D views of X (paper Eqs 3 and 9). Both are free."""
    nb = x.shape[-1]
    x1 = x.reshape(n_dyad, n_in, nb)
    # Eq 9: X2' = X.reshape(n_in, n_dyad, nb).transpose(0, 1) — a pure
    # stride swap; XLA keeps it as a layout change fused into the bmm.
    x2 = x.reshape(n_in, n_dyad, nb).transpose(1, 0, 2)
    return x1, x2


def dyad_matmul(x, wl, wu, b=None, variant: str = "it"):
    """Y = (W1 + W2) X + b via the efficient 3-D schedule.

    x: (f_in, n_batch); wl, wu: (n_dyad, n_out, n_in); b: (f_out, 1)|None.
    Cost is O(n_dyad * n_out * n_in * n_batch) — an O(n_dyad) reduction
    over the dense layer (paper §2.2.1).
    """
    n_dyad, n_out, n_in = wl.shape
    nb = x.shape[-1]
    x1, x2 = _split_views(x, n_dyad, n_in)

    if variant == "it":
        y = jnp.matmul(wl, x1) + jnp.matmul(wu, x2)  # (nd, n_out, nb)
        y = y.reshape(n_dyad * n_out, nb)
    elif variant == "ot":
        y1 = jnp.matmul(wl, x1)
        z = jnp.matmul(wu, x1)
        # output rows permuted: y2[k*nd + i] = z[i, k] (paper Eq 13)
        y2 = z.transpose(1, 0, 2).reshape(n_dyad * n_out, nb)
        y = y1.reshape(n_dyad * n_out, nb) + y2
    elif variant == "dt":
        y1 = jnp.matmul(wl, x1)
        z = jnp.matmul(wu, x2)  # input transposed ...
        y2 = z.transpose(1, 0, 2).reshape(n_dyad * n_out, nb)  # ... and output
        y = y1.reshape(n_dyad * n_out, nb) + y2
    elif variant == "it_cat":
        # -CAT (§3.4.3): one bmm of 2*n_dyad blocks instead of two bmms.
        w_cat = jnp.concatenate([wl, wu], axis=0)
        x_cat = jnp.concatenate([x1, x2], axis=0)
        out = jnp.matmul(w_cat, x_cat)  # (2*nd, n_out, nb)
        y = (out[:n_dyad] + out[n_dyad:]).reshape(n_dyad * n_out, nb)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    if b is not None:
        y = y + b
    return y


# ---------------------------------------------------------------------------
# Row-major implementation (used by the L2 transformer: x is (tokens, f_in))
# ---------------------------------------------------------------------------


def dyad_linear_row(x, wl, wu, b=None, variant: str = "it"):
    """Row-major DYAD linear: y = x @ W^T + b with x: (..., f_in).

    Implemented by transposing into the column-major core
    (:func:`dyad_matmul`) and back. Measured on XLA-CPU this is the
    fastest lowering by a wide margin (EXPERIMENTS.md §Perf): the
    column-major form's block views are *free* (pure reshapes /
    stride swaps, the paper's Eq 9), whereas einsum-with-batch-dim
    forms force materialised activation transposes in both the forward
    and especially the transposed (gradient) computation.
    """
    n_dyad, n_out, n_in = wl.shape
    lead = tuple(x.shape[:-1])
    t = 1
    for dim in lead:
        t *= int(dim)
    xc = x.reshape((t, n_dyad * n_in)).T  # (f_in, t)
    bc = None if b is None else b.reshape(n_dyad * n_out, 1)
    y = dyad_matmul(xc, wl, wu, bc, variant=variant).T
    return y.reshape(lead + (n_dyad * n_out,))


# ---------------------------------------------------------------------------
# Pallas kernels (column-major). interpret=True: CPU PJRT cannot execute
# Mosaic custom-calls; structure is TPU-shaped (DESIGN.md §7).
# ---------------------------------------------------------------------------


def _it_kernel(wl_ref, wu_ref, x1_ref, x2_ref, o_ref):
    # One grid step = one dyad block i: both components' contribution to
    # output rows [i*n_out, (i+1)*n_out). x2_ref is the strided view
    # block [:, i, :] of X.reshape(n_in, n_dyad, nb) — the permutation
    # lives entirely in the BlockSpec index_map.
    o_ref[0] = wl_ref[0] @ x1_ref[0] + wu_ref[0] @ x2_ref[:, 0, :]


def _bd_kernel(w_ref, x_ref, o_ref):
    # Plain block-diagonal bmm step (used for OT/DT partial products).
    o_ref[0] = w_ref[0] @ x_ref[0]


def _bd_kernel_strided_x(w_ref, x_ref, o_ref):
    o_ref[0] = w_ref[0] @ x_ref[:, 0, :]


def _cat_kernel(w_ref, x_ref, o_ref):
    # -CAT: one grid of 2*n_dyad steps over concatenated weights/inputs.
    o_ref[0] = w_ref[0] @ x_ref[0]


def _pallas_bd(w3, x3, *, strided: bool, interpret: bool = True):
    """pallas_call wrapper: grid (n_dyad,), one (n_out,n_in)x(n_in,nb) tile
    per step. VMEM/grid-step = (n_out*n_in + n_in*nb + n_out*nb) * 4 B."""
    n_dyad, n_out, n_in = w3.shape
    nb = x3.shape[-1]
    if strided:
        x_spec = pl.BlockSpec((n_in, 1, nb), lambda i: (0, i, 0))
        kern = _bd_kernel_strided_x
    else:
        x_spec = pl.BlockSpec((1, n_in, nb), lambda i: (i, 0, 0))
        kern = _bd_kernel
    return pl.pallas_call(
        kern,
        grid=(n_dyad,),
        in_specs=[
            pl.BlockSpec((1, n_out, n_in), lambda i: (i, 0, 0)),
            x_spec,
        ],
        out_specs=pl.BlockSpec((1, n_out, nb), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dyad, n_out, nb), w3.dtype),
        interpret=interpret,
    )(w3, x3)


def dyad_matmul_pallas(x, wl, wu, b=None, variant: str = "it", interpret=True):
    """Pallas version of :func:`dyad_matmul` (same signature/semantics)."""
    n_dyad, n_out, n_in = wl.shape
    nb = x.shape[-1]
    x1 = x.reshape(n_dyad, n_in, nb)
    xs = x.reshape(n_in, n_dyad, nb)  # strided view for BLOCKTRANS

    if variant == "it":
        y3 = pl.pallas_call(
            _it_kernel,
            grid=(n_dyad,),
            in_specs=[
                pl.BlockSpec((1, n_out, n_in), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, n_out, n_in), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, n_in, nb), lambda i: (i, 0, 0)),
                pl.BlockSpec((n_in, 1, nb), lambda i: (0, i, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_out, nb), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((n_dyad, n_out, nb), wl.dtype),
            interpret=interpret,
        )(wl, wu, x1, xs)
        y = y3.reshape(n_dyad * n_out, nb)
    elif variant == "ot":
        y1 = _pallas_bd(wl, x1, strided=False, interpret=interpret)
        z = _pallas_bd(wu, x1, strided=False, interpret=interpret)
        y = y1.reshape(n_dyad * n_out, nb) + z.transpose(1, 0, 2).reshape(
            n_dyad * n_out, nb
        )
    elif variant == "dt":
        y1 = _pallas_bd(wl, x1, strided=False, interpret=interpret)
        z = _pallas_bd(wu, xs, strided=True, interpret=interpret)
        y = y1.reshape(n_dyad * n_out, nb) + z.transpose(1, 0, 2).reshape(
            n_dyad * n_out, nb
        )
    elif variant == "it_cat":
        w_cat = jnp.concatenate([wl, wu], axis=0)
        x2 = xs.transpose(1, 0, 2)
        x_cat = jnp.concatenate([x1, x2], axis=0)
        out = pl.pallas_call(
            _cat_kernel,
            grid=(2 * n_dyad,),
            in_specs=[
                pl.BlockSpec((1, n_out, n_in), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, n_in, nb), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_out, nb), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((2 * n_dyad, n_out, nb), wl.dtype),
            interpret=interpret,
        )(w_cat, x_cat)
        y = (out[:n_dyad] + out[n_dyad:]).reshape(n_dyad * n_out, nb)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    if b is not None:
        y = y + b
    return y


def vmem_estimate_bytes(n_dyad, f_in, f_out, nb, dtype_bytes=4, cat=False):
    """Static VMEM-per-grid-step estimate for DESIGN.md §7 / EXPERIMENTS.md.

    One grid step holds a weight tile, an activation tile and an output
    tile. -CAT doubles neither (same per-step tiles, longer grid).
    """
    n_in, n_out = f_in // n_dyad, f_out // n_dyad
    tiles = n_out * n_in + n_in * nb + n_out * nb
    if not cat:
        # IT fused kernel holds both weight tiles + both activation tiles
        tiles += n_out * n_in + n_in * nb
    return tiles * dtype_bytes

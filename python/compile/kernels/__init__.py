# L1: Pallas kernels + pure-jnp oracles for the DYAD layer family.
#
# Conventions
# -----------
# Paper convention (ref + pallas kernels): activations are column-major,
#   X : (f_in, n_batch),  Y : (f_out, n_batch),  Y = W X + b.
# Model convention (L2 transformer): activations are row-major,
#   X : (n_tokens, f_in), Y = X W^T + b^T  -- provided by `*_linear_row`.
#
# Variants (paper §2.2-2.4): DYAD-IT (input transpose), DYAD-OT (output
# transpose), DYAD-DT (double transpose), and the -CAT fusion (§3.4.3).

from .ref import (
    blockdiag_full,
    blocktrans_full,
    dyad_full,
    dyad_ref,
    dense_ref,
    perm_vector,
)
from .dyad import (
    VARIANTS,
    dyad_matmul,
    dyad_matmul_pallas,
    dyad_linear_row,
    dyad_param_shapes,
)
from .dense import dense_matmul, dense_matmul_pallas, dense_linear_row

__all__ = [
    "blockdiag_full",
    "blocktrans_full",
    "dyad_full",
    "dyad_ref",
    "dense_ref",
    "perm_vector",
    "VARIANTS",
    "dyad_matmul",
    "dyad_matmul_pallas",
    "dyad_linear_row",
    "dyad_param_shapes",
    "dense_matmul",
    "dense_matmul_pallas",
    "dense_linear_row",
]

"""DENSE baseline layer: plain matmul, plus a tiled Pallas version.

The baseline the paper compares against (nn.Linear). The Pallas version
tiles over output rows so the DENSE and DYAD kernels differ only in the
block schedule — the comparison isolates the paper's contribution.
"""

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dense_param_shapes(f_in: int, f_out: int):
    """nn.Linear-style shapes and init bound k = 1/sqrt(f_in)."""
    return {"w": (f_out, f_in), "init_bound": 1.0 / math.sqrt(f_in)}


def dense_matmul(x, w, b=None):
    """Column-major dense: Y = W X + b; x: (f_in, nb), w: (f_out, f_in)."""
    y = w @ x
    if b is not None:
        y = y + b
    return y


def dense_linear_row(x, w, b=None):
    """Row-major dense: y = x @ W^T + b; x: (..., f_in)."""
    y = x @ w.T
    if b is not None:
        y = y + b
    return y


def _dense_kernel(w_ref, x_ref, o_ref):
    o_ref[...] = w_ref[...] @ x_ref[...]


def dense_matmul_pallas(x, w, b=None, row_tile: int = None, interpret=True):
    """Tiled Pallas dense matmul: grid over output-row tiles.

    Equal-footing baseline for the DYAD kernels: same pallas_call
    machinery, same activation residency, dense schedule.
    """
    f_out, f_in = w.shape
    nb = x.shape[-1]
    if row_tile is None:
        row_tile = f_out
    if f_out % row_tile:
        raise ValueError(f"f_out={f_out} not divisible by row_tile={row_tile}")
    y = pl.pallas_call(
        _dense_kernel,
        grid=(f_out // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, f_in), lambda i: (i, 0)),
            pl.BlockSpec((f_in, nb), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f_out, nb), w.dtype),
        interpret=interpret,
    )(w, x)
    if b is not None:
        y = y + b
    return y

"""Pure-jnp correctness oracle for the DYAD layer family.

The oracle *materialises* the full dense weight matrix ``W`` implied by the
3-D parameter tensors (including the BLOCKTRANS permutation) and applies a
plain dense matmul. Every efficient implementation (jnp-einsum and Pallas)
is checked against this module — if they agree with the materialised W,
the block/permutation bookkeeping is right by construction.

Shapes follow the paper's column-major convention (§2.1):
  X : (f_in, n_batch),  W : (f_out, f_in),  Y = W X + b.

Parameter tensors (paper Eq 2):
  wl : (n_dyad, n_out, n_in)   BLOCKDIAG blocks ("lower"/first component)
  wu : (n_dyad, n_out, n_in)   BLOCKTRANS blocks ("upper"/second component)
with f_in = n_dyad * n_in and f_out = n_dyad * n_out.
"""

import jax.numpy as jnp
import numpy as np

VARIANTS = ("it", "ot", "dt")


def perm_vector(n_block: int, n_dyad: int) -> np.ndarray:
    """Permutation pi over a dimension of size ``n_block * n_dyad``.

    pi[m] is the *original* index feeding slot ``m`` of the permuted
    (block-diagonal-ordered) vector. Slot m = i * n_block + k (block i,
    offset k) reads original index k * n_dyad + i — this is exactly the
    paper's "free strided view" (Eq 9): reshape(n_block, n_dyad) then
    transpose to (n_dyad, n_block).
    """
    m = np.arange(n_block * n_dyad)
    i, k = m // n_block, m % n_block
    return k * n_dyad + i


def blockdiag_full(w3: jnp.ndarray) -> jnp.ndarray:
    """Materialise a block-diagonal (f_out, f_in) matrix from blocks.

    w3 has shape (n_dyad, n_out, n_in); block i occupies rows
    [i*n_out, (i+1)*n_out) and columns [i*n_in, (i+1)*n_in) (paper Eq 2).
    """
    n_dyad, n_out, n_in = w3.shape
    full = jnp.zeros((n_dyad * n_out, n_dyad * n_in), dtype=w3.dtype)
    for i in range(n_dyad):
        full = full.at[i * n_out : (i + 1) * n_out, i * n_in : (i + 1) * n_in].set(
            w3[i]
        )
    return full


def blocktrans_full(w3: jnp.ndarray, variant: str) -> jnp.ndarray:
    """Materialise the BLOCKTRANS component for a given variant.

    The component is a block-diagonal matrix whose columns (IT), rows
    (OT), or both (DT) have been permuted by the strided-view
    permutation. Equivalences (paper §2.2.2, §2.4):

      IT: W2 = BD @ Pi_cols      -- columns permuted (input transpose)
      OT: W2 = Pi_rows^T @ BD    -- rows permuted (output transpose)
      DT: W2 = Pi_rows^T @ BD @ Pi_cols
    """
    n_dyad, n_out, n_in = w3.shape
    bd = blockdiag_full(w3)
    if variant == "it":
        pi = perm_vector(n_in, n_dyad)
        # y2 = BD @ x[pi]  =>  W2[:, pi[m]] = BD[:, m]
        return jnp.zeros_like(bd).at[:, pi].set(bd)
    if variant == "ot":
        pi = perm_vector(n_out, n_dyad)
        # y2[pi[m]] = (BD @ x)[m]  =>  W2[pi[m], :] = BD[m, :]
        return jnp.zeros_like(bd).at[pi, :].set(bd)
    if variant == "dt":
        pi_c = perm_vector(n_in, n_dyad)
        pi_r = perm_vector(n_out, n_dyad)
        w2 = jnp.zeros_like(bd).at[:, pi_c].set(bd)
        return jnp.zeros_like(w2).at[pi_r, :].set(w2)
    raise ValueError(f"unknown variant {variant!r}")


def dyad_full(wl: jnp.ndarray, wu: jnp.ndarray, variant: str) -> jnp.ndarray:
    """Materialise the full DYAD weight matrix W = W1 + W2 (paper Eq 1)."""
    return blockdiag_full(wl) + blocktrans_full(wu, variant)


def dyad_ref(x, wl, wu, b=None, variant: str = "it"):
    """Oracle forward: Y = (W1 + W2) X + b via the materialised matrix."""
    w = dyad_full(wl, wu, variant)
    y = w @ x
    if b is not None:
        y = y + b
    return y


def dense_ref(x, w, b=None):
    """Oracle forward for the DENSE baseline: Y = W X + b."""
    y = w @ x
    if b is not None:
        y = y + b
    return y

"""L2: decoder-only transformer LM with pluggable ff variant (DENSE/DYAD).

Everything the rust coordinator executes for language-model work is
defined here and AOT-lowered by ``aot.py``:

* ``train_step``  — K optimizer steps (inner ``lax.scan`` over
  microbatches) of Adam on next-token cross-entropy. K amortises the
  host round-trip of training state (DESIGN.md §2, §8).
* ``score``       — per-sequence summed token log-probability (BLIMP-like
  minimal pairs, few-shot MCQ scoring).
* ``features``    — masked mean-pooled final hidden states (GLUE-like
  probe finetuning; the probe head is trained in rust).
* ``next_logits`` — logits at each sequence's last real position
  (serving / greedy generation).
* ``ff_fwd`` / ``ff_fwdbwd`` — just the ff module at paper-true widths
  (timing tables T1/T5/T10, F6/F7, -CAT ablation).

Parameters travel as a *flat list* in the deterministic order given by
:func:`param_specs`; the same order is recorded in the artifact manifest
so rust can initialise, checkpoint and feed them without pytrees.
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import configs
from .configs import ArchConfig, VariantConfig
from .kernels.dyad import dyad_linear_row, dyad_matmul, dyad_param_shapes
from .kernels.dense import dense_linear_row

# ---------------------------------------------------------------------------
# Parameter specification (single source of truth for python AND rust)
# ---------------------------------------------------------------------------


def _ff_linear_specs(prefix, f_in, f_out, variant: VariantConfig):
    """Specs for one ff linear layer under the chosen variant."""
    if variant.kind == "dense":
        k = 1.0 / math.sqrt(f_in)
        return [
            (f"{prefix}.w", (f_out, f_in), {"kind": "uniform", "bound": k}),
            (f"{prefix}.b", (f_out,), {"kind": "uniform", "bound": k}),
        ]
    shapes = dyad_param_shapes(variant.n_dyad, f_in, f_out)
    k = shapes["init_bound"]
    return [
        (f"{prefix}.wl", shapes["wl"], {"kind": "uniform", "bound": k}),
        (f"{prefix}.wu", shapes["wu"], {"kind": "uniform", "bound": k}),
        (f"{prefix}.b", (f_out,), {"kind": "uniform", "bound": k}),
    ]


def param_specs(arch: ArchConfig, variant: VariantConfig):
    """Ordered [(name, shape, init)] for the whole model.

    Embeddings are tied (OPT-style): ``tok_emb`` doubles as the LM head.
    """
    d, ff = arch.d_model, arch.d_ff
    ka = 1.0 / math.sqrt(d)
    specs = [
        ("tok_emb", (arch.vocab, d), {"kind": "normal", "std": 0.02}),
        ("pos_emb", (arch.seq, d), {"kind": "normal", "std": 0.02}),
    ]
    for l in range(arch.n_layers):
        p = f"layer{l}"
        specs += [
            (f"{p}.ln1.scale", (d,), {"kind": "ones"}),
            (f"{p}.ln1.bias", (d,), {"kind": "zeros"}),
        ]
        for m in ("wq", "wk", "wv", "wo"):
            specs += [
                (f"{p}.attn.{m}", (d, d), {"kind": "uniform", "bound": ka}),
                (f"{p}.attn.{m}_b", (d,), {"kind": "zeros"}),
            ]
        specs += [
            (f"{p}.ln2.scale", (d,), {"kind": "ones"}),
            (f"{p}.ln2.bias", (d,), {"kind": "zeros"}),
        ]
        specs += _ff_linear_specs(f"{p}.ff.fc1", d, ff, variant)
        specs += _ff_linear_specs(f"{p}.ff.fc2", ff, d, variant)
    specs += [
        ("final_ln.scale", (d,), {"kind": "ones"}),
        ("final_ln.bias", (d,), {"kind": "zeros"}),
    ]
    return specs


def init_params(arch, variant, key):
    """Python-side init (tests + parity checks with rust init)."""
    out = []
    for name, shape, init in param_specs(arch, variant):
        key, sub = jax.random.split(key)
        if init["kind"] == "uniform":
            out.append(
                jax.random.uniform(
                    sub, shape, jnp.float32, -init["bound"], init["bound"]
                )
            )
        elif init["kind"] == "normal":
            out.append(init["std"] * jax.random.normal(sub, shape, jnp.float32))
        elif init["kind"] == "zeros":
            out.append(jnp.zeros(shape, jnp.float32))
        elif init["kind"] == "ones":
            out.append(jnp.ones(shape, jnp.float32))
        else:
            raise ValueError(init)
    return out


def _as_dict(flat, specs):
    return {name: arr for (name, _, _), arr in zip(specs, flat)}


# ---------------------------------------------------------------------------
# Model forward
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _ff_linear(p, prefix, x, variant: VariantConfig):
    if variant.kind == "dense":
        return dense_linear_row(x, p[f"{prefix}.w"], p[f"{prefix}.b"])
    return dyad_linear_row(
        x,
        p[f"{prefix}.wl"],
        p[f"{prefix}.wu"],
        p[f"{prefix}.b"],
        variant=variant.dyad_variant,
    )


def _ff_linear_cm(p, prefix, xc, variant: VariantConfig, dyad_variant: str):
    """Column-major linear: xc is (f_in, t); returns (f_out, t).

    The DYAD branch runs the paper's Eq 3-10 schedule directly — all
    block views of xc are free reshapes/stride-swaps. Measured fastest
    lowering on XLA-CPU by a wide margin (EXPERIMENTS.md §Perf L2).
    """
    if variant.kind == "dense":
        return p[f"{prefix}.w"] @ xc + p[f"{prefix}.b"][:, None]
    return dyad_matmul(
        xc,
        p[f"{prefix}.wl"],
        p[f"{prefix}.wu"],
        p[f"{prefix}.b"][:, None],
        variant=dyad_variant,
    )


def ff_module(p, prefix, x, variant: VariantConfig, layer: int = 0):
    """The paper's swap site: fc1 -> GELU -> fc2.

    Internally column-major: one activation transpose in, one out —
    both linears then see free strided block views (§Perf L2).
    ``layer`` selects the per-layer dyad variant for heterogeneous
    schedules (paper §4 future work).
    """
    dv = variant.variant_for_layer(layer) if variant.kind == "dyad" else "it"
    lead = x.shape[:-1]
    d = x.shape[-1]
    xc = x.reshape(-1, d).T
    h = jax.nn.gelu(_ff_linear_cm(p, f"{prefix}.fc1", xc, variant, dv))
    y = _ff_linear_cm(p, f"{prefix}.fc2", h, variant, dv)
    return y.T.reshape(lead + (y.shape[0],))


def _attention(p, prefix, x, arch: ArchConfig):
    """Standard causal MHA, fp32 (the paper trains fp32, §5.2)."""
    b, s, d = x.shape
    nh, hd = arch.n_heads, arch.head_dim

    def proj(w, bias):
        return (x @ w.T + bias).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)

    q = proj(p[f"{prefix}.wq"], p[f"{prefix}.wq_b"])
    k = proj(p[f"{prefix}.wk"], p[f"{prefix}.wk_b"])
    v = proj(p[f"{prefix}.wv"], p[f"{prefix}.wv_b"])
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(causal, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[f"{prefix}.wo"].T + p[f"{prefix}.wo_b"]


def hidden_states(flat_params, tokens, arch: ArchConfig, variant: VariantConfig):
    """(B, S) int32 tokens -> (B, S, d) final hidden states."""
    specs = param_specs(arch, variant)
    p = _as_dict(flat_params, specs)
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    for l in range(arch.n_layers):
        pref = f"layer{l}"
        if arch.parallel_residual:
            # Pythia-style: attn and ff both read the same pre-LN input.
            h1 = _layer_norm(x, p[f"{pref}.ln1.scale"], p[f"{pref}.ln1.bias"])
            h2 = _layer_norm(x, p[f"{pref}.ln2.scale"], p[f"{pref}.ln2.bias"])
            x = x + _attention(p, f"{pref}.attn", h1, arch) + ff_module(
                p, f"{pref}.ff", h2, variant, layer=l
            )
        else:
            h = _layer_norm(x, p[f"{pref}.ln1.scale"], p[f"{pref}.ln1.bias"])
            x = x + _attention(p, f"{pref}.attn", h, arch)
            h = _layer_norm(x, p[f"{pref}.ln2.scale"], p[f"{pref}.ln2.bias"])
            x = x + ff_module(p, f"{pref}.ff", h, variant, layer=l)
    return _layer_norm(x, p["final_ln.scale"], p["final_ln.bias"])


def logits_fn(flat_params, tokens, arch, variant):
    h = hidden_states(flat_params, tokens, arch, variant)
    specs = param_specs(arch, variant)
    p = _as_dict(flat_params, specs)
    return h @ p["tok_emb"].T  # tied head


def loss_fn(flat_params, tokens, arch, variant):
    """Mean next-token cross-entropy over (B, S) packed sequences."""
    logits = logits_fn(flat_params, tokens, arch, variant)  # (B, S, V)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Training step (Adam-in-graph, K microbatches per call)
# ---------------------------------------------------------------------------


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in grads))


def make_train_step(arch, variant, k_micro, batch):
    """Returns fn(params.., m.., v.., step, lr, tokens) -> (..., losses).

    tokens: (K, B, S) int32. Advances K Adam steps; ``losses`` is (K,).
    step is float32 (bias correction); lr is applied uniformly across
    the K inner steps (rust recomputes the schedule between calls).
    """
    n = len(param_specs(arch, variant))

    def train_step(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step, lr, tokens = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        def one_step(carry, batch_tokens):
            params, m, v, step = carry
            loss, grads = jax.value_and_grad(loss_fn)(
                params, batch_tokens, arch, variant
            )
            gnorm = _global_norm(grads)
            scale = jnp.minimum(1.0, configs.GRAD_CLIP / (gnorm + 1e-12))
            grads = [g * scale for g in grads]
            step = step + 1.0
            b1, b2, eps = configs.ADAM_B1, configs.ADAM_B2, configs.ADAM_EPS
            m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
            v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads)]
            mhat_scale = 1.0 / (1.0 - b1**step)
            vhat_scale = 1.0 / (1.0 - b2**step)
            params = [
                p - lr * (mi * mhat_scale) / (jnp.sqrt(vi * vhat_scale) + eps)
                for p, mi, vi in zip(params, m, v)
            ]
            return (params, m, v, step), loss

        (params, m, v, step), losses = jax.lax.scan(
            one_step, (params, m, v, step), tokens
        )
        return tuple(params) + tuple(m) + tuple(v) + (step, losses)

    return train_step


# ---------------------------------------------------------------------------
# Evaluation / serving functions
# ---------------------------------------------------------------------------


def make_score(arch, variant):
    """fn(params.., tokens (B,S) i32, mask (B,S) f32) -> (sum_logp, n_tok).

    sum_logp[b] = sum over positions t>=1 with mask[t]==1 of
    log P(tokens[t] | tokens[<t]). The standard minimal-pair/MCQ scorer.
    """

    def score(*args):
        n = len(param_specs(arch, variant))
        params, tokens, mask = list(args[:n]), args[n], args[n + 1]
        logits = logits_fn(params, tokens, arch, variant)
        logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
        tgt = tokens[:, 1:]
        tok_lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        m = mask[:, 1:]
        return (jnp.sum(tok_lp * m, axis=-1), jnp.sum(m, axis=-1))

    return score


def make_features(arch, variant):
    """fn(params.., tokens, mask) -> (B, d) masked mean-pooled hiddens."""

    def features(*args):
        n = len(param_specs(arch, variant))
        params, tokens, mask = list(args[:n]), args[n], args[n + 1]
        h = hidden_states(params, tokens, arch, variant)
        m = mask[..., None]
        return jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)

    return features


def make_next_logits(arch, variant):
    """fn(params.., tokens (B,S), lengths (B,) i32) -> (B, vocab) logits
    at each sequence's last real position (for sampling in rust)."""

    def next_logits(*args):
        n = len(param_specs(arch, variant))
        params, tokens, lengths = list(args[:n]), args[n], args[n + 1]
        logits = logits_fn(params, tokens, arch, variant)
        idx = jnp.maximum(lengths - 1, 0)
        return jnp.take_along_axis(
            logits, idx[:, None, None].astype(jnp.int32), axis=1
        )[:, 0, :]

    return next_logits


def make_eval_loss(arch, variant, batch):
    """fn(params.., tokens (B,S)) -> scalar mean CE (validation loss)."""

    def eval_loss(*args):
        n = len(param_specs(arch, variant))
        params, tokens = list(args[:n]), args[n]
        return (loss_fn(params, tokens, arch, variant),)

    return eval_loss


# ---------------------------------------------------------------------------
# ff-micro functions (timing tables at paper-true widths)
# ---------------------------------------------------------------------------


def ff_param_specs(d, ff, variant: VariantConfig):
    return _ff_linear_specs("fc1", d, ff, variant) + _ff_linear_specs(
        "fc2", ff, d, variant
    )


def make_ff_fwd(d, ff, variant):
    """fn(ff_params.., x (T, d)) -> (T, d): the ff module forward."""
    specs = ff_param_specs(d, ff, variant)

    def ff_fwd(*args):
        p = _as_dict(list(args[:-1]), specs)
        x = args[-1]
        h = _ff_linear(p, "fc1", x, variant)
        h = jax.nn.gelu(h)
        return (_ff_linear(p, "fc2", h, variant),)

    return ff_fwd


def make_ff_fwdbwd(d, ff, variant):
    """fn(ff_params.., x, cotangent (T, d)) -> (loss-ish scalar, grads..).

    Forward + backward through the ff module (the paper times both
    passes separately; we emit fwd and fwd+bwd artifacts and subtract).
    """
    specs = ff_param_specs(d, ff, variant)
    n = len(specs)

    def ff_loss(params, x, ct):
        p = _as_dict(params, specs)
        h = _ff_linear(p, "fc1", x, variant)
        h = jax.nn.gelu(h)
        y = _ff_linear(p, "fc2", h, variant)
        return jnp.sum(y * ct)

    def ff_fwdbwd(*args):
        params, x, ct = list(args[:n]), args[n], args[n + 1]
        loss, grads = jax.value_and_grad(ff_loss)(params, x, ct)
        return (loss,) + tuple(grads)

    return ff_fwdbwd

"""AOT lowering: every jitted function the rust coordinator needs,
emitted as HLO *text* plus a manifest describing each artifact's exact
input/output contract.

HLO text (not ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the ``xla`` crate's
backend) rejects; the text parser reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out ../artifacts [--only RE]

Python runs ONCE here; the rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, mnist, model
from .configs import ARCHS, FF_GEOMETRIES, VARIANTS, WIDTH_SWEEP, WIDTH_SWEEP_TOKENS
from .kernels.dyad import dyad_matmul_pallas, vmem_estimate_bytes

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(dtype):
    return {F32: "f32", I32: "i32"}[dtype]


class Emitter:
    def __init__(self, out_dir, only=None):
        self.out_dir = out_dir
        self.only = re.compile(only) if only else None
        self.entries = []
        self.t0 = time.time()

    def emit(self, name, fn, inputs, outputs, kind, meta=None):
        """Lower ``fn`` at the given input specs and record the contract.

        inputs:  [(name, shape, dtype, role, init-or-None)]
        outputs: [(name, shape, dtype)]
        """
        fname = name.replace("/", "_") + ".hlo.txt"
        entry = {
            "name": name,
            "file": fname,
            "kind": kind,
            "inputs": [
                {
                    "name": n,
                    "shape": list(s),
                    "dtype": _dt(d),
                    "role": role,
                    **({"init": init} if init else {}),
                }
                for (n, s, d, role, init) in inputs
            ],
            "outputs": [
                {"name": n, "shape": list(s), "dtype": _dt(d)}
                for (n, s, d) in outputs
            ],
            "meta": meta or {},
        }
        self.entries.append(entry)
        if self.only and not self.only.search(name):
            return
        path = os.path.join(self.out_dir, fname)
        specs = [sds(s, d) for (_, s, d, _, _) in inputs]
        t = time.time()
        # keep_unused=True: the manifest promises positional arity even
        # for params a given fn doesn't touch (e.g. the MLP head in
        # hidden_fwd); without it jit prunes them and PJRT rejects the
        # feed ("supplied 9 buffers but expected 7").
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(
            f"[{time.time()-self.t0:7.1f}s] {name}: {len(text)/1e6:.2f} MB "
            f"({time.time()-t:.1f}s)",
            flush=True,
        )


def model_param_inputs(arch, variant, role="param", prefix=""):
    out = []
    for n, s, init in model.param_specs(arch, variant):
        out.append((prefix + n, s, F32, role, init if role == "param" else None))
    return out


def opt_state_inputs(arch, variant):
    """Adam m/v mirrors of the params (zero-init)."""
    ins = []
    for role, pref in (("opt_m", "m."), ("opt_v", "v.")):
        for n, s, _ in model.param_specs(arch, variant):
            ins.append((pref + n, s, F32, role, {"kind": "zeros"}))
    return ins


def emit_model_artifacts(em, arch_name, variant_names):
    arch = ARCHS[arch_name]
    B, S, K = configs.TRAIN_BATCH, arch.seq, configs.TRAIN_MICROBATCHES
    EB = configs.EVAL_BATCH
    for vname in variant_names:
        var = VARIANTS[vname]
        specs = model.param_specs(arch, var)
        pnames = [n for n, _, _ in specs]
        pshapes = [s for _, s, _ in specs]
        base = f"{arch_name}/{vname}"
        params_in = model_param_inputs(arch, var)
        opt_in = opt_state_inputs(arch, var)

        for k in (K, 1):
            toks = ("tokens", (k, B, S), I32, "data", None)
            ins = (
                params_in
                + opt_in
                + [
                    ("step", (), F32, "scalar", None),
                    ("lr", (), F32, "scalar", None),
                    toks,
                ]
            )
            outs = (
                [(n, s, F32) for n, s in zip(pnames, pshapes)]
                + [("m." + n, s, F32) for n, s in zip(pnames, pshapes)]
                + [("v." + n, s, F32) for n, s in zip(pnames, pshapes)]
                + [("step", (), F32), ("losses", (k,), F32)]
            )
            em.emit(
                f"{base}/train_k{k}",
                model.make_train_step(arch, var, k, B),
                ins,
                outs,
                "train_step",
                {"k_micro": k, "batch": B, "seq": S, "arch": arch_name,
                 "variant": vname},
            )

        score_ins = params_in + [
            ("tokens", (EB, S), I32, "data", None),
            ("mask", (EB, S), F32, "data", None),
        ]
        em.emit(
            f"{base}/score",
            model.make_score(arch, var),
            score_ins,
            [("sum_logp", (EB,), F32), ("n_tok", (EB,), F32)],
            "score",
            {"batch": EB, "seq": S, "arch": arch_name, "variant": vname},
        )
        em.emit(
            f"{base}/features",
            model.make_features(arch, var),
            score_ins,
            [("features", (EB, arch.d_model), F32)],
            "features",
            {"batch": EB, "seq": S, "arch": arch_name, "variant": vname},
        )
        em.emit(
            f"{base}/next_logits",
            model.make_next_logits(arch, var),
            params_in
            + [
                ("tokens", (EB, S), I32, "data", None),
                ("lengths", (EB,), I32, "data", None),
            ],
            [("logits", (EB, arch.vocab), F32)],
            "next_logits",
            {"batch": EB, "seq": S, "arch": arch_name, "variant": vname},
        )
        em.emit(
            f"{base}/eval_loss",
            model.make_eval_loss(arch, var, EB),
            params_in + [("tokens", (EB, S), I32, "data", None)],
            [("loss", (), F32)],
            "eval_loss",
            {"batch": EB, "seq": S, "arch": arch_name, "variant": vname},
        )


def emit_ff_artifacts(em, label, d, ff, tokens, variant_names):
    for vname in variant_names:
        var = VARIANTS[vname]
        specs = model.ff_param_specs(d, ff, var)
        params_in = [(n, s, F32, "param", init) for n, s, init in specs]
        x = ("x", (tokens, d), F32, "data", None)
        ct = ("ct", (tokens, d), F32, "data", None)
        meta = {
            "d_model": d,
            "d_ff": ff,
            "tokens": tokens,
            "variant": vname,
            "vmem_bytes_per_step": (
                None
                if var.kind == "dense"
                else vmem_estimate_bytes(
                    var.n_dyad, d, ff, tokens, cat=var.dyad_variant == "it_cat"
                )
            ),
        }
        em.emit(
            f"ff/{label}/{vname}/fwd",
            model.make_ff_fwd(d, ff, var),
            params_in + [x],
            [("y", (tokens, d), F32)],
            "ff_fwd",
            meta,
        )
        em.emit(
            f"ff/{label}/{vname}/fwdbwd",
            model.make_ff_fwdbwd(d, ff, var),
            params_in + [x, ct],
            [("loss", (), F32)] + [(f"g.{n}", s, F32) for n, s, _ in specs],
            "ff_fwdbwd",
            meta,
        )


def emit_mnist_artifacts(em):
    B, K = configs.MNIST_BATCH, 4
    for vname in ("dense", "dyad_it"):
        var = VARIANTS[vname]
        specs = mnist.mnist_param_specs(var)
        pnames = [n for n, _, _ in specs]
        pshapes = [s for _, s, _ in specs]
        params_in = [(n, s, F32, "param", init) for n, s, init in specs]
        opt_in = [
            (pref + n, s, F32, role, {"kind": "zeros"})
            for role, pref in (("opt_m", "m."), ("opt_v", "v."))
            for n, s, _ in specs
        ]
        ins = (
            params_in
            + opt_in
            + [
                ("step", (), F32, "scalar", None),
                ("lr", (), F32, "scalar", None),
                ("images", (K, B, configs.MNIST_IN), F32, "data", None),
                ("labels", (K, B), I32, "data", None),
            ]
        )
        outs = (
            [(n, s, F32) for n, s in zip(pnames, pshapes)]
            + [("m." + n, s, F32) for n, s in zip(pnames, pshapes)]
            + [("v." + n, s, F32) for n, s in zip(pnames, pshapes)]
            + [("step", (), F32), ("losses", (K,), F32)]
        )
        em.emit(
            f"mnist/{vname}/train_k{K}",
            mnist.make_mnist_train_step(var, K, B),
            ins,
            outs,
            "mnist_train",
            {"k_micro": K, "batch": B, "variant": vname},
        )
        em.emit(
            f"mnist/{vname}/accuracy",
            mnist.make_mnist_accuracy(var, B),
            params_in
            + [
                ("images", (B, configs.MNIST_IN), F32, "data", None),
                ("labels", (B,), I32, "data", None),
            ],
            [("n_correct", (), I32)],
            "mnist_accuracy",
            {"batch": B, "variant": vname},
        )
        em.emit(
            f"mnist/{vname}/hidden_fwd",
            mnist.make_mnist_hidden_fwd(var, B),
            params_in + [("x", (B, configs.MNIST_IN), F32, "data", None)],
            [("h", (B, configs.MNIST_HIDDEN), F32)],
            "mnist_hidden_fwd",
            {"batch": B, "variant": vname},
        )


def emit_pallas_validation(em):
    """A small interpret-mode Pallas DYAD-IT kernel, AOT'd end-to-end.

    Proves the L1 kernel survives the full HLO-text -> PJRT -> rust
    round trip (numerics asserted in rust integration tests). Kept tiny:
    interpret-mode lowers to while-loops, unfit for timing (DESIGN.md §7).
    """
    n_dyad, n_in, n_out, nb = 4, 16, 16, 8

    def fn(wl, wu, x):
        return (dyad_matmul_pallas(x, wl, wu, None, variant="it"),)

    em.emit(
        "pallas/dyad_it_small",
        fn,
        [
            ("wl", (n_dyad, n_out, n_in), F32, "param",
             {"kind": "uniform", "bound": (n_dyad * n_in) ** -0.5}),
            ("wu", (n_dyad, n_out, n_in), F32, "param",
             {"kind": "uniform", "bound": (n_dyad * n_in) ** -0.5}),
            ("x", (n_dyad * n_in, nb), F32, "data", None),
        ],
        [("y", (n_dyad * n_out, nb), F32)],
        "pallas_validation",
        {"n_dyad": n_dyad, "n_in": n_in, "n_out": n_out, "nb": nb},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out, args.only)

    # Whole-model artifacts (quality tables + whole-model timing).
    emit_model_artifacts(
        em,
        "opt-mini",
        ["dense", "dyad_it", "dyad_ot", "dyad_dt", "dyad_it_8", "dyad_hetero"],
    )
    emit_model_artifacts(em, "pythia-mini", ["dense", "dyad_it", "dyad_it_8"])
    emit_model_artifacts(em, "opt-mid", ["dense", "dyad_it"])

    # ff-micro artifacts at the paper's true widths (T1/T5/T10, F7, CAT).
    ff_variants = ["dense", "dyad_it", "dyad_ot", "dyad_dt", "dyad_it_8",
                   "dyad_it_cat"]
    for label, (d, ff, toks) in FF_GEOMETRIES.items():
        emit_ff_artifacts(em, label, d, ff, toks, ff_variants)

    # Figure 6 width sweep.
    for w in WIDTH_SWEEP:
        emit_ff_artifacts(
            em, f"width{w}", w, 4 * w, WIDTH_SWEEP_TOKENS,
            ["dense", "dyad_it", "dyad_it_8"],
        )

    emit_mnist_artifacts(em)
    emit_pallas_validation(em)

    manifest = {
        "version": 1,
        "adam": {
            "b1": configs.ADAM_B1,
            "b2": configs.ADAM_B2,
            "eps": configs.ADAM_EPS,
            "grad_clip": configs.GRAD_CLIP,
        },
        "archs": {
            name: {
                "vocab": a.vocab,
                "d_model": a.d_model,
                "d_ff": a.d_ff,
                "n_layers": a.n_layers,
                "n_heads": a.n_heads,
                "seq": a.seq,
                "parallel_residual": a.parallel_residual,
            }
            for name, a in ARCHS.items()
        },
        "variants": {
            name: {"kind": v.kind, "dyad_variant": v.dyad_variant,
                   "n_dyad": v.n_dyad,
                   "layer_schedule": list(v.layer_schedule)}
            for name, v in VARIANTS.items()
        },
        "artifacts": em.entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(em.entries)} manifest entries "
          f"({time.time()-em.t0:.0f}s total)")


if __name__ == "__main__":
    main()

# Build-time-only package: JAX model (L2) + Pallas kernels (L1) + AOT
# lowering (python -m compile.aot). Never imported at runtime; the rust
# coordinator consumes artifacts/*.hlo.txt + artifacts/manifest.json.

"""L1 correctness: efficient DYAD implementations vs the materialised-W
oracle. Hypothesis sweeps shapes/dtypes; fixed cases pin the paper's
worked example (n_dyad = n_in = n_out = 4, Fig 1)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    blockdiag_full,
    blocktrans_full,
    dyad_full,
    dyad_ref,
    dense_ref,
    perm_vector,
    dyad_matmul,
    dyad_matmul_pallas,
    dyad_linear_row,
    dyad_param_shapes,
    dense_matmul_pallas,
    dense_linear_row,
)

jax.config.update("jax_enable_x64", False)

VARIANTS = ("it", "ot", "dt", "it_cat")
REF_VARIANT = {"it": "it", "ot": "ot", "dt": "dt", "it_cat": "it"}


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _params(rng, n_dyad, n_in, n_out, nb, dtype=np.float32):
    wl = _rand(rng, (n_dyad, n_out, n_in), dtype)
    wu = _rand(rng, (n_dyad, n_out, n_in), dtype)
    x = _rand(rng, (n_dyad * n_in, nb), dtype)
    b = _rand(rng, (n_dyad * n_out, 1), dtype)
    return wl, wu, x, b


# ---------------------------------------------------------------------------
# Permutation / materialisation invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_block,n_dyad", [(4, 4), (3, 5), (8, 2), (1, 7)])
def test_perm_vector_is_permutation(n_block, n_dyad):
    pi = perm_vector(n_block, n_dyad)
    assert sorted(pi.tolist()) == list(range(n_block * n_dyad))


@pytest.mark.parametrize("n_block,n_dyad", [(4, 4), (3, 5), (8, 2)])
def test_perm_matches_strided_view(n_block, n_dyad):
    """pi is exactly the paper's Eq-9 stride-swap view."""
    v = np.arange(n_block * n_dyad, dtype=np.float32)
    pi = perm_vector(n_block, n_dyad)
    via_perm = v[pi]
    via_view = v.reshape(n_block, n_dyad).T.flatten()
    np.testing.assert_array_equal(via_perm, via_view)


@pytest.mark.parametrize("n_block,n_dyad", [(4, 4), (3, 5)])
def test_perm_orthonormal(n_block, n_dyad):
    """P P^T = I (paper §2.2.2): applying pi then its argsort is identity."""
    pi = perm_vector(n_block, n_dyad)
    inv = np.argsort(pi)
    v = np.arange(n_block * n_dyad)
    np.testing.assert_array_equal(v[pi][inv], v)


def test_blockdiag_structure():
    rng = np.random.default_rng(0)
    w3 = _rand(rng, (3, 2, 4))
    full = np.asarray(blockdiag_full(w3))
    for i in range(3):
        blk = full[i * 2 : (i + 1) * 2, i * 4 : (i + 1) * 4]
        np.testing.assert_array_equal(blk, np.asarray(w3[i]))
    # everything off the block diagonal is exactly zero
    mask = np.ones_like(full, dtype=bool)
    for i in range(3):
        mask[i * 2 : (i + 1) * 2, i * 4 : (i + 1) * 4] = False
    assert (full[mask] == 0).all()


@pytest.mark.parametrize("variant", ("it", "ot", "dt"))
def test_blocktrans_is_permuted_blockdiag(variant):
    """BLOCKTRANS must be BLOCKDIAG with rows/cols permuted — same
    multiset of entries, same number of nonzeros."""
    rng = np.random.default_rng(1)
    w3 = _rand(rng, (4, 4, 4))
    bd = np.asarray(blockdiag_full(w3))
    bt = np.asarray(blocktrans_full(w3, variant))
    assert bt.shape == bd.shape
    np.testing.assert_allclose(np.sort(bt.flatten()), np.sort(bd.flatten()))
    assert (bt != 0).sum() == (bd != 0).sum()


def test_dyad_full_density():
    """DYAD density ~ 2/n_dyad of dense (minus shared-support overlap)."""
    rng = np.random.default_rng(2)
    n_dyad = 4
    w3l, w3u = _rand(rng, (n_dyad, 4, 4)), _rand(rng, (n_dyad, 4, 4))
    full = np.asarray(dyad_full(w3l, w3u, "it"))
    nnz = (full != 0).sum()
    assert nnz <= 2 * n_dyad * 4 * 4
    assert nnz > n_dyad * 4 * 4  # strictly denser than one component


# ---------------------------------------------------------------------------
# Efficient jnp forms vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
def test_dyad_matmul_paper_example(variant):
    """The paper's worked example: n_dyad = n_in = n_out = 4."""
    rng = np.random.default_rng(3)
    wl, wu, x, b = _params(rng, 4, 4, 4, 7)
    got = dyad_matmul(x, wl, wu, b, variant=variant)
    want = dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    n_dyad=st.sampled_from([1, 2, 4, 8]),
    n_in=st.integers(1, 9),
    n_out=st.integers(1, 9),
    nb=st.integers(1, 6),
    variant=st.sampled_from(VARIANTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dyad_matmul_vs_ref_hypothesis(n_dyad, n_in, n_out, nb, variant, seed):
    rng = np.random.default_rng(seed)
    wl, wu, x, b = _params(rng, n_dyad, n_in, n_out, nb)
    got = dyad_matmul(x, wl, wu, b, variant=variant)
    want = dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    n_dyad=st.sampled_from([2, 4]),
    n_in=st.integers(1, 6),
    n_out=st.integers(1, 6),
    nb=st.integers(1, 5),
    variant=st.sampled_from(VARIANTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dyad_linear_row_vs_ref_hypothesis(n_dyad, n_in, n_out, nb, variant, seed):
    """Row-major (model-convention) path: y = x W^T + b."""
    rng = np.random.default_rng(seed)
    wl, wu, x, b = _params(rng, n_dyad, n_in, n_out, nb)
    xr = x.T  # (nb, f_in)
    got = dyad_linear_row(xr, wl, wu, b[:, 0], variant=variant)
    want = dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant]).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_dyad_linear_row_leading_dims():
    """Row path must accept (batch, seq, f_in) activations."""
    rng = np.random.default_rng(5)
    wl, wu, x, b = _params(rng, 4, 3, 5, 6)
    xr = jnp.asarray(rng.standard_normal((2, 3, 12)), dtype=jnp.float32)
    y = dyad_linear_row(xr, wl, wu, b[:, 0], variant="it")
    assert y.shape == (2, 3, 20)
    flat = dyad_linear_row(xr.reshape(6, 12), wl, wu, b[:, 0], variant="it")
    np.testing.assert_allclose(np.asarray(y).reshape(6, 20), np.asarray(flat), rtol=1e-5)


def test_dense_linear_row():
    rng = np.random.default_rng(6)
    w = _rand(rng, (5, 3))
    x = _rand(rng, (3, 4))
    b = _rand(rng, (5, 1))
    got = dense_linear_row(x.T, w, b[:, 0])
    want = dense_ref(x, w, b).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle (interpret=True)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
def test_pallas_paper_example(variant):
    rng = np.random.default_rng(7)
    wl, wu, x, b = _params(rng, 4, 4, 4, 5)
    got = dyad_matmul_pallas(x, wl, wu, b, variant=variant)
    want = dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n_dyad=st.sampled_from([1, 2, 4]),
    n_in=st.integers(1, 8),
    n_out=st.integers(1, 8),
    nb=st.integers(1, 5),
    variant=st.sampled_from(VARIANTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_vs_ref_hypothesis(n_dyad, n_in, n_out, nb, variant, seed):
    rng = np.random.default_rng(seed)
    wl, wu, x, b = _params(rng, n_dyad, n_in, n_out, nb)
    got = dyad_matmul_pallas(x, wl, wu, b, variant=variant)
    want = dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_pallas_dtypes(dtype, tol):
    rng = np.random.default_rng(8)
    wl, wu, x, b = _params(rng, 4, 4, 4, 4, dtype=np.float32)
    wl, wu, x, b = (a.astype(dtype) for a in (wl, wu, x, b))
    got = dyad_matmul_pallas(x, wl, wu, b, variant="it").astype(jnp.float32)
    want = dyad_ref(
        x.astype(jnp.float32),
        wl.astype(jnp.float32),
        wu.astype(jnp.float32),
        b.astype(jnp.float32),
        variant="it",
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_pallas_dense_baseline():
    rng = np.random.default_rng(9)
    w = _rand(rng, (8, 6))
    x = _rand(rng, (6, 5))
    b = _rand(rng, (8, 1))
    got = dense_matmul_pallas(x, w, b, row_tile=4)
    want = dense_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_pallas_matches_jnp_exactly_it():
    """Pallas and einsum paths should agree to float32 round-off."""
    rng = np.random.default_rng(10)
    wl, wu, x, b = _params(rng, 4, 8, 8, 16)
    a = np.asarray(dyad_matmul(x, wl, wu, b, variant="it"))
    c = np.asarray(dyad_matmul_pallas(x, wl, wu, b, variant="it"))
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Gradients + jit of the efficient forms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
def test_dyad_grads_match_ref(variant):
    """d/dW of the efficient form == d/dW of the materialised oracle."""
    rng = np.random.default_rng(11)
    wl, wu, x, b = _params(rng, 2, 3, 4, 5)

    def loss_eff(wl, wu):
        return jnp.sum(dyad_matmul(x, wl, wu, b, variant=variant) ** 2)

    def loss_ref(wl, wu):
        return jnp.sum(dyad_ref(x, wl, wu, b, variant=REF_VARIANT[variant]) ** 2)

    ge = jax.grad(loss_eff, argnums=(0, 1))(wl, wu)
    gr = jax.grad(loss_ref, argnums=(0, 1))(wl, wu)
    for a, b_ in zip(ge, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)


def test_param_shapes_and_divisibility():
    s = dyad_param_shapes(4, 768, 3072)
    assert s["wl"] == (4, 768, 192)
    assert s["wu"] == (4, 768, 192)
    assert abs(s["init_bound"] - 768**-0.5) < 1e-12
    with pytest.raises(ValueError):
        dyad_param_shapes(5, 768, 3072)


def test_param_reduction_factor():
    """DYAD stores 2/n_dyad of the dense weight count (paper §2.2.1)."""
    for n_dyad in (2, 4, 8):
        s = dyad_param_shapes(n_dyad, 512, 2048)
        dyad_params = 2 * np.prod(s["wl"])
        dense_params = 512 * 2048
        assert dyad_params * n_dyad == 2 * dense_params

"""§4 future work: heterogeneous per-layer DYAD variant schedules."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.configs import ArchConfig, VariantConfig, VARIANTS

TINY = ArchConfig("tiny", vocab=64, d_model=32, d_ff=64, n_layers=3,
                  n_heads=4, seq=16)


def test_variant_for_layer_cycles():
    v = VARIANTS["dyad_hetero"]
    assert [v.variant_for_layer(l) for l in range(5)] == \
        ["it", "ot", "dt", "it", "ot"]
    homog = VARIANTS["dyad_it"]
    assert homog.variant_for_layer(7) == "it"


def test_hetero_param_shapes_same_as_homogeneous():
    """Hetero uses the same 3-D storage as any dyad variant, so specs
    (and therefore manifests/checkpoints) are shape-compatible."""
    a = model.param_specs(TINY, VARIANTS["dyad_hetero"])
    b = model.param_specs(TINY, VARIANTS["dyad_it"])
    assert [(n, s) for n, s, _ in a] == [(n, s) for n, s, _ in b]


def test_hetero_forward_differs_from_homogeneous():
    """Same weights, different per-layer permutations => different
    function (unless n_layers < 2, which TINY isn't)."""
    params = model.init_params(TINY, VARIANTS["dyad_hetero"], jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, 64, size=(2, TINY.seq)), jnp.int32)
    out_h = model.logits_fn(params, toks, TINY, VARIANTS["dyad_hetero"])
    out_i = model.logits_fn(params, toks, TINY, VARIANTS["dyad_it"])
    assert out_h.shape == out_i.shape
    assert bool(jnp.all(jnp.isfinite(out_h)))
    assert not np.allclose(np.asarray(out_h), np.asarray(out_i))


def test_hetero_layer0_matches_it():
    """Layer 0 of the schedule is IT, so a 1-layer hetero model equals
    the homogeneous IT model exactly."""
    one = ArchConfig("one", vocab=64, d_model=32, d_ff=64, n_layers=1,
                     n_heads=4, seq=16)
    params = model.init_params(one, VARIANTS["dyad_hetero"], jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, 64, size=(2, 16)), jnp.int32)
    out_h = model.logits_fn(params, toks, one, VARIANTS["dyad_hetero"])
    out_i = model.logits_fn(params, toks, one, VARIANTS["dyad_it"])
    np.testing.assert_allclose(np.asarray(out_h), np.asarray(out_i), rtol=1e-5)


def test_hetero_trains():
    var = VARIANTS["dyad_hetero"]
    params = model.init_params(TINY, var, jax.random.PRNGKey(2))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step_fn = jax.jit(model.make_train_step(TINY, var, 2, 2))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, 64, size=(2, 2, TINY.seq)), jnp.int32)
    first = last = None
    step = jnp.float32(0)
    for _ in range(4):
        out = step_fn(*params, *m, *v, step, jnp.float32(1e-3), toks)
        n = len(params)
        params, m, v = list(out[:n]), list(out[n:2*n]), list(out[2*n:3*n])
        step, losses = out[3 * n], out[3 * n + 1]
        if first is None:
            first = float(losses[0])
        last = float(losses[-1])
    assert last < first, (first, last)

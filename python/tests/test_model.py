"""L2 correctness: model shapes, training dynamics, scoring semantics,
dense-vs-dyad structural parity."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import configs, model, mnist
from compile.configs import ArchConfig, VARIANTS

TINY = ArchConfig("tiny", vocab=64, d_model=32, d_ff=64, n_layers=2,
                  n_heads=4, seq=16)
TINY_PAR = ArchConfig("tiny-par", vocab=64, d_model=32, d_ff=64, n_layers=2,
                      n_heads=4, seq=16, parallel_residual=True)


def _toks(rng, b, s, vocab=64):
    return jnp.asarray(rng.integers(1, vocab, size=(b, s)), dtype=jnp.int32)


@pytest.mark.parametrize("vname", ["dense", "dyad_it", "dyad_ot", "dyad_dt",
                                   "dyad_it_8"])
@pytest.mark.parametrize("arch", [TINY, TINY_PAR])
def test_forward_shapes(arch, vname):
    var = VARIANTS[vname]
    params = model.init_params(arch, var, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = _toks(rng, 3, arch.seq)
    logits = model.logits_fn(params, toks, arch, var)
    assert logits.shape == (3, arch.seq, arch.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_count_reduction():
    """DYAD model must have fewer parameters than DENSE; ff params drop
    by 2/n_dyad (paper Table 11 / 'Non-Embedding Parameters')."""
    dense_n = sum(
        int(np.prod(s)) for _, s, _ in model.param_specs(TINY, VARIANTS["dense"])
    )
    dyad_n = sum(
        int(np.prod(s)) for _, s, _ in model.param_specs(TINY, VARIANTS["dyad_it"])
    )
    dyad8_n = sum(
        int(np.prod(s)) for _, s, _ in model.param_specs(TINY, VARIANTS["dyad_it_8"])
    )
    assert dyad_n < dense_n
    assert dyad8_n < dyad_n
    # exact accounting: each ff matmul w (f_out*f_in) -> 2*f_out*f_in/n_dyad
    ff_w_dense = 2 * TINY.n_layers * TINY.d_model * TINY.d_ff
    expected_drop = ff_w_dense - 2 * ff_w_dense // 4
    assert dense_n - dyad_n == expected_drop


def test_causality():
    """Changing a future token must not affect past logits."""
    var = VARIANTS["dyad_it"]
    params = model.init_params(TINY, var, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = _toks(rng, 1, TINY.seq)
    l1 = model.logits_fn(params, toks, TINY, var)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % 63 + 1)
    l2 = model.logits_fn(params, toks2, TINY, var)
    np.testing.assert_allclose(
        np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), rtol=1e-5, atol=1e-6
    )
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


@pytest.mark.parametrize("vname", ["dense", "dyad_it"])
def test_train_step_decreases_loss(vname):
    """A few steps on a repeated batch must overfit (loss strictly drops)."""
    var = VARIANTS[vname]
    params = model.init_params(TINY, var, jax.random.PRNGKey(2))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step_fn = jax.jit(model.make_train_step(TINY, var, 4, 2))
    rng = np.random.default_rng(2)
    toks = _toks(rng, 2, TINY.seq)
    tokens = jnp.broadcast_to(toks, (4, 2, TINY.seq))
    step = jnp.float32(0.0)
    first = last = None
    for it in range(3):
        out = step_fn(*params, *m, *v, step, jnp.float32(1e-3), tokens)
        n = len(params)
        params, m, v = list(out[:n]), list(out[n:2*n]), list(out[2*n:3*n])
        step, losses = out[3 * n], out[3 * n + 1]
        if first is None:
            first = float(losses[0])
        last = float(losses[-1])
    assert last < first - 0.05, (first, last)
    assert float(step) == 12.0


def test_score_semantics():
    """score must equal a hand-rolled log-softmax walk, and masking must
    exclude positions."""
    var = VARIANTS["dense"]
    params = model.init_params(TINY, var, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    toks = _toks(rng, 2, TINY.seq)
    mask = jnp.ones((2, TINY.seq), jnp.float32)
    score = model.make_score(TINY, var)
    s, n = score(*params, toks, mask)
    assert s.shape == (2,) and n.shape == (2,)
    assert float(n[0]) == TINY.seq - 1
    logits = model.logits_fn(params, toks, TINY, var)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    want = sum(
        float(logp[0, t, int(toks[0, t + 1])]) for t in range(TINY.seq - 1)
    )
    assert abs(float(s[0]) - want) < 1e-3
    # masking out the second half must change the sum and the count
    mask2 = mask.at[:, TINY.seq // 2 :].set(0.0)
    s2, n2 = score(*params, toks, mask2)
    assert float(n2[0]) == TINY.seq // 2 - 1
    assert float(s2[0]) > float(s[0])  # fewer (negative) terms


def test_features_masked_pooling():
    var = VARIANTS["dyad_it"]
    params = model.init_params(TINY, var, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    toks = _toks(rng, 2, TINY.seq)
    feat = model.make_features(TINY, var)
    mask = jnp.ones((2, TINY.seq), jnp.float32)
    f_full = feat(*params, toks, mask)
    assert f_full.shape == (2, TINY.d_model)
    # pooling over only the first token == that token's hidden state
    mask1 = jnp.zeros_like(mask).at[:, 0].set(1.0)
    f1 = feat(*params, toks, mask1)
    h = model.hidden_states(params, toks, TINY, var)
    np.testing.assert_allclose(
        np.asarray(f1), np.asarray(h[:, 0, :]), rtol=1e-4, atol=1e-5
    )


def test_next_logits_matches_position():
    var = VARIANTS["dense"]
    params = model.init_params(TINY, var, jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    toks = _toks(rng, 2, TINY.seq)
    nl = model.make_next_logits(TINY, var)
    lengths = jnp.asarray([4, TINY.seq], jnp.int32)
    out = nl(*params, toks, lengths)
    logits = model.logits_fn(params, toks, TINY, var)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(logits[0, 3]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray(logits[1, TINY.seq - 1]), rtol=1e-5)


def test_ff_micro_matches_model_ff():
    """The ff-micro artifact fns must compute the same ff module used
    inside the transformer."""
    var = VARIANTS["dyad_it"]
    d, ff, t = 32, 64, 8
    specs = model.ff_param_specs(d, ff, var)
    rng = np.random.default_rng(6)
    params = [jnp.asarray(rng.standard_normal(s) * 0.05, jnp.float32)
              for _, s, _ in specs]
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    (y,) = model.make_ff_fwd(d, ff, var)(*params, x)
    # ff_module uses f"{prefix}.fc1" names; replicate with prefix ""
    p2 = {"." + n: a for (n, _, _), a in zip(specs, params)}
    want = model.ff_module(p2, "", x, var)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_ff_fwdbwd_grad_check():
    """ff_fwdbwd grads vs numerical finite differences on one weight."""
    var = VARIANTS["dyad_it"]
    d, ff, t = 16, 32, 4
    specs = model.ff_param_specs(d, ff, var)
    rng = np.random.default_rng(7)
    params = [jnp.asarray(rng.standard_normal(s) * 0.1, jnp.float32)
              for _, s, _ in specs]
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    ct = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    fb = model.make_ff_fwdbwd(d, ff, var)
    out = fb(*params, x, ct)
    loss, grads = out[0], out[1:]
    eps = 1e-3
    p0 = params[0]
    bumped = params.copy()
    bumped[0] = p0.at[0, 0, 0].add(eps)
    loss_b = fb(*bumped, x, ct)[0]
    fd = (float(loss_b) - float(loss)) / eps
    assert abs(fd - float(grads[0][0, 0, 0])) < 5e-2 * max(1.0, abs(fd))


@pytest.mark.parametrize("vname", ["dense", "dyad_it"])
def test_mnist_train_and_accuracy(vname):
    var = VARIANTS[vname]
    specs = mnist.mnist_param_specs(var)
    key = jax.random.PRNGKey(8)
    params = []
    for _, s, init in specs:
        key, sub = jax.random.split(key)
        if init["kind"] == "uniform":
            params.append(jax.random.uniform(sub, s, jnp.float32,
                                             -init["bound"], init["bound"]))
        else:
            params.append(jnp.zeros(s, jnp.float32))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(8)
    B, K = 16, 2
    # two linearly separable blobs -> must be learnable fast
    x = np.zeros((K, B, 784), np.float32)
    y = np.zeros((K, B), np.int32)
    for k in range(K):
        for i in range(B):
            cls = i % 2
            y[k, i] = cls
            x[k, i] = rng.normal(loc=2.0 * cls - 1.0, scale=0.3, size=784)
    step_fn = jax.jit(mnist.make_mnist_train_step(var, K, B))
    step = jnp.float32(0)
    losses0 = None
    for it in range(10):
        out = step_fn(*params, *m, *v, step, jnp.float32(1e-3),
                      jnp.asarray(x), jnp.asarray(y))
        n = len(params)
        params, m, v = list(out[:n]), list(out[n:2*n]), list(out[2*n:3*n])
        step, losses = out[3 * n], out[3 * n + 1]
        if losses0 is None:
            losses0 = float(losses[0])
    assert float(losses[-1]) < losses0
    acc_fn = mnist.make_mnist_accuracy(var, B)
    (correct,) = acc_fn(*params, jnp.asarray(x[0]), jnp.asarray(y[0]))
    assert int(correct) >= B * 3 // 4


def test_param_specs_deterministic_order():
    """The manifest contract: spec order must be stable across calls."""
    a = [n for n, _, _ in model.param_specs(TINY, VARIANTS["dyad_it"])]
    b = [n for n, _, _ in model.param_specs(TINY, VARIANTS["dyad_it"])]
    assert a == b
    assert a[0] == "tok_emb" and a[-1] == "final_ln.bias"
    assert len(a) == len(set(a)), "duplicate param names"

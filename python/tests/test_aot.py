"""AOT emitter: manifest schema, lowering validity, contract
consistency between param_specs and emitted inputs/outputs."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model
from compile.configs import ARCHS, VARIANTS


def test_to_hlo_text_produces_parseable_module():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(aot.sds((2, 2)))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # must be plain HLO text, not a serialized proto blob
    assert text.isprintable() or "\n" in text


def test_emitter_writes_files_and_entries():
    with tempfile.TemporaryDirectory() as d:
        em = aot.Emitter(d)

        def fn(x):
            return (x + 1.0,)

        em.emit(
            "unit/test",
            fn,
            [("x", (2, 3), aot.F32, "data", None)],
            [("y", (2, 3), aot.F32)],
            "test_kind",
            {"foo": 7},
        )
        assert os.path.exists(os.path.join(d, "unit_test.hlo.txt"))
        assert len(em.entries) == 1
        e = em.entries[0]
        assert e["kind"] == "test_kind"
        assert e["inputs"][0]["dtype"] == "f32"
        assert e["meta"]["foo"] == 7


def test_emitter_only_filter_skips_lowering_but_keeps_entry():
    with tempfile.TemporaryDirectory() as d:
        em = aot.Emitter(d, only="nomatch-xyz")

        def fn(x):
            return (x,)

        em.emit(
            "skipped/one",
            fn,
            [("x", (1,), aot.F32, "data", None)],
            [("y", (1,), aot.F32)],
            "k",
        )
        assert not os.listdir(d)
        assert len(em.entries) == 1  # manifest entry still recorded


@pytest.mark.parametrize("arch_name", ["opt-mini"])
@pytest.mark.parametrize("vname", ["dense", "dyad_it"])
def test_train_step_contract_matches_param_specs(arch_name, vname):
    """The manifest input list must be params ++ m ++ v ++ step ++ lr ++
    tokens and outputs params ++ m ++ v ++ step ++ losses, in spec order
    — the rust TrainState relies on exactly this."""
    arch, var = ARCHS[arch_name], VARIANTS[vname]
    specs = model.param_specs(arch, var)
    n = len(specs)
    params_in = aot.model_param_inputs(arch, var)
    opt_in = aot.opt_state_inputs(arch, var)
    assert len(params_in) == n
    assert len(opt_in) == 2 * n
    assert [p[0] for p in params_in] == [s for s, _, _ in specs]
    assert opt_in[0][0] == "m." + specs[0][0]
    assert opt_in[n][0] == "v." + specs[0][0]
    # every param has an init, every opt state is zero-init
    assert all(p[4] is not None for p in params_in)
    assert all(o[4] == {"kind": "zeros"} for o in opt_in)


def test_manifest_json_is_loadable_and_complete():
    """If artifacts/ has been built, its manifest must satisfy the
    contract the rust parser expects."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    m = json.load(open(path))
    assert m["version"] == 1
    assert set(m["adam"]) == {"b1", "b2", "eps", "grad_clip"}
    for name in ("opt-mini", "pythia-mini", "opt-mid"):
        assert name in m["archs"]
    names = [a["name"] for a in m["artifacts"]]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in m["artifacts"]:
        for io in a["inputs"]:
            assert io["role"] in {"param", "opt_m", "opt_v", "scalar", "data"}
            assert all(isinstance(d, int) and d >= 0 for d in io["shape"])
            if io["role"] == "param":
                assert "init" in io, f"{a['name']}: param {io['name']} missing init"
        # train artifacts: outputs mirror state inputs + step + losses
        if a["kind"] in ("train_step", "mnist_train"):
            n_state = sum(
                1 for io in a["inputs"] if io["role"] in ("param", "opt_m", "opt_v")
            )
            assert len(a["outputs"]) == n_state + 2, a["name"]


def test_vocab_fits_all_archs():
    """Model vocab must hold the rust tokenizer's vocabulary (~150)."""
    for arch in ARCHS.values():
        assert arch.vocab >= 256


def test_ff_geometries_divisible_by_n_dyad():
    for d, ff, _ in configs.FF_GEOMETRIES.values():
        for v in VARIANTS.values():
            if v.kind == "dyad":
                assert d % v.n_dyad == 0
                assert ff % v.n_dyad == 0
    for w in configs.WIDTH_SWEEP:
        assert w % 8 == 0

//! `repro` — the DYAD reproduction coordinator CLI.
//!
//! Subcommands:
//!   train            pretrain one (arch, variant) on nanoBabyLM
//!   quality          pretrain + full benchmark suite (Tables 2/3/6-8/12)
//!   eval             run the benchmark suite on an existing checkpoint
//!   serve            batched-inference demo server (scoring/generation)
//!   mnist            the §3.4.5 MNIST probe (dense vs dyad)
//!   data-gen         dump a nanoBabyLM corpus / minimal pairs to stdout
//!   inspect          connectivity analysis (Eq 17/18) + artifact info
//!   list-artifacts   show the manifest inventory
//!
//! Every command — including `train` and `quality` — takes
//! `--backend native|xla` (default native — pure Rust, no artifacts
//! needed, training included; xla needs the `xla` cargo feature and a
//! `make artifacts` directory). Paper-scale names alias onto the mini
//! reproductions (`--arch opt125m --variant dyad` = opt-mini/dyad_it).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use dyad_repro::config::TrainConfig;
use dyad_repro::coordinator::{MetricsLogger, Trainer};
use dyad_repro::data::{Grammar, Tokenizer};
use dyad_repro::dyad::{connectivity_ratio, DyadDims, Variant};
use dyad_repro::eval;
use dyad_repro::runtime::{open_backend_with_precision, Backend, BackendKind};
use dyad_repro::tensor::Precision;
use dyad_repro::util::cli::Args;
use dyad_repro::util::json::{num, s};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "quality" => cmd_quality(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "mnist" => cmd_mnist(&args),
        "data-gen" => cmd_data_gen(&args),
        "inspect" => cmd_inspect(&args),
        "list-artifacts" => cmd_list(&args),
        "quality-summary" => cmd_quality_summary(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "repro — DYAD reproduction coordinator\n\n\
         USAGE: repro <command> [--flag value]...\n\n\
         COMMANDS:\n\
           train          --arch A --variant V --steps N --lr F --out DIR\n\
           quality        --arch A [--variants v1,v2] --steps N --out DIR\n\
           eval           --arch A --variant V --ckpt DIR [--pairs N]\n\
           serve          --arch A --variant V [--workers N] [--dispatch P]\n\
                          [--ckpt DIR] [--requests N]   (P: round-robin|least-pending)\n\
                          [--threads-per-worker T]  pool size per shard\n\
                          (default: machine threads / workers, min 1)\n\
                          [--fleet N [--listen ADDR]]  N shard *processes*\n\
                          behind a TCP front-end (wire protocol: serve::net)\n\
                          [--weights F | --write-weights F]  serve from a\n\
                          shared read-only DYW1 weight map (mmap, ~1x\n\
                          resident bytes across a fleet)\n\
           mnist          [--steps N] [--variant dense|dyad_it]\n\
           data-gen       [--tokens N | --pairs N] [--seed S]\n\
           inspect        [--n-dyad N] [--n-in N] | --artifact NAME\n\
           list-artifacts [--kind K]\n\
           quality-summary --dir runs/quality-opt   (render Table-2 style)\n\n\
         Common flags:\n\
           --backend native|xla   execution backend (default: native; trains too)\n\
           --precision f32|bf16|i8  weight-stream precision for the swap-site\n\
                          linears (native only; default f32; dw stays f32)\n\
           --artifacts DIR        artifact dir for --backend xla (default: artifacts)\n\
           --arch/--variant also accept paper-scale aliases\n\
           (opt125m/opt350m/pythia160m -> mini configs, dyad -> dyad_it)"
    );
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    args.str_or("backend", "native").parse::<BackendKind>()
}

fn precision_of(args: &Args) -> Result<Precision> {
    Precision::from_str(&args.str_or("precision", "f32"))
}

fn backend_of(args: &Args) -> Result<Box<dyn Backend>> {
    open_backend_with_precision(
        backend_kind(args)?,
        std::path::Path::new(&args.str_or("artifacts", "artifacts")),
        precision_of(args)?,
    )
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let backend =
        open_backend_with_precision(backend_kind(args)?, &cfg.artifacts_dir, precision_of(args)?)?;
    let mut log = MetricsLogger::to_dir(&cfg.out_dir)?;
    std::fs::write(cfg.out_dir.join("config.json"), cfg.to_json().to_string())?;
    let report = Trainer::new(cfg).run(backend.as_ref(), &mut log)?;
    println!(
        "train done: steps={} first_loss={:.4} final_loss={:.4} valid={:.4} \
         ({:.0} ms/call)",
        report.steps,
        report.first_loss,
        report.final_loss,
        report.valid_loss,
        report.ms_per_call.mean
    );
    Ok(())
}

/// Pretrain + evaluate one or more variants; writes per-variant quality
/// reports (the Table 2/3 pipeline).
fn cmd_quality(args: &Args) -> Result<()> {
    let arch = args.str_or("arch", "opt-mini");
    let variants: Vec<String> = args
        .str_or("variants", "dense,dyad_it")
        .split(',')
        .map(|v| v.trim().to_string())
        .collect();
    let out_root = PathBuf::from(args.str_or("out", "runs/quality"));
    for variant in &variants {
        let mut sub = Args::parse(Vec::new())?;
        sub.flags = args.flags.clone();
        sub.flags.insert("arch".into(), arch.clone());
        sub.flags.insert("variant".into(), variant.clone());
        sub.flags.insert(
            "out".into(),
            out_root.join(variant).to_string_lossy().into_owned(),
        );
        let cfg = TrainConfig::from_args(&sub)?;
        let backend = open_backend_with_precision(
            backend_kind(args)?,
            &cfg.artifacts_dir,
            precision_of(args)?,
        )?;
        let mut log = MetricsLogger::to_dir(&cfg.out_dir)?;
        std::fs::write(cfg.out_dir.join("config.json"), cfg.to_json().to_string())?;
        println!("== pretraining {arch}/{variant} ==");
        let out_dir = cfg.out_dir.clone();
        let report = Trainer::new(cfg.clone()).run(backend.as_ref(), &mut log)?;
        let quality = run_suite(backend.as_ref(), &cfg, &report, args)?;
        quality.save(&out_dir.join("quality.json"))?;
        println!("{}", quality.render_table());
    }
    Ok(())
}

fn run_suite(
    backend: &dyn Backend,
    cfg: &TrainConfig,
    report: &dyad_repro::coordinator::TrainReport,
    args: &Args,
) -> Result<eval::QualityReport> {
    let grammar = Grammar::new();
    let tokenizer = Tokenizer::from_words(&grammar.vocabulary());
    let ckpt =
        dyad_repro::coordinator::checkpoint::CheckpointManager::new(&cfg.out_dir);
    let train_spec = backend
        .manifest()
        .artifact(&cfg.train_artifact(8))
        .or_else(|_| backend.manifest().artifact(&cfg.train_artifact(1)))?
        .clone();
    let state = ckpt.load_state(backend, &train_spec)?;
    let score_art = backend.load(&cfg.artifact("score"))?;
    let feats_art = backend.load(&cfg.artifact("features"))?;
    let pairs = args.usize_or("pairs", 50)?;
    let mcq_items = args.usize_or("mcq-items", 25)?;
    let shots = args.usize_or("shots", 3)?;
    let probe_train = args.usize_or("probe-train", 128)?;
    let probe_test = args.usize_or("probe-test", 64)?;
    let blimp = eval::blimp::evaluate(
        backend, score_art.as_ref(), &state, &tokenizer, pairs, cfg.seed,
    )?;
    let mcq = eval::mcq::evaluate(
        backend, score_art.as_ref(), &state, &tokenizer, mcq_items, shots, cfg.seed,
    )?;
    let probe = eval::probe::evaluate(
        backend, feats_art.as_ref(), &state, &tokenizer, probe_train, probe_test, cfg.seed,
    )?;
    Ok(eval::QualityReport {
        arch: cfg.arch.clone(),
        variant: cfg.variant.clone(),
        blimp,
        mcq,
        probe,
        valid_loss: report.valid_loss,
        final_train_loss: report.final_loss,
        params: report.params,
        checkpoint_bytes: report.checkpoint_bytes,
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    use dyad_repro::runtime::TrainState;
    let cfg = TrainConfig::from_args(args)?;
    let backend =
        open_backend_with_precision(backend_kind(args)?, &cfg.artifacts_dir, precision_of(args)?)?;
    let grammar = Grammar::new();
    let tokenizer = Tokenizer::from_words(&grammar.vocabulary());
    let train_spec = backend
        .manifest()
        .artifact(&cfg.train_artifact(8))
        .or_else(|_| backend.manifest().artifact(&cfg.train_artifact(1)))?
        .clone();
    let state = match args.str_opt("ckpt") {
        Some(dir) => {
            let ckpt_dir = PathBuf::from(dir);
            let mgr =
                dyad_repro::coordinator::checkpoint::CheckpointManager::new(&ckpt_dir);
            if !mgr.has_state() {
                bail!("no checkpoint in {}", ckpt_dir.display());
            }
            mgr.load_state(backend.as_ref(), &train_spec)?
        }
        None => {
            eprintln!(
                "note: no --ckpt given; evaluating freshly initialised \
                 (untrained) parameters"
            );
            TrainState::init(backend.as_ref(), &train_spec, cfg.seed)?
        }
    };
    let score_art = backend.load(&cfg.artifact("score"))?;
    let pairs = args.usize_or("pairs", 50)?;
    let blimp = eval::blimp::evaluate(
        backend.as_ref(), score_art.as_ref(), &state, &tokenizer, pairs, cfg.seed,
    )?;
    println!("BLIMP mean = {:.4}", blimp.mean);
    for (name, acc, n) in &blimp.per_phenomenon {
        println!("  {name:<24} {acc:.4}  (n={n})");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use dyad_repro::serve::{run_shard, DispatchPolicy, Request, Router, ServeConfig, ServeStats};
    use dyad_repro::runtime::catalog::{canonical_arch, canonical_variant};
    let mut cfg = ServeConfig {
        backend: backend_kind(args)?,
        artifacts_dir: args.str_or("artifacts", "artifacts").into(),
        arch: canonical_arch(&args.str_or("arch", "opt-mini")).to_string(),
        variant: canonical_variant(&args.str_or("variant", "dyad_it")).to_string(),
        checkpoint_dir: args.str_opt("ckpt").map(PathBuf::from),
        max_batch: args.usize_or("max-batch", 8)?,
        window_ms: args.u64_or("window-ms", 5)?,
        seed: args.u64_or("seed", 7)?,
        n_workers: args.usize_or("workers", 1)?,
        dispatch: args.str_or("dispatch", "round-robin").parse::<DispatchPolicy>()?,
        // default None: each worker gets num_threads()/n_workers (min 1)
        threads_per_worker: args
            .str_opt("threads-per-worker")
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("--threads-per-worker={v}: {e}"))
            })
            .transpose()?,
        // parity oracle: full-context recompute instead of the
        // KV-cache decode session
        legacy_generate: args.switch("legacy-generate"),
        // serve from a shared read-only DYW1 weight map instead of
        // initialising per-process heap copies
        weights_file: args.str_opt("weights").map(PathBuf::from),
    };
    if let Some(out) = args.str_opt("write-weights") {
        use dyad_repro::runtime::open_backend_sized;
        let backend = open_backend_sized(cfg.backend, &cfg.artifacts_dir, Precision::F32, 1)?;
        let spec = backend
            .manifest()
            .artifact(&format!("{}/{}/train_k1", cfg.arch, cfg.variant))?
            .clone();
        let path = PathBuf::from(out);
        dyad_repro::runtime::catalog::mmap::write_init(&path, &spec, cfg.seed)?;
        println!(
            "wrote DYW1 weight map for {}/{} (seed {}) to {}",
            cfg.arch,
            cfg.variant,
            cfg.seed,
            path.display()
        );
        cfg.weights_file = Some(path);
    }
    // hidden child mode: one shard process of a fleet (spawned by
    // Fleet::start, or by hand for debugging). Binds the given
    // address, prints `SHARD_READY <addr>`, serves the wire protocol.
    if args.switch("shard") {
        let listen = args.str_or("listen", "127.0.0.1:0");
        return run_shard(cfg, &listen);
    }
    let n = args.usize_or("requests", 64)?;
    let fleet_n = args.usize_or("fleet", 0)?;
    if fleet_n > 0 {
        return serve_fleet(cfg, fleet_n, n, args.str_opt("listen"));
    }
    println!(
        "starting {} worker(s) ({}/{}) on {} backend, {} dispatch ...",
        cfg.n_workers.max(1),
        cfg.arch,
        cfg.variant,
        cfg.backend.name(),
        cfg.dispatch.name()
    );
    let router = Router::start(cfg);
    let sentences = dyad_repro::data::sample_sentences(n, 1);
    // client fan-out rides the resident worker pool (one lane per
    // chunk) instead of ad-hoc std::thread::scope spawns
    let chunks: Vec<&[Vec<i32>]> = sentences.chunks(n.div_ceil(4).max(1)).collect();
    let pool = dyad_repro::runtime::pool::sized(chunks.len());
    pool.run(chunks.len(), &|t| {
        let srv = router.sender();
        for toks in chunks[t] {
            let (rtx, rrx) = std::sync::mpsc::channel();
            let _ = srv.send(Request::Score { tokens: toks.clone(), resp: rtx.into() });
            let _ = rrx.recv();
        }
    });
    let stats = router.stats()?;
    println!("{}", stats.render());
    println!("{}", ServeStats::render_workers(&router.worker_stats()));
    router.shutdown()?;
    Ok(())
}

/// `serve --fleet N`: spawn N shard *processes* (this same binary in
/// `--shard` child mode) behind the process-level front-end. With
/// `--listen ADDR` the front-end also serves the wire protocol over
/// TCP — smoke traffic then runs through a real network client, so the
/// whole path (client → TCP → dispatcher → shard process → back) is
/// exercised; with `--requests 0` it just serves until a client sends
/// Shutdown.
fn serve_fleet(
    cfg: dyad_repro::serve::ServeConfig,
    n_shards: usize,
    n_requests: usize,
    listen: Option<&str>,
) -> Result<()> {
    use dyad_repro::serve::{Fleet, FleetConfig, NetClient, ServeStats};
    fn render_fleet(stats: &ServeStats) -> String {
        format!(
            "{}\nfleet resident weight bytes: {} (heap {} + mapped/shared {})",
            stats.render(),
            stats.weight_resident_bytes(),
            stats.weight_heap_bytes,
            stats.weight_mapped_bytes
        )
    }
    let bin = std::env::current_exe().context("locate repro binary to spawn shards")?;
    println!(
        "starting {n_shards} shard process(es) ({}/{}), {} dispatch ...",
        cfg.arch,
        cfg.variant,
        cfg.dispatch.name()
    );
    let fleet = Fleet::start(FleetConfig::new(cfg, n_shards, bin))?;
    let Some(listen) = listen else {
        let sentences = dyad_repro::data::sample_sentences(n_requests, 1);
        for toks in &sentences {
            fleet.score(toks.clone())?;
        }
        println!("{}", render_fleet(&fleet.stats()?));
        return fleet.shutdown();
    };
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("bind fleet front-end on {listen}"))?;
    let addr = listener.local_addr()?;
    println!("fleet front-end listening on {addr}");
    let demo = if n_requests > 0 {
        // xtask:allow(thread_spawn): CLI smoke client driving the TCP
        // front-end, not kernel parallelism.
        Some(std::thread::spawn(move || -> Result<()> {
            let mut client = NetClient::connect(&addr.to_string())?;
            let sentences = dyad_repro::data::sample_sentences(n_requests, 1);
            for toks in &sentences {
                client.score(toks.clone())?;
            }
            println!("{}", render_fleet(&client.stats()?));
            client.shutdown()
        }))
    } else {
        None
    };
    fleet.serve_net(listener)?;
    if let Some(j) = demo {
        j.join().map_err(|_| anyhow::anyhow!("fleet smoke client panicked"))??;
    }
    fleet.shutdown()
}

fn cmd_mnist(args: &Args) -> Result<()> {
    let backend = backend_of(args)?;
    eval::mnist_probe::run(
        backend.as_ref(),
        args.usize_or("steps", 200)?,
        args.str_opt("variant"),
        args.u64_or("seed", 5)?,
    )
}

fn cmd_data_gen(args: &Args) -> Result<()> {
    let grammar = Grammar::new();
    let seed = args.u64_or("seed", 0)?;
    if let Some(p) = args.str_opt("pairs") {
        let n: usize = p.parse()?;
        let mut rng = dyad_repro::util::rng::Rng::new(seed);
        for ph in dyad_repro::data::Phenomenon::ALL {
            for _ in 0..n {
                let pair = grammar.minimal_pair(ph, &mut rng);
                println!(
                    "{}\t{}\t{}",
                    ph.name(),
                    pair.good.join(" "),
                    pair.bad.join(" ")
                );
            }
        }
        return Ok(());
    }
    let tokens = args.usize_or("tokens", 1000)?;
    let words = grammar.corpus(tokens, seed);
    let mut line = Vec::new();
    for w in words {
        let end = w == "." || w == "?";
        line.push(w);
        if end {
            println!("{}", line.join(" "));
            line.clear();
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if let Some(name) = args.str_opt("artifact") {
        let backend = backend_of(args)?;
        let spec = backend.manifest().artifact(name)?;
        println!("artifact {name}");
        println!("  kind    {}", spec.kind);
        println!("  file    {}", spec.file);
        println!(
            "  params  {} tensors / {} values",
            spec.param_specs().len(),
            spec.param_count()
        );
        println!("  inputs  {}", spec.inputs.len());
        for io in &spec.inputs {
            println!(
                "    {:<28} {:?} {:?} {:?}",
                io.name, io.shape, io.dtype, io.role
            );
        }
        println!("  outputs {}", spec.outputs.len());
        for io in spec.outputs.iter().take(8) {
            println!("    {:<28} {:?} {:?}", io.name, io.shape, io.dtype);
        }
        if spec.outputs.len() > 8 {
            println!("    ... ({} more)", spec.outputs.len() - 8);
        }
        return Ok(());
    }
    let n_dyad = args.usize_or("n-dyad", 4)?;
    let n_in = args.usize_or("n-in", 16)?;
    let dims = DyadDims { n_dyad, n_in, n_out: n_in };
    println!("connectivity analysis (paper Eq 17/18), n_dyad={n_dyad} n_in={n_in}:");
    for (label, v) in [("IT", Variant::It), ("OT", Variant::Ot), ("DT", Variant::Dt)] {
        let (rw, rc) = connectivity_ratio(dims, v);
        println!(
            "  DYAD-{label}: dense/dyad connection ratio within-block={rw:.2} \
             (paper: O(n_dyad)={n_dyad}), cross-block={rc:.2} \
             (paper: O(n_dyad^2)={})",
            n_dyad * n_dyad
        );
    }
    Ok(())
}

/// Render the paper's Table-2-shaped cross-variant comparison from a
/// `repro quality` output directory (one subdir per variant).
fn cmd_quality_summary(args: &Args) -> Result<()> {
    use dyad_repro::util::json::Json;
    let dir = PathBuf::from(args.str_or("dir", "runs/quality-opt"));
    let mut rows: Vec<(String, Json)> = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .with_context(|| format!("read {}", dir.display()))?
    {
        let path = entry?.path().join("quality.json");
        if path.exists() {
            let j = Json::parse(&std::fs::read_to_string(&path)?)?;
            rows.push((j.req("variant")?.as_str()?.to_string(), j));
        }
    }
    if rows.is_empty() {
        bail!("no quality.json files under {}", dir.display());
    }
    // dense first, then the dyad variants in a stable order
    rows.sort_by_key(|(v, _)| (v != "dense", v.clone()));
    println!(
        "{:<12} {:>8} {:>8} {:>8} {:>10} {:>10} {:>12}",
        "variant", "BLIMP", "MCQ", "probe", "valid", "params", "ckpt(KB)"
    );
    let dense_scores = rows.iter().find(|(v, _)| v == "dense").map(|(_, j)| {
        (
            j.get("blimp_mean").and_then(|x| x.as_f64().ok()).unwrap_or(f64::NAN),
            j.get("mcq_mean").and_then(|x| x.as_f64().ok()).unwrap_or(f64::NAN),
            j.get("probe_mean").and_then(|x| x.as_f64().ok()).unwrap_or(f64::NAN),
        )
    });
    for (v, j) in &rows {
        let blimp = j.req("blimp_mean")?.as_f64()?;
        let mcq = j.req("mcq_mean")?.as_f64()?;
        let probe = j.req("probe_mean")?.as_f64()?;
        println!(
            "{:<12} {:>8.4} {:>8.4} {:>8.4} {:>10.4} {:>10} {:>12.1}",
            v,
            blimp,
            mcq,
            probe,
            j.req("valid_loss")?.as_f64()?,
            j.req("params")?.as_usize()?,
            j.req("checkpoint_bytes")?.as_f64()? / 1024.0
        );
    }
    if let Some((db, dm, dp)) = dense_scores {
        println!("\npaper T2 bar: every DYAD variant >= 0.95x DENSE?");
        for (v, j) in &rows {
            if v == "dense" {
                continue;
            }
            let r = [
                j.req("blimp_mean")?.as_f64()? / db,
                j.req("mcq_mean")?.as_f64()? / dm,
                j.req("probe_mean")?.as_f64()? / dp,
            ];
            let min = r.iter().cloned().fold(f64::MAX, f64::min);
            println!(
                "  {v:<12} min ratio {min:.3}  {}",
                if min >= 0.95 { "PASS" } else { "below bar" }
            );
        }
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let backend = backend_of(args)?;
    let filter = args.str_opt("kind");
    for a in &backend.manifest().artifacts {
        if filter.map(|k| a.kind == k).unwrap_or(true) {
            println!(
                "{}",
                dyad_repro::util::json::obj(vec![
                    ("name", s(&a.name)),
                    ("kind", s(&a.kind)),
                    ("params", num(a.param_count() as f64)),
                    ("inputs", num(a.inputs.len() as f64)),
                ])
                .to_string()
            );
        }
    }
    Ok(())
}

//! Paper-table workloads: ff-module timing rows (Tables 1/5/10,
//! Figures 6/7, the -CAT ablation) in the paper's exact row format.

use anyhow::Result;

use super::harness::{bench_artifact, write_bench_json, BenchOpts};
use crate::runtime::Backend;
use crate::util::json::{num, obj, s, Json};

/// One row of a paper timing table.
#[derive(Debug, Clone)]
pub struct FfTiming {
    pub variant: String,
    pub fwd_ms: f64,
    pub bwd_ms: f64,
    pub total_ms: f64,
}

/// Time the ff module of `geometry` under `variant`: forward from the
/// `fwd` artifact, total from `fwdbwd`, backward = total - forward
/// (the paper reports all three).
pub fn ff_timing(
    backend: &dyn Backend,
    geometry: &str,
    variant: &str,
    opts: BenchOpts,
) -> Result<FfTiming> {
    let fwd = bench_artifact(backend, &format!("ff/{geometry}/{variant}/fwd"), opts)?;
    let fb = bench_artifact(backend, &format!("ff/{geometry}/{variant}/fwdbwd"), opts)?;
    let total = fb.mean;
    Ok(FfTiming {
        variant: variant.to_string(),
        fwd_ms: fwd.mean,
        bwd_ms: (total - fwd.mean).max(0.0),
        total_ms: total,
    })
}

/// Full table: every variant against the DENSE baseline.
pub fn ff_table(
    backend: &dyn Backend,
    geometry: &str,
    variants: &[&str],
    opts: BenchOpts,
) -> Result<Vec<FfTiming>> {
    variants
        .iter()
        .map(|v| ff_timing(backend, geometry, v, opts))
        .collect()
}

/// Print in the paper's Table-1 format + one JSON line per row, and
/// persist the whole table as `BENCH_native_ff.json` (the ff-module
/// perf-trajectory file; the last table bench run wins).
pub fn print_ff_table(title: &str, rows: &[FfTiming]) {
    println!("\n== {title} ==");
    println!(
        "{:<14} {:>12} {:>13} {:>10} {:>20}",
        "Model", "Forward(ms)", "Backward(ms)", "Total(ms)", "Total speedup ratio"
    );
    let dense_total = rows
        .iter()
        .find(|r| r.variant == "dense")
        .map(|r| r.total_ms)
        .unwrap_or(f64::NAN);
    let mut json_rows = Vec::with_capacity(rows.len());
    for r in rows {
        let speedup = dense_total / r.total_ms;
        println!(
            "{:<14} {:>12.3} {:>13.3} {:>10.3} {:>20.3}",
            r.variant, r.fwd_ms, r.bwd_ms, r.total_ms, speedup
        );
        let row = obj(vec![
            ("table", s(title)),
            ("variant", s(&r.variant)),
            ("fwd_ms", num(r.fwd_ms)),
            ("bwd_ms", num(r.bwd_ms)),
            ("total_ms", num(r.total_ms)),
            ("speedup", num(speedup)),
        ]);
        println!("{}", row.to_string());
        json_rows.push(row);
    }
    let doc = obj(vec![
        ("bench", s("ff_table")),
        ("table", s(title)),
        ("rows", Json::Arr(json_rows)),
    ]);
    match write_bench_json("native_ff", &doc) {
        Ok(path) => println!("bench json: {}", path.display()),
        Err(e) => eprintln!("warning: could not write BENCH_native_ff.json: {e:#}"),
    }
}

//! Benchmark harness (criterion is unavailable offline; DESIGN.md §6).
//!
//! Each `rust/benches/*.rs` binary regenerates one paper table/figure:
//! it loads the relevant AOT artifacts, times them with warmup +
//! repeated measurement, and prints rows in the paper's format plus a
//! machine-readable JSON line per row.

pub mod harness;
pub mod workloads;

pub use harness::{bench_artifact, synth_input, BenchOpts};
pub use workloads::{ff_table, ff_timing, print_ff_table, FfTiming};

//! Benchmark harness (criterion is unavailable offline; DESIGN.md §6).
//!
//! Each `rust/benches/*.rs` binary regenerates one paper table/figure:
//! it opens a backend (`REPRO_BACKEND`, default native — so every
//! table runs without PJRT artifacts), times the relevant programs
//! with warmup + repeated measurement, and prints rows in the paper's
//! format plus a machine-readable JSON line per row.

pub mod harness;
pub mod workloads;

pub use harness::{
    backend_from_env, bench_artifact, bench_artifact_bound, legacy_train_inputs, quick_mode,
    staging_delta, synth_input, write_bench_json, BenchOpts,
};
pub use workloads::{ff_table, ff_timing, print_ff_table, FfTiming};

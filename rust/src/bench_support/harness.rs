//! Generic artifact timing: synthesize valid inputs from the manifest,
//! warm up (includes any lazy compile), then measure repeated
//! executions through the backend-neutral [`Executable`] interface.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::runtime::{
    open_backend, staging, ArtifactSpec, Backend, BackendKind, Bindings, DeviceTensor,
    Executable, Role,
};
use crate::tensor::{DType, InitSpec, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: usize,
    pub reps: usize,
    pub seed: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 3, reps: 10, seed: 1234 }
    }
}

/// Write one machine-readable bench result file, `BENCH_<name>.json`,
/// into `BENCH_JSON_DIR` (default: the current directory — note that
/// `cargo bench` runs bench binaries with the *package* root as cwd,
/// so unredirected files land in `rust/`; set `BENCH_JSON_DIR` to pin
/// an absolute location, as CI does). Every bench that prints a paper
/// table also emits its rows through here, so the perf trajectory is
/// trackable across commits without scraping stdout; CI validates the
/// files parse. Returns the written path.
pub fn write_bench_json(name: &str, value: &Json) -> Result<PathBuf> {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = PathBuf::from(dir).join(format!("BENCH_{name}.json"));
    let mut text = value.to_string();
    text.push('\n');
    std::fs::write(&path, text)
        .with_context(|| format!("writing bench json {}", path.display()))?;
    Ok(path)
}

/// Quick mode for smoke runs (`BENCH_QUICK=1`): benches shrink to one
/// small geometry and fewer reps so CI can assert the run + JSON
/// contract without caring about absolute timings.
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Open the backend the benches should run on: `REPRO_BACKEND`
/// (native|xla, default native) over `REPRO_ARTIFACTS` (default
/// `artifacts`, only read by the xla backend).
pub fn backend_from_env() -> Result<Box<dyn Backend>> {
    let kind = match std::env::var("REPRO_BACKEND") {
        Ok(v) => v.parse::<BackendKind>()?,
        Err(_) => BackendKind::Native,
    };
    let dir = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    open_backend(kind, std::path::Path::new(&dir))
}

/// Synthesize one valid input tensor for an IoSpec.
pub fn synth_input(
    spec: &crate::runtime::IoSpec,
    rng: &mut Rng,
) -> Tensor {
    match (spec.role, spec.dtype) {
        (Role::Param | Role::OptM | Role::OptV, _) => {
            let init = spec.init.clone().unwrap_or(InitSpec::Uniform { bound: 0.05 });
            Tensor::init(&spec.shape, &init, rng)
        }
        (Role::Scalar, DType::F32) => Tensor::scalar_f32(if spec.name == "lr" {
            1e-3
        } else {
            0.0
        }),
        (Role::Scalar, DType::I32) => Tensor::scalar_i32(0),
        (Role::Data, DType::F32) => {
            let n: usize = spec.shape.iter().product();
            let v = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            Tensor::from_f32(&spec.shape, v).unwrap()
        }
        (Role::Data, DType::I32) => {
            // token-ish ids: small positive ints, safe for any vocab >= 64
            let n: usize = spec.shape.iter().product();
            let v = (0..n).map(|_| rng.range(3, 60) as i32).collect();
            Tensor::from_i32(&spec.shape, v).unwrap()
        }
    }
}

/// Time one artifact end-to-end (inputs pre-synthesized; the measured
/// region is one full `Executable::run` — staging + execute + fetch).
pub fn bench_artifact(
    backend: &dyn Backend,
    name: &str,
    opts: BenchOpts,
) -> Result<Summary> {
    let art = backend.load(name)?;
    let mut rng = Rng::new(opts.seed);
    let inputs: Vec<Tensor> = art
        .spec()
        .inputs
        .iter()
        .map(|io| synth_input(io, &mut rng))
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    // warmup (first call includes any lazy work)
    for _ in 0..opts.warmup.max(1) {
        let _ = art.run(&refs)?;
    }
    let mut samples = Vec::with_capacity(opts.reps);
    for _ in 0..opts.reps {
        let t = Timer::start();
        let out = art.run(&refs)?;
        std::hint::black_box(&out);
        samples.push(t.elapsed_ms());
    }
    Ok(Summary::of(&samples))
}

/// Time one artifact through the resident-bindings path: every input
/// is uploaded once, bound resident, and the measured region is a
/// bare `Bindings::call` — what a hot loop with device-held weights
/// actually pays per call.
pub fn bench_artifact_bound(
    backend: &dyn Backend,
    name: &str,
    opts: BenchOpts,
) -> Result<Summary> {
    let art = backend.load(name)?;
    let mut rng = Rng::new(opts.seed);
    let dev: Vec<DeviceTensor> = art
        .spec()
        .inputs
        .iter()
        .map(|io| backend.upload(synth_input(io, &mut rng)))
        .collect::<Result<_>>()?;
    let mut bind = Bindings::new(art.as_ref());
    for (i, d) in dev.iter().enumerate() {
        bind.bind(i, d.clone())?;
    }
    for _ in 0..opts.warmup.max(1) {
        let _ = bind.call(&[])?;
    }
    let mut samples = Vec::with_capacity(opts.reps);
    for _ in 0..opts.reps {
        let t = Timer::start();
        let out = bind.call(&[])?;
        std::hint::black_box(&out);
        samples.push(t.elapsed_ms());
    }
    Ok(Summary::of(&samples))
}

/// Run `f` and report the host↔backend staging traffic it generated
/// on this thread (see [`staging`]).
pub fn staging_delta<T>(
    f: impl FnOnce() -> Result<T>,
) -> Result<(T, staging::StagingSnapshot)> {
    let before = staging::snapshot();
    let out = f()?;
    Ok((out, staging::snapshot().since(&before)))
}

/// Assemble the full positional host-tensor input set of a train-step
/// artifact from its role groups: `state` is params ++ m ++ v in feed
/// order, scalars resolve by name (`step`/`lr`), `data` fills the
/// `Role::Data` slots left-to-right. This is the legacy-path mirror of
/// `TrainState::train_call`'s device-side assembly; the staging bench
/// and the parity tests share it so the feed-order contract lives in
/// one place.
pub fn legacy_train_inputs<'a>(
    spec: &ArtifactSpec,
    state: &'a [Tensor],
    step: &'a Tensor,
    lr: &'a Tensor,
    data: &'a [Tensor],
) -> Result<Vec<&'a Tensor>> {
    let mut inputs = Vec::with_capacity(spec.inputs.len());
    let (mut si, mut di) = (0, 0);
    for io in &spec.inputs {
        match io.role {
            Role::Param | Role::OptM | Role::OptV => {
                anyhow::ensure!(
                    si < state.len(),
                    "{}: more state inputs than the {} tensors given",
                    spec.name,
                    state.len()
                );
                inputs.push(&state[si]);
                si += 1;
            }
            Role::Scalar => inputs.push(if io.name == "step" { step } else { lr }),
            Role::Data => {
                anyhow::ensure!(
                    di < data.len(),
                    "{}: more data inputs than the {} tensors given",
                    spec.name,
                    data.len()
                );
                inputs.push(&data[di]);
                di += 1;
            }
        }
    }
    anyhow::ensure!(
        si == state.len() && di == data.len(),
        "{}: {} state / {} data tensors left unconsumed",
        spec.name,
        state.len() - si,
        data.len() - di
    );
    Ok(inputs)
}

//! Word-level tokenizer over the nanoBabyLM lexicon.
//!
//! Vocabulary = specials + the grammar's full surface-form list, built
//! deterministically (not from corpus frequency) so every eval item is
//! in-vocabulary by construction. IDs are stable across runs — a
//! tokenizer mismatch between pretraining and eval is impossible by
//! design rather than by discipline.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub const PAD: i32 = 0;
pub const EOS: i32 = 1;
pub const UNK: i32 = 2;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    id_of: BTreeMap<String, i32>,
    word_of: Vec<String>,
}

impl Tokenizer {
    /// Build from a word list (typically `Grammar::vocabulary()`).
    pub fn from_words(words: &[String]) -> Tokenizer {
        let mut word_of: Vec<String> =
            vec!["<pad>".into(), "<eos>".into(), "<unk>".into()];
        let mut id_of = BTreeMap::new();
        id_of.insert("<pad>".to_string(), PAD);
        id_of.insert("<eos>".to_string(), EOS);
        id_of.insert("<unk>".to_string(), UNK);
        for w in words {
            if !id_of.contains_key(w) {
                id_of.insert(w.clone(), word_of.len() as i32);
                word_of.push(w.clone());
            }
        }
        Tokenizer { id_of, word_of }
    }

    pub fn vocab_size(&self) -> usize {
        self.word_of.len()
    }

    pub fn id(&self, word: &str) -> i32 {
        *self.id_of.get(word).unwrap_or(&UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.word_of
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    pub fn encode(&self, words: &[String]) -> Vec<i32> {
        words.iter().map(|w| self.id(w)).collect()
    }

    /// Encode a sentence and append `<eos>`.
    pub fn encode_sentence(&self, words: &[String]) -> Vec<i32> {
        let mut ids = self.encode(words);
        ids.push(EOS);
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> Vec<String> {
        ids.iter().map(|&i| self.word(i).to_string()).collect()
    }

    /// Validate that the model vocab (from the manifest arch) can hold
    /// every id this tokenizer produces.
    pub fn check_fits(&self, model_vocab: usize) -> Result<()> {
        if self.vocab_size() > model_vocab {
            bail!(
                "tokenizer vocab {} exceeds model vocab {model_vocab}",
                self.vocab_size()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::grammar::Grammar;

    #[test]
    fn specials_reserved() {
        let t = Tokenizer::from_words(&["dog".into(), "cat".into()]);
        assert_eq!(t.id("<pad>"), PAD);
        assert_eq!(t.id("<eos>"), EOS);
        assert_eq!(t.id("dog"), 3);
        assert_eq!(t.id("zebra"), UNK);
        assert_eq!(t.word(3), "dog");
        assert_eq!(t.vocab_size(), 5);
    }

    #[test]
    fn roundtrip_encode_decode() {
        let g = Grammar::new();
        let t = Tokenizer::from_words(&g.vocabulary());
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..100 {
            let s = g.sentence(&mut rng);
            let ids = t.encode(&s);
            assert!(!ids.contains(&UNK), "OOV in {s:?}");
            assert_eq!(t.decode(&ids), s);
        }
    }

    #[test]
    fn grammar_fits_model_vocab() {
        let g = Grammar::new();
        let t = Tokenizer::from_words(&g.vocabulary());
        assert!(t.check_fits(512).is_ok(), "vocab {}", t.vocab_size());
        assert!(t.check_fits(10).is_err());
    }

    #[test]
    fn deterministic_ids() {
        let g = Grammar::new();
        let a = Tokenizer::from_words(&g.vocabulary());
        let b = Tokenizer::from_words(&g.vocabulary());
        assert_eq!(a.id("dog"), b.id("dog"));
        assert_eq!(a.vocab_size(), b.vocab_size());
    }

    #[test]
    fn encode_sentence_appends_eos() {
        let t = Tokenizer::from_words(&["hi".into()]);
        assert_eq!(t.encode_sentence(&["hi".into()]), vec![3, EOS]);
    }
}

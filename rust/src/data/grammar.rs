//! nanoBabyLM: a feature-agreement grammar for corpus + eval generation.
//!
//! One lexicon with morphological features (number, gender, animacy,
//! verb valency, irregular plurals) drives four generators:
//!
//! * **corpus** — grammatical sentences over weighted templates
//!   (pretraining data; babyLM stand-in);
//! * **minimal pairs** — grammatical/ungrammatical twins per
//!   phenomenon (BLIMP stand-in; metric: P(good) > P(bad));
//! * **MCQ items** — cloze stems with one correct choice (OPENLLM
//!   stand-in; few-shot prompts assembled by `eval::mcq`);
//! * **probe examples** — labelled sentences for feature-probing
//!   classification heads (GLUE stand-in; heads trained in rust).
//!
//! Everything is deterministic in the caller-supplied RNG.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Number {
    Sg,
    Pl,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gender {
    Masc,
    Fem,
    Neut,
}

#[derive(Debug, Clone)]
struct Noun {
    sg: &'static str,
    pl: &'static str,
    gender: Gender,
    animate: bool,
    person: bool,
    irregular: bool,
}

#[derive(Debug, Clone)]
struct Verb {
    base: &'static str, // plural-agreement form ("run")
    #[allow(dead_code)]
    transitive: bool,
}

const NOUNS: &[Noun] = &[
    Noun { sg: "dog", pl: "dogs", gender: Gender::Neut, animate: true, person: false, irregular: false },
    Noun { sg: "cat", pl: "cats", gender: Gender::Neut, animate: true, person: false, irregular: false },
    Noun { sg: "bird", pl: "birds", gender: Gender::Neut, animate: true, person: false, irregular: false },
    Noun { sg: "horse", pl: "horses", gender: Gender::Neut, animate: true, person: false, irregular: false },
    Noun { sg: "mouse", pl: "mice", gender: Gender::Neut, animate: true, person: false, irregular: true },
    Noun { sg: "boy", pl: "boys", gender: Gender::Masc, animate: true, person: true, irregular: false },
    Noun { sg: "girl", pl: "girls", gender: Gender::Fem, animate: true, person: true, irregular: false },
    Noun { sg: "man", pl: "men", gender: Gender::Masc, animate: true, person: true, irregular: true },
    Noun { sg: "woman", pl: "women", gender: Gender::Fem, animate: true, person: true, irregular: true },
    Noun { sg: "child", pl: "children", gender: Gender::Neut, animate: true, person: true, irregular: true },
    Noun { sg: "king", pl: "kings", gender: Gender::Masc, animate: true, person: true, irregular: false },
    Noun { sg: "queen", pl: "queens", gender: Gender::Fem, animate: true, person: true, irregular: false },
    Noun { sg: "teacher", pl: "teachers", gender: Gender::Neut, animate: true, person: true, irregular: false },
    Noun { sg: "student", pl: "students", gender: Gender::Neut, animate: true, person: true, irregular: false },
    Noun { sg: "doctor", pl: "doctors", gender: Gender::Neut, animate: true, person: true, irregular: false },
    Noun { sg: "farmer", pl: "farmers", gender: Gender::Neut, animate: true, person: true, irregular: false },
    Noun { sg: "apple", pl: "apples", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "book", pl: "books", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "ball", pl: "balls", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "house", pl: "houses", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "tree", pl: "trees", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "stone", pl: "stones", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "river", pl: "rivers", gender: Gender::Neut, animate: false, person: false, irregular: false },
    Noun { sg: "car", pl: "cars", gender: Gender::Neut, animate: false, person: false, irregular: false },
];

const VERBS_INTRANS: &[Verb] = &[
    Verb { base: "sleep", transitive: false },
    Verb { base: "run", transitive: false },
    Verb { base: "jump", transitive: false },
    Verb { base: "swim", transitive: false },
    Verb { base: "laugh", transitive: false },
    Verb { base: "smile", transitive: false },
    Verb { base: "bark", transitive: false },
    Verb { base: "sing", transitive: false },
    Verb { base: "dance", transitive: false },
    Verb { base: "fall", transitive: false },
];

const VERBS_TRANS: &[Verb] = &[
    Verb { base: "see", transitive: true },
    Verb { base: "chase", transitive: true },
    Verb { base: "like", transitive: true },
    Verb { base: "love", transitive: true },
    Verb { base: "push", transitive: true },
    Verb { base: "find", transitive: true },
    Verb { base: "hold", transitive: true },
    Verb { base: "carry", transitive: true },
    Verb { base: "watch", transitive: true },
    Verb { base: "hurt", transitive: true },
];

const ADJS: &[&str] = &[
    "big", "small", "happy", "sad", "old", "young", "red", "blue", "fast", "slow",
];

const ADVS: &[&str] = &["quickly", "slowly", "often", "always"];

/// 3rd-person-singular morphology ("watch"->"watches", "carry"->"carries").
fn third_sg(base: &str) -> String {
    if base.ends_with('s')
        || base.ends_with("sh")
        || base.ends_with("ch")
        || base.ends_with('x')
    {
        format!("{base}es")
    } else if base.ends_with('y')
        && !base.ends_with("ay")
        && !base.ends_with("ey")
        && !base.ends_with("oy")
    {
        format!("{}ies", &base[..base.len() - 1])
    } else {
        format!("{base}s")
    }
}

/// The incorrect regular plural of an irregular noun ("mans", "childs").
fn fake_regular_plural(sg: &str) -> String {
    format!("{sg}s")
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phenomenon {
    SubjVerbAgreement,
    DetNounAgreement,
    AnaphorAgreement,
    NpiLicensing,
    WordOrder,
    ArgStructure,
    IrregularForms,
    NumeralAgreement,
}

impl Phenomenon {
    pub const ALL: [Phenomenon; 8] = [
        Phenomenon::SubjVerbAgreement,
        Phenomenon::DetNounAgreement,
        Phenomenon::AnaphorAgreement,
        Phenomenon::NpiLicensing,
        Phenomenon::WordOrder,
        Phenomenon::ArgStructure,
        Phenomenon::IrregularForms,
        Phenomenon::NumeralAgreement,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Phenomenon::SubjVerbAgreement => "subj_verb_agreement",
            Phenomenon::DetNounAgreement => "det_noun_agreement",
            Phenomenon::AnaphorAgreement => "anaphor_agreement",
            Phenomenon::NpiLicensing => "npi_licensing",
            Phenomenon::WordOrder => "word_order",
            Phenomenon::ArgStructure => "arg_structure",
            Phenomenon::IrregularForms => "irregular_forms",
            Phenomenon::NumeralAgreement => "numeral_agreement",
        }
    }
}

/// Few-shot MCQ task families (OPENLLM stand-in, 4 tasks like the
/// leaderboard's 4 benchmarks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McqTask {
    VerbAgreement,
    Anaphor,
    Npi,
    AuxAgreement,
}

impl McqTask {
    pub const ALL: [McqTask; 4] = [
        McqTask::VerbAgreement,
        McqTask::Anaphor,
        McqTask::Npi,
        McqTask::AuxAgreement,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            McqTask::VerbAgreement => "verb_agreement_mcq",
            McqTask::Anaphor => "anaphor_mcq",
            McqTask::Npi => "npi_mcq",
            McqTask::AuxAgreement => "aux_agreement_mcq",
        }
    }
}

/// Probe classification tasks (GLUE stand-in).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeTask {
    /// CoLA-like: is the sentence grammatical?
    Acceptability,
    /// Is the subject an animate entity?
    SubjectAnimacy,
    /// Does the sentence contain negation?
    Polarity,
    /// Is the subject plural?
    SubjectNumber,
}

impl ProbeTask {
    pub const ALL: [ProbeTask; 4] = [
        ProbeTask::Acceptability,
        ProbeTask::SubjectAnimacy,
        ProbeTask::Polarity,
        ProbeTask::SubjectNumber,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ProbeTask::Acceptability => "acceptability",
            ProbeTask::SubjectAnimacy => "subject_animacy",
            ProbeTask::Polarity => "polarity",
            ProbeTask::SubjectNumber => "subject_number",
        }
    }

    pub fn n_classes(&self) -> usize {
        2
    }
}

#[derive(Debug, Clone)]
pub struct MinimalPair {
    pub good: Vec<String>,
    pub bad: Vec<String>,
    pub phenomenon: Phenomenon,
}

#[derive(Debug, Clone)]
pub struct McqItem {
    /// Shared stem, e.g. ["the", "cat"].
    pub stem: Vec<String>,
    /// Continuations; exactly one is correct.
    pub choices: Vec<Vec<String>>,
    pub correct: usize,
}

pub struct Grammar;

impl Default for Grammar {
    fn default() -> Self {
        Self::new()
    }
}

impl Grammar {
    pub fn new() -> Grammar {
        Grammar
    }

    /// Every surface form the grammar can emit (tokenizer vocabulary).
    pub fn vocabulary(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for n in NOUNS {
            v.push(n.sg.to_string());
            v.push(n.pl.to_string());
            if n.irregular {
                v.push(fake_regular_plural(n.sg)); // bad forms still need ids
            }
        }
        for verb in VERBS_INTRANS.iter().chain(VERBS_TRANS) {
            v.push(verb.base.to_string());
            v.push(third_sg(verb.base));
        }
        for a in ADJS {
            v.push(a.to_string());
        }
        for a in ADVS {
            v.push(a.to_string());
        }
        for w in [
            "the", "a", "this", "these", "that", "those", "every", "some", "no",
            "one", "two", "three", "is", "are", "was", "were", "has", "have",
            "does", "do", "not", "ever", "never", "himself", "herself", "itself",
            "themselves", "who", "and", "in", "on", "near", "under", "with",
            ".", "?",
        ] {
            v.push(w.to_string());
        }
        v.sort();
        v.dedup();
        v
    }

    fn noun<'a>(&self, rng: &mut Rng, filter: impl Fn(&Noun) -> bool) -> &'a Noun {
        let candidates: Vec<&Noun> = NOUNS.iter().filter(|n| filter(n)).collect();
        candidates[rng.below(candidates.len())]
    }

    fn noun_form(&self, n: &Noun, num: Number) -> String {
        match num {
            Number::Sg => n.sg.to_string(),
            Number::Pl => n.pl.to_string(),
        }
    }

    fn verb_form(&self, v: &Verb, num: Number) -> String {
        match num {
            Number::Sg => third_sg(v.base),
            Number::Pl => v.base.to_string(),
        }
    }

    fn det(&self, rng: &mut Rng, num: Number) -> &'static str {
        match num {
            Number::Sg => *rng.choice(&["the", "a", "this", "that", "every"]),
            Number::Pl => *rng.choice(&["the", "these", "those", "some"]),
        }
    }

    fn number(&self, rng: &mut Rng) -> Number {
        if rng.bool(0.5) {
            Number::Sg
        } else {
            Number::Pl
        }
    }

    /// One grammatical sentence (sequence of word tokens incl. final
    /// punctuation). Weighted over 8 templates.
    pub fn sentence(&self, rng: &mut Rng) -> Vec<String> {
        let template = rng.weighted(&[3.0, 3.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let num = self.number(rng);
        let mut s: Vec<String> = Vec::new();
        match template {
            0 => {
                // Det (Adj) N V_intrans (Adv) .
                s.push(self.det(rng, num).into());
                if rng.bool(0.35) {
                    s.push((*rng.choice(ADJS)).into());
                }
                let n = self.noun(rng, |n| n.animate);
                s.push(self.noun_form(n, num));
                let v = rng.choice(VERBS_INTRANS);
                s.push(self.verb_form(v, num));
                if rng.bool(0.3) {
                    s.push((*rng.choice(ADVS)).into());
                }
                s.push(".".into());
            }
            1 => {
                // Det N V_trans Det (Adj) N .
                s.push(self.det(rng, num).into());
                let subj = self.noun(rng, |n| n.animate);
                s.push(self.noun_form(subj, num));
                let v = rng.choice(VERBS_TRANS);
                s.push(self.verb_form(v, num));
                let onum = self.number(rng);
                s.push(self.det(rng, onum).into());
                if rng.bool(0.35) {
                    s.push((*rng.choice(ADJS)).into());
                }
                let obj = self.noun(rng, |_| true);
                s.push(self.noun_form(obj, onum));
                s.push(".".into());
            }
            2 => {
                // Det N is/are Adj .
                s.push(self.det(rng, num).into());
                let n = self.noun(rng, |_| true);
                s.push(self.noun_form(n, num));
                s.push(if num == Number::Sg { "is" } else { "are" }.into());
                s.push((*rng.choice(ADJS)).into());
                s.push(".".into());
            }
            3 => {
                // Det N V_trans <reflexive> .  (person/animate subjects)
                s.push("the".into());
                let n = self.noun(rng, |n| n.animate);
                s.push(self.noun_form(n, num));
                s.push(self.verb_form(&Verb { base: "hurt", transitive: true }, num));
                s.push(reflexive(n, num).into());
                s.push(".".into());
            }
            4 => {
                // Det N has/have not ever V .  (licensed NPI)
                s.push("the".into());
                let n = self.noun(rng, |n| n.animate);
                s.push(self.noun_form(n, num));
                s.push(if num == Number::Sg { "has" } else { "have" }.into());
                s.push("not".into());
                if rng.bool(0.5) {
                    s.push("ever".into());
                }
                let v = rng.choice(VERBS_INTRANS);
                s.push(v.base.into()); // bare form after aux
                s.push(".".into());
            }
            5 => {
                // Numeral N V .   (one/two/three agreement)
                let (word, num2) = match rng.below(3) {
                    0 => ("one", Number::Sg),
                    1 => ("two", Number::Pl),
                    _ => ("three", Number::Pl),
                };
                s.push(word.into());
                let n = self.noun(rng, |n| n.animate);
                s.push(self.noun_form(n, num2));
                let v = rng.choice(VERBS_INTRANS);
                s.push(self.verb_form(v, num2));
                s.push(".".into());
            }
            6 => {
                // is/are Det N Adj ?   (subject-aux inversion)
                s.push(if num == Number::Sg { "is" } else { "are" }.into());
                s.push("the".into());
                let n = self.noun(rng, |_| true);
                s.push(self.noun_form(n, num));
                s.push((*rng.choice(ADJS)).into());
                s.push("?".into());
            }
            _ => {
                // Det N who V_intrans V_trans Det N .  (relative clause;
                // long-distance agreement pressure)
                s.push("the".into());
                let subj = self.noun(rng, |n| n.person);
                s.push(self.noun_form(subj, num));
                s.push("who".into());
                let v1 = rng.choice(VERBS_INTRANS);
                s.push(self.verb_form(v1, num));
                let v2 = rng.choice(VERBS_TRANS);
                s.push(self.verb_form(v2, num));
                let onum = self.number(rng);
                s.push(self.det(rng, onum).into());
                let obj = self.noun(rng, |_| true);
                s.push(self.noun_form(obj, onum));
                s.push(".".into());
            }
        }
        s
    }

    /// Stream of sentences (words) until at least `n_tokens` tokens.
    pub fn corpus(&self, n_tokens: usize, seed: u64) -> Vec<String> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(n_tokens + 16);
        while out.len() < n_tokens {
            out.extend(self.sentence(&mut rng));
        }
        out
    }

    /// One grammatical/ungrammatical twin for a phenomenon.
    pub fn minimal_pair(&self, ph: Phenomenon, rng: &mut Rng) -> MinimalPair {
        let num = self.number(rng);
        let (good, bad): (Vec<String>, Vec<String>) = match ph {
            Phenomenon::SubjVerbAgreement => {
                let n = self.noun(rng, |n| n.animate && !n.irregular);
                let v = rng.choice(VERBS_INTRANS);
                let det = if num == Number::Sg { "the" } else { "the" };
                let subj = self.noun_form(n, num);
                let good_v = self.verb_form(v, num);
                let bad_v = self.verb_form(
                    v,
                    if num == Number::Sg { Number::Pl } else { Number::Sg },
                );
                (
                    vec![det.into(), subj.clone(), good_v, ".".into()],
                    vec![det.into(), subj, bad_v, ".".into()],
                )
            }
            Phenomenon::DetNounAgreement => {
                let n = self.noun(rng, |n| !n.irregular);
                let (good_det, bad_det) = match num {
                    Number::Sg => ("this", "these"),
                    Number::Pl => ("these", "this"),
                };
                let form = self.noun_form(n, num);
                let v = rng.choice(VERBS_INTRANS);
                let vf = self.verb_form(v, num);
                (
                    vec![good_det.into(), form.clone(), vf.clone(), ".".into()],
                    vec![bad_det.into(), form, vf, ".".into()],
                )
            }
            Phenomenon::AnaphorAgreement => {
                let n = self.noun(rng, |n| n.animate && n.gender != Gender::Neut);
                let good_refl = reflexive(n, Number::Sg);
                let bad_refl = match n.gender {
                    Gender::Masc => "herself",
                    _ => "himself",
                };
                (
                    vec!["the".into(), n.sg.into(), "hurts".into(),
                         good_refl.into(), ".".into()],
                    vec!["the".into(), n.sg.into(), "hurts".into(),
                         bad_refl.into(), ".".into()],
                )
            }
            Phenomenon::NpiLicensing => {
                let n = self.noun(rng, |n| n.animate);
                let subj = self.noun_form(n, num);
                let aux = if num == Number::Sg { "has" } else { "have" };
                let v = rng.choice(VERBS_INTRANS);
                (
                    // "the dog has not ever barked" (licensed)
                    vec!["the".into(), subj.clone(), aux.into(), "not".into(),
                         "ever".into(), v.base.into(), ".".into()],
                    // "the dog has ever barked" (unlicensed NPI)
                    vec!["the".into(), subj, aux.into(), "ever".into(),
                         v.base.into(), ".".into()],
                )
            }
            Phenomenon::WordOrder => {
                let n = self.noun(rng, |n| n.animate);
                let subj = self.noun_form(n, num);
                let v = rng.choice(VERBS_INTRANS);
                let vf = self.verb_form(v, num);
                (
                    vec!["the".into(), subj.clone(), vf.clone(), ".".into()],
                    // determiner displaced after noun
                    vec![subj, "the".into(), vf, ".".into()],
                )
            }
            Phenomenon::ArgStructure => {
                let subj = self.noun(rng, |n| n.animate);
                let sf = self.noun_form(subj, num);
                let obj = self.noun(rng, |_| true);
                let onum = self.number(rng);
                let of = self.noun_form(obj, onum);
                let vt = rng.choice(VERBS_TRANS);
                let vi = rng.choice(VERBS_INTRANS);
                let odet = self.det(rng, onum);
                (
                    // transitive verb with object: fine
                    vec!["the".into(), sf.clone(), self.verb_form(vt, num),
                         odet.into(), of.clone(), ".".into()],
                    // intransitive verb with object: violation
                    vec!["the".into(), sf, self.verb_form(vi, num),
                         odet.into(), of, ".".into()],
                )
            }
            Phenomenon::IrregularForms => {
                let n = self.noun(rng, |n| n.irregular);
                let v = rng.choice(VERBS_INTRANS);
                let vf = self.verb_form(v, Number::Pl);
                (
                    vec!["the".into(), n.pl.into(), vf.clone(), ".".into()],
                    vec!["the".into(), fake_regular_plural(n.sg), vf, ".".into()],
                )
            }
            Phenomenon::NumeralAgreement => {
                let n = self.noun(rng, |n| n.animate && !n.irregular);
                let v = rng.choice(VERBS_INTRANS);
                let (numeral, nnum) = if rng.bool(0.5) {
                    ("two", Number::Pl)
                } else {
                    ("three", Number::Pl)
                };
                (
                    vec![numeral.into(), self.noun_form(n, nnum),
                         self.verb_form(v, nnum), ".".into()],
                    // numeral > 1 with singular noun
                    vec![numeral.into(), self.noun_form(n, Number::Sg),
                         self.verb_form(v, nnum), ".".into()],
                )
            }
        };
        MinimalPair { good, bad, phenomenon: ph }
    }

    /// One MCQ cloze item.
    pub fn mcq(&self, task: McqTask, rng: &mut Rng) -> McqItem {
        match task {
            McqTask::VerbAgreement => {
                let num = self.number(rng);
                let n = self.noun(rng, |n| n.animate && !n.irregular);
                let v = rng.choice(VERBS_INTRANS);
                let good = self.verb_form(v, num);
                let bad = self.verb_form(
                    v,
                    if num == Number::Sg { Number::Pl } else { Number::Sg },
                );
                let correct = rng.below(2);
                let mut choices = vec![vec![bad, ".".into()], vec![good, ".".into()]];
                if correct == 0 {
                    choices.swap(0, 1);
                }
                McqItem {
                    stem: vec!["the".into(), self.noun_form(n, num)],
                    choices,
                    correct,
                }
            }
            McqTask::Anaphor => {
                let n = self.noun(rng, |n| n.person && n.gender != Gender::Neut);
                let good = reflexive(n, Number::Sg).to_string();
                let bad1 = if n.gender == Gender::Masc { "herself" } else { "himself" };
                let bad2 = "themselves";
                let correct = rng.below(3);
                let mut choices = vec![
                    vec![good, ".".into()],
                    vec![bad1.into(), ".".into()],
                    vec![bad2.into(), ".".into()],
                ];
                choices.swap(0, correct);
                McqItem {
                    stem: vec!["the".into(), n.sg.into(), "hurts".into()],
                    choices,
                    correct,
                }
            }
            McqTask::Npi => {
                let n = self.noun(rng, |n| n.animate);
                let num = self.number(rng);
                let aux = if num == Number::Sg { "has" } else { "have" };
                let v = rng.choice(VERBS_INTRANS);
                let correct = rng.below(2);
                // "the dog has not ___ barked": "ever" good, "never" bad
                let mut choices = vec![
                    vec!["ever".into(), v.base.into(), ".".into()],
                    vec!["never".into(), v.base.into(), ".".into()],
                ];
                choices.swap(0, correct);
                McqItem {
                    stem: vec!["the".into(), self.noun_form(n, num), aux.into(),
                               "not".into()],
                    choices,
                    correct,
                }
            }
            McqTask::AuxAgreement => {
                let num = self.number(rng);
                let n = self.noun(rng, |n| !n.irregular);
                let good = if num == Number::Sg { "is" } else { "are" };
                let bad = if num == Number::Sg { "are" } else { "is" };
                let adj = *rng.choice(ADJS);
                let correct = rng.below(2);
                let mut choices = vec![
                    vec![good.into(), adj.into(), ".".into()],
                    vec![bad.into(), adj.into(), ".".into()],
                ];
                choices.swap(0, correct);
                McqItem {
                    stem: vec!["the".into(), self.noun_form(n, num)],
                    choices,
                    correct,
                }
            }
        }
    }

    /// One labelled probe example: (sentence tokens, class label).
    pub fn probe_example(&self, task: ProbeTask, rng: &mut Rng) -> (Vec<String>, usize) {
        match task {
            ProbeTask::Acceptability => {
                // reuse minimal pairs: label 1 = grammatical
                let ph = *rng.choice(&Phenomenon::ALL);
                let pair = self.minimal_pair(ph, rng);
                if rng.bool(0.5) {
                    (pair.good, 1)
                } else {
                    (pair.bad, 0)
                }
            }
            ProbeTask::SubjectAnimacy => {
                let num = self.number(rng);
                let want_animate = rng.bool(0.5);
                let n = self.noun(rng, |n| n.animate == want_animate);
                let s = vec![
                    "the".into(),
                    self.noun_form(n, num),
                    if num == Number::Sg { "is" } else { "are" }.into(),
                    (*rng.choice(ADJS)).into(),
                    ".".into(),
                ];
                (s, want_animate as usize)
            }
            ProbeTask::Polarity => {
                let num = self.number(rng);
                let n = self.noun(rng, |n| n.animate);
                let v = rng.choice(VERBS_INTRANS);
                let negated = rng.bool(0.5);
                let aux = if num == Number::Sg { "does" } else { "do" };
                let s = if negated {
                    vec!["the".into(), self.noun_form(n, num), aux.into(),
                         "not".into(), v.base.into(), ".".into()]
                } else {
                    vec!["the".into(), self.noun_form(n, num),
                         self.verb_form(v, num), ".".into()]
                };
                (s, negated as usize)
            }
            ProbeTask::SubjectNumber => {
                let num = self.number(rng);
                let n = self.noun(rng, |n| !n.irregular);
                let v = rng.choice(VERBS_INTRANS);
                let s = vec![
                    "the".into(),
                    self.noun_form(n, num),
                    self.verb_form(v, num),
                    (*rng.choice(ADVS)).into(),
                    ".".into(),
                ];
                (s, (num == Number::Pl) as usize)
            }
        }
    }
}

fn reflexive(n: &Noun, num: Number) -> &'static str {
    if num == Number::Pl {
        return "themselves";
    }
    match (n.person, n.gender) {
        (_, Gender::Masc) => "himself",
        (_, Gender::Fem) => "herself",
        (true, Gender::Neut) => "themselves",
        (false, Gender::Neut) => "itself",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_is_compact_and_stable() {
        let g = Grammar::new();
        let v = g.vocabulary();
        assert!(v.len() > 80 && v.len() < 300, "{}", v.len());
        assert_eq!(v, g.vocabulary());
        assert!(v.contains(&"themselves".to_string()));
        assert!(v.contains(&"mans".to_string())); // bad irregular form
        assert!(v.iter().all(|w| !w.is_empty()));
    }

    #[test]
    fn sentences_end_with_punctuation_and_stay_in_vocab() {
        let g = Grammar::new();
        let vocab: std::collections::BTreeSet<_> = g.vocabulary().into_iter().collect();
        let mut rng = Rng::new(0);
        for _ in 0..500 {
            let s = g.sentence(&mut rng);
            assert!(s.len() >= 3);
            let last = s.last().unwrap();
            assert!(last == "." || last == "?");
            for w in &s {
                assert!(vocab.contains(w), "OOV word {w:?} in {s:?}");
            }
        }
    }

    #[test]
    fn corpus_reaches_length_deterministically() {
        let g = Grammar::new();
        let c1 = g.corpus(1000, 7);
        let c2 = g.corpus(1000, 7);
        assert_eq!(c1, c2);
        assert!(c1.len() >= 1000);
        let c3 = g.corpus(1000, 8);
        assert_ne!(c1, c3);
    }

    #[test]
    fn minimal_pairs_differ_and_stay_in_vocab() {
        let g = Grammar::new();
        let vocab: std::collections::BTreeSet<_> = g.vocabulary().into_iter().collect();
        let mut rng = Rng::new(1);
        for ph in Phenomenon::ALL {
            for _ in 0..50 {
                let p = g.minimal_pair(ph, &mut rng);
                assert_ne!(p.good, p.bad, "{ph:?}");
                for w in p.good.iter().chain(&p.bad) {
                    assert!(vocab.contains(w), "{ph:?} OOV {w:?}");
                }
            }
        }
    }

    #[test]
    fn third_sg_morphology() {
        assert_eq!(third_sg("run"), "runs");
        assert_eq!(third_sg("watch"), "watches");
        assert_eq!(third_sg("push"), "pushes");
        assert_eq!(third_sg("carry"), "carries");
        assert_eq!(third_sg("see"), "sees");
    }

    #[test]
    fn subj_verb_pair_flips_only_verb() {
        let g = Grammar::new();
        let mut rng = Rng::new(2);
        let p = g.minimal_pair(Phenomenon::SubjVerbAgreement, &mut rng);
        assert_eq!(p.good.len(), p.bad.len());
        let diffs: Vec<_> = p
            .good
            .iter()
            .zip(&p.bad)
            .filter(|(a, b)| a != b)
            .collect();
        assert_eq!(diffs.len(), 1, "{:?} vs {:?}", p.good, p.bad);
    }

    #[test]
    fn mcq_correct_index_valid_and_choices_distinct() {
        let g = Grammar::new();
        let mut rng = Rng::new(3);
        for task in McqTask::ALL {
            let mut correct_positions = std::collections::BTreeSet::new();
            for _ in 0..60 {
                let item = g.mcq(task, &mut rng);
                assert!(item.correct < item.choices.len());
                correct_positions.insert(item.correct);
                let set: std::collections::BTreeSet<_> =
                    item.choices.iter().collect();
                assert_eq!(set.len(), item.choices.len(), "{task:?} dup choices");
            }
            // answer position must not be constant (no position bias)
            assert!(correct_positions.len() > 1, "{task:?}");
        }
    }

    #[test]
    fn probe_labels_balanced() {
        let g = Grammar::new();
        let mut rng = Rng::new(4);
        for task in ProbeTask::ALL {
            let mut ones = 0;
            for _ in 0..200 {
                let (s, label) = g.probe_example(task, &mut rng);
                assert!(!s.is_empty());
                assert!(label < task.n_classes());
                ones += label;
            }
            assert!((40..160).contains(&ones), "{task:?} unbalanced: {ones}/200");
        }
    }

    #[test]
    fn npi_pair_is_the_licensing_contrast() {
        let g = Grammar::new();
        let mut rng = Rng::new(5);
        let p = g.minimal_pair(Phenomenon::NpiLicensing, &mut rng);
        assert!(p.good.contains(&"not".to_string()));
        assert!(p.good.contains(&"ever".to_string()));
        assert!(!p.bad.contains(&"not".to_string()));
        assert!(p.bad.contains(&"ever".to_string()));
    }
}

//! Data substrates: synthetic corpus, tokenizer, batching, MNIST.
//!
//! `grammar` is the babyLM substitute ("nanoBabyLM", DESIGN.md §6): a
//! feature-agreement grammar that generates the pretraining corpus AND
//! the evaluation suites (minimal pairs, few-shot MCQ, probe tasks)
//! from the same lexicon, so the model is evaluated on exactly the
//! linguistic structure it was trained to acquire — the babyLM→BLIMP
//! relationship in miniature.

pub mod dataset;
pub mod grammar;
pub mod mnist;
pub mod tokenizer;

pub use dataset::TokenDataset;
pub use grammar::{Grammar, McqTask, Phenomenon, ProbeTask};
pub use mnist::MnistGen;
pub use tokenizer::Tokenizer;

/// `n` tokenized nanoBabyLM sentences from a fresh seeded grammar —
/// the request corpus used by the serving CLI, example, bench and
/// tests (same seed ⇒ same corpus, so scores are comparable).
pub fn sample_sentences(n: usize, seed: u64) -> Vec<Vec<i32>> {
    let grammar = Grammar::new();
    let tok = Tokenizer::from_words(&grammar.vocabulary());
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| tok.encode_sentence(&grammar.sentence(&mut rng)))
        .collect()
}

//! Data substrates: synthetic corpus, tokenizer, batching, MNIST.
//!
//! `grammar` is the babyLM substitute ("nanoBabyLM", DESIGN.md §6): a
//! feature-agreement grammar that generates the pretraining corpus AND
//! the evaluation suites (minimal pairs, few-shot MCQ, probe tasks)
//! from the same lexicon, so the model is evaluated on exactly the
//! linguistic structure it was trained to acquire — the babyLM→BLIMP
//! relationship in miniature.

pub mod dataset;
pub mod grammar;
pub mod mnist;
pub mod tokenizer;

pub use dataset::TokenDataset;
pub use grammar::{Grammar, McqTask, Phenomenon, ProbeTask};
pub use mnist::MnistGen;
pub use tokenizer::Tokenizer;

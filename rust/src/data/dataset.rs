//! Packed-token dataset + train/valid batching.
//!
//! Sentences are concatenated with `<eos>` separators into one token
//! stream (babyLM-style packed LM pretraining), then sliced into
//! fixed-length sequences. Batches come out shaped for the train-step
//! artifact: `(K, B, S)` int32 — K microbatches per PJRT call.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TokenDataset {
    /// Sequence-major storage: each row is one packed sequence of len S.
    train: Vec<Vec<i32>>,
    valid: Vec<Vec<i32>>,
    pub seq: usize,
}

impl TokenDataset {
    /// Pack a token stream into sequences of length `seq`, holding out
    /// `valid_frac` of sequences for validation.
    pub fn from_stream(tokens: &[i32], seq: usize, valid_frac: f64, seed: u64) -> Result<TokenDataset> {
        if tokens.len() < 2 * seq {
            bail!("stream of {} tokens too short for seq={seq}", tokens.len());
        }
        let mut seqs: Vec<Vec<i32>> = tokens
            .chunks_exact(seq)
            .map(|c| c.to_vec())
            .collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut seqs);
        let n_valid = ((seqs.len() as f64 * valid_frac) as usize).max(1);
        if n_valid >= seqs.len() {
            bail!("not enough sequences ({}) for valid_frac={valid_frac}", seqs.len());
        }
        let valid = seqs.split_off(seqs.len() - n_valid);
        Ok(TokenDataset { train: seqs, valid, seq })
    }

    pub fn n_train(&self) -> usize {
        self.train.len()
    }

    pub fn n_valid(&self) -> usize {
        self.valid.len()
    }

    pub fn train_tokens(&self) -> usize {
        self.train.len() * self.seq
    }

    /// Sample a `(K, B, S)` i32 tensor of training microbatches.
    pub fn train_batch(&self, k: usize, b: usize, rng: &mut Rng) -> Tensor {
        let mut data = Vec::with_capacity(k * b * self.seq);
        for _ in 0..k * b {
            let row = &self.train[rng.below(self.train.len())];
            data.extend_from_slice(row);
        }
        Tensor::from_i32(&[k, b, self.seq], data).expect("batch shape")
    }

    /// Deterministic validation batch `(B, S)` starting at `offset`
    /// sequences (wraps around).
    pub fn valid_batch(&self, b: usize, offset: usize) -> Tensor {
        let mut data = Vec::with_capacity(b * self.seq);
        for i in 0..b {
            let row = &self.valid[(offset + i) % self.valid.len()];
            data.extend_from_slice(row);
        }
        Tensor::from_i32(&[b, self.seq], data).expect("batch shape")
    }
}

/// Right-pad a batch of variable-length sequences to `(b, s)` plus the
/// matching f32 mask — the shape the score/features artifacts take.
/// Sequences longer than `s` are truncated from the left (keep the
/// most recent context).
pub fn pad_batch(seqs: &[Vec<i32>], b: usize, s: usize) -> Result<(Tensor, Tensor)> {
    if seqs.len() > b {
        bail!("{} sequences for batch of {b}", seqs.len());
    }
    let mut toks = vec![0i32; b * s];
    let mut mask = vec![0.0f32; b * s];
    for (i, seq) in seqs.iter().enumerate() {
        let start = seq.len().saturating_sub(s);
        let slice = &seq[start..];
        for (j, &t) in slice.iter().enumerate() {
            toks[i * s + j] = t;
            mask[i * s + j] = 1.0;
        }
    }
    Ok((
        Tensor::from_i32(&[b, s], toks)?,
        Tensor::from_f32(&[b, s], mask)?,
    ))
}

/// Lengths vector `(b,)` for next_logits-style artifacts.
pub fn lengths_of(seqs: &[Vec<i32>], b: usize, s: usize) -> Tensor {
    let mut lens = vec![1i32; b];
    for (i, seq) in seqs.iter().enumerate() {
        lens[i] = seq.len().min(s).max(1) as i32;
    }
    Tensor::from_i32(&[b], lens).expect("length shape")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn packs_and_splits() {
        let ds = TokenDataset::from_stream(&stream(1000), 16, 0.1, 0).unwrap();
        assert_eq!(ds.n_train() + ds.n_valid(), 62);
        assert!(ds.n_valid() >= 6);
        assert_eq!(ds.seq, 16);
    }

    #[test]
    fn too_short_errors() {
        assert!(TokenDataset::from_stream(&stream(10), 16, 0.1, 0).is_err());
    }

    #[test]
    fn train_batch_shape_and_membership() {
        let ds = TokenDataset::from_stream(&stream(2000), 8, 0.1, 1).unwrap();
        let mut rng = Rng::new(2);
        let b = ds.train_batch(4, 3, &mut rng);
        assert_eq!(b.shape, vec![4, 3, 8]);
        // every row must be a contiguous run of 8 consecutive ints
        let v = b.as_i32().unwrap();
        for row in v.chunks_exact(8) {
            for w in row.windows(2) {
                assert_eq!(w[1], w[0] + 1);
            }
        }
    }

    #[test]
    fn valid_batch_deterministic_and_wrapping() {
        let ds = TokenDataset::from_stream(&stream(500), 8, 0.2, 3).unwrap();
        let a = ds.valid_batch(4, 0);
        let b = ds.valid_batch(4, 0);
        assert_eq!(a, b);
        let _wrapped = ds.valid_batch(ds.n_valid() + 2, 0); // must not panic
    }

    #[test]
    fn pad_batch_masks_correctly() {
        let seqs = vec![vec![5, 6, 7], vec![9]];
        let (t, m) = pad_batch(&seqs, 3, 4).unwrap();
        assert_eq!(t.shape, vec![3, 4]);
        assert_eq!(t.as_i32().unwrap(), &[5, 6, 7, 0, 9, 0, 0, 0, 0, 0, 0, 0]);
        assert_eq!(
            m.as_f32().unwrap(),
            &[1., 1., 1., 0., 1., 0., 0., 0., 0., 0., 0., 0.]
        );
    }

    #[test]
    fn pad_batch_truncates_left() {
        let seqs = vec![vec![1, 2, 3, 4, 5, 6]];
        let (t, _) = pad_batch(&seqs, 1, 4).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[3, 4, 5, 6]);
        let l = lengths_of(&seqs, 1, 4);
        assert_eq!(l.as_i32().unwrap(), &[4]);
    }
}

//! Procedural MNIST-like digit generator (paper §3.4.5 substitute).
//!
//! The real MNIST download is unavailable offline; we render 28×28
//! grayscale digits from 7×5 glyph skeletons with random translation,
//! stroke-thickness dilation and pixel noise. The task keeps MNIST's
//! shape — 10-class, centered-ish digits, linearly-dominated MLP
//! compute — which is all §3.4.5 exercises (DESIGN.md §6).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Classic 7-row × 5-col digit glyphs (1 = stroke).
const GLYPHS: [[u8; 7]; 10] = [
    // each u8 is a 5-bit row, MSB = leftmost column
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

pub const IMG: usize = 28;
pub const PIXELS: usize = IMG * IMG;

pub struct MnistGen {
    rng: Rng,
}

impl MnistGen {
    pub fn new(seed: u64) -> MnistGen {
        MnistGen { rng: Rng::new(seed) }
    }

    /// Render one digit into a 28×28 f32 image in [0, 1].
    pub fn render(&mut self, digit: usize) -> Vec<f32> {
        assert!(digit < 10);
        let glyph = &GLYPHS[digit];
        let mut img = vec![0.0f32; PIXELS];
        // glyph cell size ~3px, glyph occupies 21x15; random offset
        let cell = 3usize;
        let (gh, gw) = (7 * cell, 5 * cell);
        let dy = self.rng.range(0, IMG - gh);
        let dx = self.rng.range(0, IMG - gw);
        for (r, row) in glyph.iter().enumerate() {
            for c in 0..5 {
                if (row >> (4 - c)) & 1 == 1 {
                    for py in 0..cell {
                        for px in 0..cell {
                            let y = dy + r * cell + py;
                            let x = dx + c * cell + px;
                            img[y * IMG + x] = 1.0;
                        }
                    }
                }
            }
        }
        // stroke dilation with prob 0.3: thicken right/down by one pixel
        if self.rng.bool(0.3) {
            let src = img.clone();
            for y in 0..IMG {
                for x in 0..IMG - 1 {
                    if src[y * IMG + x] > 0.5 {
                        img[y * IMG + x + 1] = img[y * IMG + x + 1].max(0.8);
                    }
                }
            }
        }
        // additive pixel noise + intensity jitter
        let gain = self.rng.uniform(0.8, 1.0);
        for p in img.iter_mut() {
            *p = (*p * gain + self.rng.uniform(0.0, 0.12)).clamp(0.0, 1.0);
        }
        img
    }

    /// A labelled batch: images (n, 784) f32 and labels (n,) i32, with
    /// classes cycled (balanced) then shuffled.
    pub fn batch(&mut self, n: usize) -> (Tensor, Tensor) {
        let mut order: Vec<usize> = (0..n).map(|i| i % 10).collect();
        self.rng.shuffle(&mut order);
        let mut images = Vec::with_capacity(n * PIXELS);
        let mut labels = Vec::with_capacity(n);
        for &d in &order {
            images.extend(self.render(d));
            labels.push(d as i32);
        }
        (
            Tensor::from_f32(&[n, PIXELS], images).unwrap(),
            Tensor::from_i32(&[n], labels).unwrap(),
        )
    }

    /// Train-step-shaped batch: images (k, b, 784), labels (k, b).
    pub fn train_batch(&mut self, k: usize, b: usize) -> (Tensor, Tensor) {
        let (imgs, labels) = self.batch(k * b);
        let imgs = Tensor::from_f32(&[k, b, PIXELS], imgs.as_f32().unwrap().to_vec())
            .unwrap();
        let labels =
            Tensor::from_i32(&[k, b], labels.as_i32().unwrap().to_vec()).unwrap();
        (imgs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_images() {
        let mut g = MnistGen::new(0);
        for d in 0..10 {
            let img = g.render(d);
            assert_eq!(img.len(), PIXELS);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let ink: f32 = img.iter().sum();
            assert!(ink > 20.0, "digit {d} nearly blank: {ink}");
            assert!(ink < 500.0, "digit {d} nearly solid: {ink}");
        }
    }

    #[test]
    fn digits_are_distinguishable() {
        // different digits must differ more than two renders of the same
        let mut g = MnistGen::new(1);
        // disable translation variance by averaging many renders
        let avg = |g: &mut MnistGen, d: usize| {
            let mut acc = vec![0.0f64; PIXELS];
            for _ in 0..30 {
                for (a, p) in acc.iter_mut().zip(g.render(d)) {
                    *a += p as f64;
                }
            }
            acc
        };
        let a0 = avg(&mut g, 0);
        let a1 = avg(&mut g, 1);
        let d01: f64 = a0.iter().zip(&a1).map(|(x, y)| (x - y).abs()).sum();
        assert!(d01 > 100.0, "digits 0/1 indistinguishable: {d01}");
    }

    #[test]
    fn batch_balanced_and_shaped() {
        let mut g = MnistGen::new(2);
        let (x, y) = g.batch(40);
        assert_eq!(x.shape, vec![40, PIXELS]);
        assert_eq!(y.shape, vec![40]);
        let mut counts = [0; 10];
        for &l in y.as_i32().unwrap() {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn train_batch_shape() {
        let mut g = MnistGen::new(3);
        let (x, y) = g.train_batch(4, 8);
        assert_eq!(x.shape, vec![4, 8, PIXELS]);
        assert_eq!(y.shape, vec![4, 8]);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x1, _) = MnistGen::new(7).batch(10);
        let (x2, _) = MnistGen::new(7).batch(10);
        assert_eq!(x1, x2);
    }
}

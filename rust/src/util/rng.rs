//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! All data generation, init and shuffling in the coordinator is
//! seeded, so every training/eval run is exactly reproducible from its
//! config. (The vendored crate set has no `rand`; DESIGN.md §6.)

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-run / per-shard rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (sv, cv) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * sv);
            return r * cv;
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Pick an index according to (unnormalised, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0f64;
        for _ in 0..20_000 {
            let x = r.uniform(-0.5, 0.5);
            assert!((-0.5..0.5).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 20_000.0).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_all() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 8.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] * 3 && counts[1] > counts[2] * 3);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

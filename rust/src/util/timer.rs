//! Wall-clock timing helpers used by the trainer, server and benches.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, milliseconds).
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_ms())
}

/// Repeat-measurement harness: `warmup` unmeasured runs, then `reps`
/// measured runs; returns per-run milliseconds.
pub fn measure_ms<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        let _ = f();
    }
    (0..reps)
        .map(|_| {
            let t = Timer::start();
            let _ = f();
            t.elapsed_ms()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let ms = measure_ms(1, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert_eq!(ms.len(), 3);
        assert!(ms.iter().all(|&m| m >= 1.5), "{ms:?}");
    }
}

//! Summary statistics over timing samples (mean/std/percentiles).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.50),
            p90: pct(0.90),
            p95: pct(0.95),
            p99: pct(0.99),
            max: sorted[n - 1],
        }
    }
}

/// Online mean/count accumulator (loss curves, token throughput).
#[derive(Debug, Clone, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
}

impl Accum {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn accum() {
        let mut a = Accum::default();
        assert!(a.mean().is_nan());
        a.add(2.0);
        a.add(4.0);
        assert_eq!(a.mean(), 3.0);
    }
}

//! Deterministic, NaN-safe argmax.
//!
//! `Iterator::max_by(|a, b| a.partial_cmp(b).unwrap())` panics the
//! moment a NaN shows up in a logit row — a single poisoned weight
//! would take down a serve worker. These helpers never panic: NaN
//! entries are skipped entirely, ties resolve to the **lowest index**
//! (strict `>` while scanning left to right), and an empty or all-NaN
//! slice yields `None` instead of a crash.

/// Index of the largest finite-or-infinite value in `row`.
///
/// NaNs are ignored; ties go to the lowest index; returns `None` when
/// `row` is empty or every entry is NaN.
pub fn argmax_f32(row: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i)
}

/// `f64` twin of [`argmax_f32`] (eval paths aggregate scores in f64).
pub fn argmax_f64(row: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_max() {
        assert_eq!(argmax_f32(&[0.5, 2.0, -1.0, 1.5]), Some(1));
        assert_eq!(argmax_f64(&[-3.0, -1.0, -2.0]), Some(1));
    }

    #[test]
    fn ties_resolve_to_lowest_index() {
        assert_eq!(argmax_f32(&[1.0, 7.0, 7.0, 7.0]), Some(1));
        assert_eq!(argmax_f64(&[4.0, 4.0]), Some(0));
    }

    #[test]
    fn nan_entries_are_skipped_not_fatal() {
        assert_eq!(argmax_f32(&[f32::NAN, 1.0, 2.0, f32::NAN]), Some(2));
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), None);
        assert_eq!(argmax_f64(&[f64::NAN, 0.0]), Some(1));
    }

    #[test]
    fn empty_is_none() {
        assert_eq!(argmax_f32(&[]), None);
        assert_eq!(argmax_f64(&[]), None);
    }

    #[test]
    fn infinities_participate_normally() {
        assert_eq!(argmax_f32(&[0.0, f32::INFINITY, 1.0]), Some(1));
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, -1.0]), Some(1));
    }
}

//! Offline-build substrates: JSON codec, CLI parsing, PRNG, timing.
//!
//! The image's vendored crate set has no serde/clap/criterion/rand, so
//! these are first-class modules of the reproduction (DESIGN.md §6).

pub mod argmax;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

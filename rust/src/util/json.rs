//! Minimal JSON codec (parser + serializer).
//!
//! Covers the full JSON grammar we produce/consume: the artifact
//! manifest, run configs, metrics JSONL and eval reports. Objects keep
//! insertion order (manifest param order is a *contract* — the rust
//! side feeds PJRT executables positionally from it).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are f64 (JSON has no integer type); use
/// [`Json::as_usize`]/[`Json::as_i64`] for integral reads.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (no dedup; later keys shadow on lookup).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (manifest
    /// parsing produces actionable messages).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report/metrics emission.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, got {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at offset {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        let seg = std::str::from_utf8(&self.b[start..end])
                            .map_err(|e| anyhow!("bad UTF-8: {e}"))?;
                        out.push_str(seg);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| anyhow!("bad number {txt:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize().unwrap(), 1);
        assert_eq!(a[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","shape":[2,3],"init":{"kind":"uniform","bound":0.03608439182435161},"ok":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".into()));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn large_ints_exact() {
        // token counts / byte sizes must round-trip exactly
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.as_i64().unwrap(), 123456789012);
        assert_eq!(v.to_string(), "123456789012");
    }
}

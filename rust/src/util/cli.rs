//! Tiny CLI argument parser (`--flag value`, `--bool-flag`, positionals).
//!
//! `repro <subcommand> [--key value]...` — enough structure for the
//! coordinator binary without the (unavailable) clap dependency.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    /// flags given without a value (`--verbose`)
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(argv("train --arch opt-mini --steps 300 --verbose")).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str_opt("arch"), Some("opt-mini"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn parse_equals_form() {
        let a = Args::parse(argv("bench --lr=0.001 --out=runs/x")).unwrap();
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert_eq!(a.str_or("out", ""), "runs/x");
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv("serve")).unwrap();
        assert_eq!(a.usize_or("batch", 8).unwrap(), 8);
        assert_eq!(a.f64_or("lr", 3e-4).unwrap(), 3e-4);
    }

    #[test]
    fn bad_values_error() {
        let a = Args::parse(argv("x --steps abc")).unwrap();
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(argv("x --dry-run")).unwrap();
        assert!(a.switch("dry-run"));
    }
}

//! Run metrics: stdout progress lines + JSONL event log.
//!
//! Every event is one JSON object per line in `<out_dir>/metrics.jsonl`
//! — the loss curves in EXPERIMENTS.md are read straight from these.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

pub struct MetricsLogger {
    file: Option<BufWriter<File>>,
    pub quiet: bool,
}

impl MetricsLogger {
    /// Log to `<dir>/metrics.jsonl` (created/truncated) and stdout.
    pub fn to_dir(dir: &Path) -> Result<MetricsLogger> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(dir.join("metrics.jsonl"))?;
        Ok(MetricsLogger { file: Some(BufWriter::new(file)), quiet: false })
    }

    /// Stdout-only logger (tests, ad-hoc runs).
    pub fn stdout() -> MetricsLogger {
        MetricsLogger { file: None, quiet: false }
    }

    pub fn event(&mut self, kind: &str, fields: Vec<(&str, Json)>) {
        let mut kv = vec![("event".to_string(), Json::Str(kind.to_string()))];
        kv.extend(fields.into_iter().map(|(k, v)| (k.to_string(), v)));
        let line = Json::Obj(kv).to_string();
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
        if !self.quiet {
            println!("{line}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn writes_jsonl() {
        let dir = std::env::temp_dir().join("dyad-metrics-test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut m = MetricsLogger::to_dir(&dir).unwrap();
        m.quiet = true;
        m.event("step", vec![("loss", num(3.5)), ("step", num(1.0))]);
        m.event("eval", vec![("valid_loss", num(3.2))]);
        drop(m);
        let text = std::fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(first.get("loss").unwrap().as_f64().unwrap(), 3.5);
    }
}

//! Training coordinator: schedule, metrics, checkpoints, the loop.
//!
//! The L3 counterpart of the paper's pretraining setup: one binary
//! drives corpus generation → tokenization → packed batching → PJRT
//! train-step calls (K optimizer steps each) → periodic validation →
//! checkpointing, entirely in rust.

pub mod checkpoint;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::MetricsLogger;
pub use schedule::LrSchedule;
pub use trainer::{TrainReport, Trainer};

//! Learning-rate schedule: linear warmup → cosine decay to a floor.
//!
//! Computed coordinator-side and fed to the train-step artifact as a
//! scalar each call (the artifact applies it uniformly across its K
//! inner microbatch steps).

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub peak: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_frac: f64,
}

impl LrSchedule {
    pub fn new(peak: f64, warmup_steps: usize, total_steps: usize, min_frac: f64) -> Self {
        LrSchedule { peak, warmup_steps, total_steps, min_frac }
    }

    pub fn at(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak * (step as f64 + 1.0) / self.warmup_steps as f64;
        }
        let decay_span = (self.total_steps.max(self.warmup_steps + 1)
            - self.warmup_steps) as f64;
        let t = ((step - self.warmup_steps) as f64 / decay_span).clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        let floor = self.peak * self.min_frac;
        floor + (self.peak - floor) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_rises_linearly() {
        let s = LrSchedule::new(1e-3, 10, 100, 0.1);
        assert!((s.at(0) - 1e-4).abs() < 1e-12);
        assert!((s.at(9) - 1e-3).abs() < 1e-12);
        assert!(s.at(4) < s.at(5));
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule::new(1e-3, 10, 100, 0.1);
        assert!((s.at(10) - 1e-3).abs() < 1e-6);
        assert!(s.at(50) < s.at(20));
        assert!((s.at(100) - 1e-4).abs() < 1e-9);
        assert!((s.at(10_000) - 1e-4).abs() < 1e-9); // clamped past end
    }

    #[test]
    fn no_warmup_edge_case() {
        let s = LrSchedule::new(5e-4, 0, 10, 0.0);
        assert!((s.at(0) - 5e-4).abs() < 1e-12);
        assert!(s.at(10) < 1e-8);
    }
}

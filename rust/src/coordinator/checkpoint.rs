//! Checkpoint manager: full train-state and params-only exports.
//!
//! The params-only file is what Table 11's "Model Checkpoint Size"
//! measures — DYAD's 3-D component tensors make it smaller than DENSE's
//! at the same architecture.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::runtime::{ArtifactSpec, Backend, TrainState};
use crate::tensor::{load_checkpoint, save_checkpoint};

pub struct CheckpointManager {
    dir: PathBuf,
}

impl CheckpointManager {
    pub fn new(dir: &Path) -> CheckpointManager {
        CheckpointManager { dir: dir.to_path_buf() }
    }

    pub fn latest_path(&self) -> PathBuf {
        self.dir.join("state.dyt")
    }

    pub fn params_path(&self) -> PathBuf {
        self.dir.join("model.dyt")
    }

    /// Save the full resumable state (params + Adam moments + step).
    /// Downloads the backend-resident state to host tensors first.
    pub fn save_state(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
        state: &TrainState,
    ) -> Result<u64> {
        let entries = state.to_tensors(backend, spec)?;
        let refs: Vec<(String, &crate::tensor::Tensor)> =
            entries.iter().map(|(n, t)| (n.clone(), t)).collect();
        save_checkpoint(&self.latest_path(), &refs)?;
        Ok(std::fs::metadata(self.latest_path())?.len())
    }

    /// Save params only; returns on-disk size in bytes (Table 11).
    pub fn save_params(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
        state: &TrainState,
    ) -> Result<u64> {
        let entries = state.params_to_tensors(backend, spec)?;
        let refs: Vec<(String, &crate::tensor::Tensor)> =
            entries.iter().map(|(n, t)| (n.clone(), t)).collect();
        save_checkpoint(&self.params_path(), &refs)?;
        Ok(std::fs::metadata(self.params_path())?.len())
    }

    /// Restore a full state saved by [`CheckpointManager::save_state`]
    /// and stage it onto `backend` once.
    pub fn load_state(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
    ) -> Result<TrainState> {
        let entries = load_checkpoint(&self.latest_path())
            .with_context(|| format!("load {}", self.latest_path().display()))?;
        TrainState::from_tensors(backend, spec, &entries)
    }

    pub fn has_state(&self) -> bool {
        self.latest_path().exists()
    }
}

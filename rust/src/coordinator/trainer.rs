//! The pretraining loop: nanoBabyLM corpus → packed batches →
//! train-step calls on the configured backend → periodic validation →
//! checkpoints. Runs artifact-free on the default native backend
//! (layer-module autodiff) and unchanged on XLA.
//!
//! One `train_call` advances K optimizer steps (the artifact's inner
//! `lax.scan`); the coordinator recomputes the LR schedule between
//! calls, tracks loss/throughput and records everything as JSONL.

use anyhow::{Context, Result};

use super::checkpoint::CheckpointManager;
use super::metrics::MetricsLogger;
use super::schedule::LrSchedule;
use crate::config::TrainConfig;
use crate::data::{Grammar, TokenDataset, Tokenizer};
use crate::runtime::{Backend, Executable, TrainState};
use crate::util::json::{num, s};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    /// Mean loss over the first / last 10% of microbatch losses.
    pub first_loss: f64,
    pub final_loss: f64,
    pub valid_loss: f64,
    pub losses: Vec<f32>,
    pub ms_per_call: Summary,
    pub tokens_seen: usize,
    pub params: usize,
    pub checkpoint_bytes: u64,
}

pub struct Trainer {
    cfg: TrainConfig,
    k_micro: usize,
    batch: usize,
    seq: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        Trainer { cfg, k_micro: 0, batch: 0, seq: 0 }
    }

    /// Run the full pretraining loop on `backend` (for the xla backend
    /// that means the artifact dir in `cfg.artifacts_dir`).
    pub fn run(&mut self, backend: &dyn Backend, log: &mut MetricsLogger) -> Result<TrainReport> {
        let cfg = &self.cfg;
        // Pick the K=8 artifact; fall back to K=1 if absent.
        let art = backend
            .load(&cfg.train_artifact(8))
            .or_else(|_| backend.load(&cfg.train_artifact(1)))
            .context("load train artifact")?;
        let k = art.spec().meta_usize("k_micro")?;
        let b = art.spec().meta_usize("batch")?;
        let seq = art.spec().meta_usize("seq")?;
        self.k_micro = k;
        self.batch = b;
        self.seq = seq;

        // Data pipeline: grammar corpus -> tokenizer -> packed dataset.
        let grammar = Grammar::new();
        let tokenizer = Tokenizer::from_words(&grammar.vocabulary());
        let arch = backend.manifest().arch(&cfg.arch)?;
        tokenizer.check_fits(arch.vocab)?;
        let words = grammar.corpus(cfg.corpus_tokens, cfg.seed ^ 0xC0FFEE);
        let mut stream = Vec::with_capacity(words.len() + words.len() / 8);
        for w in &words {
            stream.push(tokenizer.id(w));
            if w == "." || w == "?" {
                stream.push(crate::data::tokenizer::EOS);
            }
        }
        let data = TokenDataset::from_stream(&stream, seq, cfg.valid_frac, cfg.seed)?;
        log.event(
            "setup",
            vec![
                ("arch", s(&cfg.arch)),
                ("variant", s(&cfg.variant)),
                ("vocab", num(tokenizer.vocab_size() as f64)),
                ("train_sequences", num(data.n_train() as f64)),
                ("valid_sequences", num(data.n_valid() as f64)),
                ("k_micro", num(k as f64)),
                ("batch", num(b as f64)),
                ("seq", num(seq as f64)),
                ("params", num(art.spec().param_count() as f64)),
            ],
        );

        // Init or resume. The state stages onto the backend once here;
        // each train_call below uploads only the token batch + scalars.
        let ckpt = CheckpointManager::new(&cfg.out_dir);
        let mut state = if ckpt.has_state() {
            log.event("resume", vec![("from", s(&ckpt.latest_path().to_string_lossy()))]);
            ckpt.load_state(backend, art.spec())?
        } else {
            TrainState::init(backend, art.spec(), cfg.seed)?
        };

        let eval_art = backend.load(&cfg.artifact("eval_loss")).ok();
        let schedule =
            LrSchedule::new(cfg.lr, cfg.warmup_steps, cfg.steps, cfg.min_lr_frac);
        let mut rng = Rng::new(cfg.seed ^ 0xBA7C4);
        let n_calls = cfg.steps.div_ceil(k);
        let mut all_losses: Vec<f32> = Vec::with_capacity(n_calls * k);
        let mut call_ms: Vec<f64> = Vec::with_capacity(n_calls);
        let mut valid_loss = f64::NAN;
        let run_timer = Timer::start();

        for call in 0..n_calls {
            let step = state.step as usize;
            let lr = schedule.at(step) as f32;
            let tokens = data.train_batch(k, b, &mut rng);
            let t = Timer::start();
            let losses = state.train_call(backend, art.as_ref(), lr, vec![tokens])?;
            call_ms.push(t.elapsed_ms());
            all_losses.extend_from_slice(&losses);

            if (call + 1) % cfg.log_every.max(1) == 0 || call + 1 == n_calls {
                let recent: f64 = losses.iter().map(|&x| x as f64).sum::<f64>()
                    / losses.len() as f64;
                log.event(
                    "step",
                    vec![
                        ("step", num(state.step as f64)),
                        ("loss", num(recent)),
                        ("lr", num(lr as f64)),
                        ("ms_per_call", num(*call_ms.last().unwrap())),
                        (
                            "tokens_per_s",
                            num((k * b * seq) as f64
                                / (call_ms.last().unwrap() / 1e3)),
                        ),
                    ],
                );
            }
            if let Some(ev) = &eval_art {
                let every = cfg.eval_every.max(1);
                if (call + 1) % every.div_ceil(k).max(1) == 0 || call + 1 == n_calls {
                    valid_loss = self.valid_loss(backend, ev.as_ref(), &state, &data)?;
                    log.event(
                        "eval",
                        vec![
                            ("step", num(state.step as f64)),
                            ("valid_loss", num(valid_loss)),
                        ],
                    );
                }
            }
        }

        let state_bytes = ckpt.save_state(backend, art.spec(), &state)?;
        let params_bytes = ckpt.save_params(backend, art.spec(), &state)?;
        let n = all_losses.len();
        let head = &all_losses[..(n / 10).max(1)];
        let tail = &all_losses[n - (n / 10).max(1)..];
        let report = TrainReport {
            steps: state.step as usize,
            first_loss: head.iter().map(|&x| x as f64).sum::<f64>() / head.len() as f64,
            final_loss: tail.iter().map(|&x| x as f64).sum::<f64>() / tail.len() as f64,
            valid_loss,
            losses: all_losses,
            ms_per_call: Summary::of(&call_ms),
            tokens_seen: n_calls * k * b * seq,
            params: art.spec().param_count(),
            checkpoint_bytes: params_bytes,
        };
        log.event(
            "done",
            vec![
                ("steps", num(report.steps as f64)),
                ("first_loss", num(report.first_loss)),
                ("final_loss", num(report.final_loss)),
                ("valid_loss", num(report.valid_loss)),
                ("wall_s", num(run_timer.elapsed_s())),
                ("ms_per_call_mean", num(report.ms_per_call.mean)),
                ("tokens_seen", num(report.tokens_seen as f64)),
                ("state_ckpt_bytes", num(state_bytes as f64)),
                ("params_ckpt_bytes", num(params_bytes as f64)),
            ],
        );
        Ok(report)
    }

    fn valid_loss(
        &self,
        backend: &dyn Backend,
        eval_art: &dyn Executable,
        state: &TrainState,
        data: &TokenDataset,
    ) -> Result<f64> {
        let b = eval_art.spec().meta_usize("batch")?;
        let n_batches = (data.n_valid() / b).clamp(1, 4);
        let mut total = 0.0;
        for i in 0..n_batches {
            let tokens = data.valid_batch(b, i * b);
            let out = crate::eval::run_with_params(backend, eval_art, state, vec![tokens])?;
            total += out[0].as_f32()?[0] as f64;
        }
        Ok(total / n_batches as f64)
    }
}

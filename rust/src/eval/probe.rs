//! Probe-task evaluation (the paper's GLUE+ finetuning benchmark).
//!
//! Transfer protocol: freeze the pretrained LM, extract mean-pooled
//! hidden features via the `features` artifact, then train a logistic
//! -regression head per task **in rust** (plain SGD + momentum) and
//! report held-out accuracy. This keeps the paper's question — does
//! the representation transfer? — while avoiding per-task re-lowering
//! (DESIGN.md §6).

use anyhow::Result;

use super::run_with_params;
use crate::data::dataset::pad_batch;
use crate::data::grammar::{Grammar, ProbeTask};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::{Backend, Executable, TrainState};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ProbeResult {
    /// (task name, test accuracy, n train, n test)
    pub per_task: Vec<(String, f64, usize, usize)>,
    pub mean: f64,
}

/// Extract features for a set of token sequences.
fn features_for(
    backend: &dyn Backend,
    art: &dyn Executable,
    state: &TrainState,
    seqs: &[Vec<i32>],
    b: usize,
    s: usize,
    d: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(seqs.len());
    for chunk in seqs.chunks(b) {
        let (tokens, mask) = pad_batch(chunk, b, s)?;
        let res = run_with_params(backend, art, state, vec![tokens, mask])?;
        let flat = res[0].as_f32()?;
        for i in 0..chunk.len() {
            out.push(flat[i * d..(i + 1) * d].to_vec());
        }
    }
    Ok(out)
}

/// Binary logistic-regression head trained with SGD + momentum.
pub struct LogisticHead {
    pub w: Vec<f32>,
    pub b: f32,
}

impl LogisticHead {
    pub fn train(
        xs: &[Vec<f32>],
        ys: &[usize],
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> LogisticHead {
        let d = xs[0].len();
        let mut w = vec![0.0f32; d];
        let mut b = 0.0f32;
        let mut mw = vec![0.0f32; d];
        let mut mb = 0.0f32;
        let momentum = 0.9f32;
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut rng = Rng::new(seed);
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let x = &xs[i];
                let z: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + b;
                let p = 1.0 / (1.0 + (-z).exp());
                let err = p - ys[i] as f32; // dL/dz for BCE
                for j in 0..d {
                    mw[j] = momentum * mw[j] + err * x[j];
                    w[j] -= lr * mw[j];
                }
                mb = momentum * mb + err;
                b -= lr * mb;
            }
        }
        LogisticHead { w, b }
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let z: f32 = x.iter().zip(&self.w).map(|(a, b)| a * b).sum::<f32>() + self.b;
        (z > 0.0) as usize
    }
}

pub fn evaluate(
    backend: &dyn Backend,
    features_art: &dyn Executable,
    state: &TrainState,
    tokenizer: &Tokenizer,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> Result<ProbeResult> {
    let grammar = Grammar::new();
    let b = features_art.spec().meta_usize("batch")?;
    let s = features_art.spec().meta_usize("seq")?;
    let d = features_art.spec().outputs[0].shape[1];
    let mut per = Vec::new();
    let mut rng = Rng::new(seed);
    for task in ProbeTask::ALL {
        let mut seqs = Vec::with_capacity(n_train + n_test);
        let mut labels = Vec::with_capacity(n_train + n_test);
        for _ in 0..n_train + n_test {
            let (words, label) = grammar.probe_example(task, &mut rng);
            seqs.push(tokenizer.encode_sentence(&words));
            labels.push(label);
        }
        let feats = features_for(backend, features_art, state, &seqs, b, s, d)?;
        let (train_x, test_x) = feats.split_at(n_train);
        let (train_y, test_y) = labels.split_at(n_train);
        let head = LogisticHead::train(train_x, train_y, 30, 0.01, seed ^ 0x9E37);
        let correct = test_x
            .iter()
            .zip(test_y)
            .filter(|(x, &y)| head.predict(x) == y)
            .count();
        per.push((
            task.name().to_string(),
            correct as f64 / n_test as f64,
            n_train,
            n_test,
        ));
    }
    let mean = per.iter().map(|(_, a, _, _)| a).sum::<f64>() / per.len() as f64;
    Ok(ProbeResult { per_task: per, mean })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logistic_head_learns_separable_data() {
        let mut rng = Rng::new(0);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..200 {
            let label = i % 2;
            let center = if label == 1 { 1.0 } else { -1.0 };
            xs.push(vec![
                center + 0.3 * rng.normal() as f32,
                -center + 0.3 * rng.normal() as f32,
            ]);
            ys.push(label);
        }
        let head = LogisticHead::train(&xs[..160], &ys[..160], 20, 0.1, 1);
        let acc = xs[160..]
            .iter()
            .zip(&ys[160..])
            .filter(|(x, &y)| head.predict(x) == y)
            .count() as f64
            / 40.0;
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn logistic_head_chance_on_random_labels() {
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f32>> =
            (0..100).map(|_| vec![rng.f32(), rng.f32()]).collect();
        let ys: Vec<usize> = (0..100).map(|_| rng.below(2)).collect();
        let head = LogisticHead::train(&xs[..80], &ys[..80], 10, 0.05, 3);
        let acc = xs[80..]
            .iter()
            .zip(&ys[80..])
            .filter(|(x, &y)| head.predict(x) == y)
            .count() as f64
            / 20.0;
        assert!(acc < 0.95); // must not hallucinate structure
    }
}

//! §3.4.5: the MNIST vision probe — DENSE vs DYAD-IT hidden layers.
//!
//! Trains the 784→256→256→10 MLP artifact on procedural digits, then
//! reports test accuracy and the "ff-only" time per minibatch (the two
//! swap-site linears), mirroring the paper's CPU experiment. Runs on
//! any backend — the native backend trains it entirely in Rust.

use anyhow::{Context, Result};

use crate::bench_support::{bench_artifact, BenchOpts};
use crate::data::mnist::MnistGen;
use crate::runtime::{Backend, Executable, TrainState};
use crate::util::timer::Timer;

#[derive(Debug, Clone)]
pub struct MnistOutcome {
    pub variant: String,
    pub test_accuracy: f64,
    pub hidden_fwd_ms: f64,
    pub final_loss: f64,
    pub train_wall_s: f64,
    pub params: usize,
}

/// Train + evaluate one variant. `steps` counts optimizer steps.
pub fn run_variant(
    backend: &dyn Backend,
    variant: &str,
    steps: usize,
    seed: u64,
) -> Result<MnistOutcome> {
    let train_art = backend
        .load(&format!("mnist/{variant}/train_k4"))
        .with_context(|| format!("mnist train artifact for {variant}"))?;
    let acc_art = backend.load(&format!("mnist/{variant}/accuracy"))?;
    let k = train_art.spec().meta_usize("k_micro")?;
    let b = train_art.spec().meta_usize("batch")?;
    // params/m/v stage onto the backend once; each call uploads only
    // the fresh microbatches
    let mut state = TrainState::init(backend, train_art.spec(), seed)?;
    let mut gen = MnistGen::new(seed ^ 0xD161);
    let timer = Timer::start();
    let mut final_loss = f64::NAN;
    let n_calls = steps.div_ceil(k);
    for _ in 0..n_calls {
        let (images, labels) = gen.train_batch(k, b);
        let losses =
            state.train_call(backend, train_art.as_ref(), 1e-3, vec![images, labels])?;
        final_loss = *losses.last().unwrap() as f64;
    }
    let train_wall_s = timer.elapsed_s();

    // held-out accuracy over fresh renders (generator is the population)
    let mut test_gen = MnistGen::new(seed ^ 0x7E57);
    let mut correct = 0usize;
    let mut total = 0usize;
    let eval_batches = 20;
    for _ in 0..eval_batches {
        let (images, labels) = test_gen.batch(b);
        let out = crate::eval::run_with_params(
            backend,
            acc_art.as_ref(),
            &state,
            vec![images, labels],
        )?;
        correct += out[0].as_i32()?[0] as usize;
        total += b;
    }

    let fwd = bench_artifact(
        backend,
        &format!("mnist/{variant}/hidden_fwd"),
        BenchOpts { warmup: 3, reps: 20, seed },
    )?;

    Ok(MnistOutcome {
        variant: variant.to_string(),
        test_accuracy: correct as f64 / total as f64,
        hidden_fwd_ms: fwd.mean,
        final_loss,
        train_wall_s,
        params: train_art.spec().param_count(),
    })
}

/// The full §3.4.5 comparison; prints the paper-shaped summary.
pub fn run(
    backend: &dyn Backend,
    steps: usize,
    only_variant: Option<&str>,
    seed: u64,
) -> Result<()> {
    let variants: Vec<&str> = match only_variant {
        Some(v) => vec![v],
        None => vec!["dense", "dyad_it"],
    };
    let mut outcomes = Vec::new();
    for v in variants {
        println!("training mnist/{v} for {steps} steps ...");
        let o = run_variant(backend, v, steps, seed)?;
        println!(
            "  {}: test_acc={:.2}% hidden_fwd={:.3} ms/minibatch params={} \
             final_loss={:.4} ({:.1}s train)",
            o.variant,
            100.0 * o.test_accuracy,
            o.hidden_fwd_ms,
            o.params,
            o.final_loss,
            o.train_wall_s
        );
        outcomes.push(o);
    }
    if outcomes.len() == 2 {
        let (d, y) = (&outcomes[0], &outcomes[1]);
        println!(
            "\n§3.4.5 shape check: dyad within {:.1} pts of dense accuracy \
             (paper: 98.51 vs 98.43); ff speedup {:.2}x (paper: 1.29x)",
            100.0 * (d.test_accuracy - y.test_accuracy).abs(),
            d.hidden_fwd_ms / y.hidden_fwd_ms
        );
    }
    Ok(())
}

//! Quality report: the Table-2-shaped aggregate over the three suites.

use std::path::Path;

use anyhow::Result;

use super::{BlimpResult, McqResult, ProbeResult};
use crate::util::json::{arr, num, obj, s, Json};

#[derive(Debug, Clone)]
pub struct QualityReport {
    pub arch: String,
    pub variant: String,
    pub blimp: BlimpResult,
    pub mcq: McqResult,
    pub probe: ProbeResult,
    pub valid_loss: f64,
    pub final_train_loss: f64,
    pub params: usize,
    pub checkpoint_bytes: u64,
}

impl QualityReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("arch", s(&self.arch)),
            ("variant", s(&self.variant)),
            ("valid_loss", num(self.valid_loss)),
            ("final_train_loss", num(self.final_train_loss)),
            ("params", num(self.params as f64)),
            ("checkpoint_bytes", num(self.checkpoint_bytes as f64)),
            ("blimp_mean", num(self.blimp.mean)),
            (
                "blimp",
                arr(self.blimp.per_phenomenon.iter().map(|(n, a, c)| {
                    obj(vec![("name", s(n)), ("acc", num(*a)), ("n", num(*c as f64))])
                })),
            ),
            ("mcq_mean", num(self.mcq.mean)),
            (
                "mcq",
                arr(self.mcq.per_task.iter().map(|(n, a, c)| {
                    obj(vec![("name", s(n)), ("acc", num(*a)), ("n", num(*c as f64))])
                })),
            ),
            ("probe_mean", num(self.probe.mean)),
            (
                "probe",
                arr(self.probe.per_task.iter().map(|(n, a, tr, te)| {
                    obj(vec![
                        ("name", s(n)),
                        ("acc", num(*a)),
                        ("n_train", num(*tr as f64)),
                        ("n_test", num(*te as f64)),
                    ])
                })),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Human-readable table (paper Table 2 row shape).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "== {} / {} ==\n  params             {:>12}\n  ckpt bytes         {:>12}\n  valid loss         {:>12.4}\n",
            self.arch, self.variant, self.params, self.checkpoint_bytes, self.valid_loss
        ));
        out.push_str(&format!("  BLIMP mean         {:>12.4}\n", self.blimp.mean));
        for (name, acc, _) in &self.blimp.per_phenomenon {
            out.push_str(&format!("    {name:<24} {acc:.4}\n"));
        }
        out.push_str(&format!("  OPENLLM(mcq) mean  {:>12.4}\n", self.mcq.mean));
        for (name, acc, _) in &self.mcq.per_task {
            out.push_str(&format!("    {name:<24} {acc:.4}\n"));
        }
        out.push_str(&format!("  GLUE(probe) mean   {:>12.4}\n", self.probe.mean));
        for (name, acc, _, _) in &self.probe.per_task {
            out.push_str(&format!("    {name:<24} {acc:.4}\n"));
        }
        out
    }
}

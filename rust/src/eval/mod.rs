//! Evaluation harnesses: the paper's three benchmark families, rebuilt
//! on the nanoBabyLM grammar (DESIGN.md §6 substitutions).
//!
//! * `blimp`  — zero-shot minimal pairs (BLIMP): P(good) > P(bad).
//! * `mcq`    — few-shot multiple choice (OPENLLM): length-normalised
//!   choice log-prob under a k-shot prompt.
//! * `probe`  — finetuning-style transfer (GLUE): frozen LM features +
//!   a logistic-regression head trained in rust.
//! * `report` — aggregates the three into a Table-2-shaped report.
//!
//! All harnesses run against the [`Executable`] trait, so they work on
//! the native backend and (with the `xla` feature) on PJRT alike.

pub mod blimp;
pub mod mcq;
pub mod mnist_probe;
pub mod probe;
pub mod report;

pub use blimp::BlimpResult;
pub use mcq::McqResult;
pub use probe::ProbeResult;
pub use report::QualityReport;

use anyhow::Result;

use crate::runtime::{Backend, Bindings, DeviceTensor, Executable, Role, TrainState};
use crate::tensor::Tensor;

/// Run a params+data artifact (score/features/next_logits/...) against
/// the current state. The state's parameter handles stay resident on
/// `backend`; only the positional `data` tensors are uploaded per
/// call, and the outputs are downloaded back to host tensors.
pub fn run_with_params(
    backend: &dyn Backend,
    art: &dyn Executable,
    state: &TrainState,
    data: Vec<Tensor>,
) -> Result<Vec<Tensor>> {
    let spec = art.spec();
    let n_data = spec.inputs.iter().filter(|i| i.role == Role::Data).count();
    anyhow::ensure!(
        data.len() == n_data,
        "{}: {} data tensors, manifest wants {}",
        spec.name,
        data.len(),
        n_data
    );
    let mut bind = Bindings::new(art);
    bind.bind_role(Role::Param, state.param_handles())?;
    let dev: Vec<DeviceTensor> = data
        .into_iter()
        .map(|t| backend.upload(t))
        .collect::<Result<_>>()?;
    let refs: Vec<&DeviceTensor> = dev.iter().collect();
    let out = bind.call(&refs)?;
    // fresh outputs are sole-owner handles: copy-free on native
    out.into_iter().map(|d| backend.take(d)).collect()
}

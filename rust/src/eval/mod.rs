//! Evaluation harnesses: the paper's three benchmark families, rebuilt
//! on the nanoBabyLM grammar (DESIGN.md §6 substitutions).
//!
//! * `blimp`  — zero-shot minimal pairs (BLIMP): P(good) > P(bad).
//! * `mcq`    — few-shot multiple choice (OPENLLM): length-normalised
//!   choice log-prob under a k-shot prompt.
//! * `probe`  — finetuning-style transfer (GLUE): frozen LM features +
//!   a logistic-regression head trained in rust.
//! * `report` — aggregates the three into a Table-2-shaped report.

pub mod blimp;
pub mod mcq;
pub mod mnist_probe;
pub mod probe;
pub mod report;

pub use blimp::BlimpResult;
pub use mcq::McqResult;
pub use probe::ProbeResult;
pub use report::QualityReport;

use anyhow::{Context, Result};

use crate::runtime::{tensor_to_literal, Loaded, TrainState};
use crate::tensor::Tensor;

/// Run a params+data artifact (score/features/next_logits/...) against
/// the current state. `data` are positional tensors for the Data inputs.
pub fn run_with_params(
    art: &Loaded,
    state: &TrainState,
    data: &[Tensor],
) -> Result<Vec<xla::Literal>> {
    let data_specs: Vec<_> = art
        .spec
        .inputs
        .iter()
        .filter(|i| i.role == crate::runtime::Role::Data)
        .collect();
    anyhow::ensure!(
        data.len() == data_specs.len(),
        "{}: {} data tensors, manifest wants {}",
        art.spec.name,
        data.len(),
        data_specs.len()
    );
    let data_lits: Vec<xla::Literal> = data
        .iter()
        .zip(&data_specs)
        .map(|(t, s)| tensor_to_literal(t, s))
        .collect::<Result<_>>()
        .context("stage data")?;
    let mut inputs: Vec<&xla::Literal> = state.param_literals().iter().collect();
    inputs.extend(data_lits.iter());
    art.run_literals(&inputs)
}

//! Few-shot multiple-choice evaluation (the paper's OPENLLM suite).
//!
//! LMEvalHarness protocol: build a k-shot prompt of solved examples,
//! append the query stem, then score each choice continuation by its
//! length-normalised log-probability under the model (mask restricted
//! to the choice tokens). Accuracy = argmax matches the gold choice.

use anyhow::Result;

use super::run_with_params;
use crate::data::grammar::{Grammar, McqTask};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::{Backend, Executable, TrainState};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct McqResult {
    /// (task name, accuracy, n items)
    pub per_task: Vec<(String, f64, usize)>,
    pub mean: f64,
}

/// Score (tokens, mask) rows; returns (sum_logp, n_tok) per row.
fn score_rows(
    backend: &dyn Backend,
    art: &dyn Executable,
    state: &TrainState,
    rows: &[(Vec<i32>, Vec<f32>)],
    b: usize,
    s: usize,
) -> Result<Vec<(f64, f64)>> {
    let mut out = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(b) {
        let mut toks = vec![0i32; b * s];
        let mut mask = vec![0.0f32; b * s];
        for (i, (t, m)) in chunk.iter().enumerate() {
            let n = t.len().min(s);
            let start = t.len() - n; // keep most recent context
            toks[i * s..i * s + n].copy_from_slice(&t[start..]);
            mask[i * s..i * s + n].copy_from_slice(&m[start..]);
        }
        let res = run_with_params(
            backend,
            art,
            state,
            vec![
                Tensor::from_i32(&[b, s], toks)?,
                Tensor::from_f32(&[b, s], mask)?,
            ],
        )?;
        let sums = res[0].as_f32()?;
        let counts = res[1].as_f32()?;
        for i in 0..chunk.len() {
            out.push((sums[i] as f64, counts[i] as f64));
        }
    }
    Ok(out)
}

pub fn evaluate(
    backend: &dyn Backend,
    score_art: &dyn Executable,
    state: &TrainState,
    tokenizer: &Tokenizer,
    items_per_task: usize,
    shots: usize,
    seed: u64,
) -> Result<McqResult> {
    let grammar = Grammar::new();
    let b = score_art.spec().meta_usize("batch")?;
    let s = score_art.spec().meta_usize("seq")?;
    let mut per = Vec::new();
    let mut rng = Rng::new(seed);
    for task in McqTask::ALL {
        let mut correct = 0usize;
        for _ in 0..items_per_task {
            // k-shot prompt: solved examples joined with <eos>.
            let mut prefix: Vec<i32> = Vec::new();
            for _ in 0..shots {
                let shot = grammar.mcq(task, &mut rng);
                let mut words = shot.stem.clone();
                words.extend(shot.choices[shot.correct].clone());
                prefix.extend(tokenizer.encode_sentence(&words));
            }
            let item = grammar.mcq(task, &mut rng);
            let mut rows: Vec<(Vec<i32>, Vec<f32>)> = Vec::new();
            for choice in &item.choices {
                let mut toks = prefix.clone();
                toks.extend(tokenizer.encode(&item.stem));
                let stem_len = toks.len();
                toks.extend(tokenizer.encode(choice));
                let mut mask = vec![0.0f32; toks.len()];
                for m in mask.iter_mut().skip(stem_len) {
                    *m = 1.0;
                }
                rows.push((toks, mask));
            }
            let scored = score_rows(backend, score_art, state, &rows, b, s)?;
            let normalized: Vec<f64> =
                scored.iter().map(|(s, n)| s / n.max(1.0)).collect();
            let pick = crate::util::argmax::argmax_f64(&normalized).unwrap_or(0);
            if pick == item.correct {
                correct += 1;
            }
        }
        per.push((
            task.name().to_string(),
            correct as f64 / items_per_task as f64,
            items_per_task,
        ));
    }
    let mean = per.iter().map(|(_, a, _)| a).sum::<f64>() / per.len() as f64;
    Ok(McqResult { per_task: per, mean })
}

//! Zero-shot minimal-pair evaluation (the paper's BLIMP benchmark).
//!
//! For each phenomenon, generate N grammatical/ungrammatical twins and
//! count how often the LM assigns the grammatical member a higher
//! summed log-probability — BLIMP's exact protocol.

use anyhow::Result;

use super::run_with_params;
use crate::data::dataset::pad_batch;
use crate::data::grammar::{Grammar, Phenomenon};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::{Backend, Executable, TrainState};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct BlimpResult {
    /// (phenomenon name, accuracy, n pairs)
    pub per_phenomenon: Vec<(String, f64, usize)>,
    pub mean: f64,
}

/// Score a batch of token sequences; returns per-sequence summed logp.
fn score_batch(
    backend: &dyn Backend,
    art: &dyn Executable,
    state: &TrainState,
    seqs: &[Vec<i32>],
    b: usize,
    s: usize,
) -> Result<Vec<f64>> {
    let (tokens, mask) = pad_batch(seqs, b, s)?;
    let out = run_with_params(backend, art, state, vec![tokens, mask])?;
    let sums = out[0].as_f32()?;
    Ok(sums[..seqs.len()].iter().map(|&x| x as f64).collect())
}

pub fn evaluate(
    backend: &dyn Backend,
    score_art: &dyn Executable,
    state: &TrainState,
    tokenizer: &Tokenizer,
    pairs_per_phenomenon: usize,
    seed: u64,
) -> Result<BlimpResult> {
    let grammar = Grammar::new();
    let b = score_art.spec().meta_usize("batch")?;
    let s = score_art.spec().meta_usize("seq")?;
    let mut per = Vec::new();
    let mut rng = Rng::new(seed);
    for ph in Phenomenon::ALL {
        let mut correct = 0usize;
        let mut ties = 0usize;
        let mut pending: Vec<Vec<i32>> = Vec::new();
        let mut n_done = 0usize;
        let flush =
            |pending: &mut Vec<Vec<i32>>, correct: &mut usize, ties: &mut usize|
             -> Result<()> {
                // pending holds alternating good/bad sequences
                for chunk in pending.chunks(b) {
                    let scores = score_batch(backend, score_art, state, chunk, b, s)?;
                    for pair in scores.chunks_exact(2) {
                        if pair[0] > pair[1] {
                            *correct += 1;
                        } else if pair[0] == pair[1] {
                            *ties += 1;
                        }
                    }
                }
                pending.clear();
                Ok(())
            };
        for _ in 0..pairs_per_phenomenon {
            let p = grammar.minimal_pair(ph, &mut rng);
            pending.push(tokenizer.encode_sentence(&p.good));
            pending.push(tokenizer.encode_sentence(&p.bad));
            n_done += 1;
            if pending.len() + 2 > b - (b % 2) {
                flush(&mut pending, &mut correct, &mut ties)?;
            }
        }
        flush(&mut pending, &mut correct, &mut ties)?;
        // ties count half (random-guess convention)
        let acc = (correct as f64 + 0.5 * ties as f64) / n_done as f64;
        per.push((ph.name().to_string(), acc, n_done));
    }
    let mean = per.iter().map(|(_, a, _)| a).sum::<f64>() / per.len() as f64;
    Ok(BlimpResult { per_phenomenon: per, mean })
}

//! The serving loop: backend-owning worker thread + request channels.
//!
//! Backend handles are not `Send` (the PJRT client isn't), so the
//! worker thread *creates* its own backend from the config; clients
//! interact through mpsc channels. The worker uploads the model
//! weights onto its backend **once** at startup and binds them
//! resident (`Bindings`); the per-request hot path stages only the
//! padded token batches, never the weights. Scoring requests are
//! dynamically batched (see `Batcher`); generation requests run
//! through a per-worker `DecodeSession` — a continuous batcher over
//! the KV-cache `decode_step` artifact where each engine call
//! advances every active generation by one token, new requests are
//! admitted into free cache lanes at step boundaries, and finished
//! ones retire immediately. Per generated token the worker stages one
//! token id and one reset flag per lane up, one logits row per lane
//! down — O(1) traffic and O(prefix) FLOPs saved versus the legacy
//! full-recompute loop (still available as the parity oracle via
//! [`ServeConfig::legacy_generate`]).
//!
//! [`ServerHandle`] runs exactly one worker — the direct,
//! single-shard path. The sharded front-end that fans requests out to
//! several of these workers is [`super::Router`]; both speak the same
//! [`Request`] enum, and the worker loop here is the unit of sharding
//! (per-worker backend, per-worker resident weights + KV cache,
//! per-worker [`ServeStats`]).

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::Batcher;
use super::router::{DispatchPolicy, WorkerShared};
use super::stats::ServeStats;
use crate::coordinator::checkpoint::CheckpointManager;
use crate::data::dataset::pad_batch;
use crate::runtime::catalog::mmap::MappedWeights;
use crate::runtime::{
    open_backend_sized, Backend, BackendKind, Bindings, DeviceTensor, Executable, Role,
    TrainState,
};
use crate::tensor::Tensor;
use crate::util::argmax::argmax_f32;
use crate::util::timer::Timer;

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Which execution backend each worker opens (native by default).
    pub backend: BackendKind,
    /// Artifact dir for the xla backend (unused by native).
    pub artifacts_dir: PathBuf,
    pub arch: String,
    pub variant: String,
    /// Load params from this run dir's checkpoint if present.
    pub checkpoint_dir: Option<PathBuf>,
    pub max_batch: usize,
    pub window_ms: u64,
    pub seed: u64,
    /// Worker shards opened by [`super::Router::start`] (each one a
    /// backend-owning thread with its weights bound resident).
    /// [`ServerHandle::start`] ignores this and always runs one.
    pub n_workers: usize,
    /// How the router spreads requests over the shards.
    pub dispatch: DispatchPolicy,
    /// Worker-pool size each shard's native backend runs on. `None`
    /// (the default) splits the machine evenly:
    /// `num_threads() / n_workers`, min 1 — so a fleet never
    /// oversubscribes the cores the way N full-width shards would.
    /// `serve --threads-per-worker N` overrides the split.
    pub threads_per_worker: Option<usize>,
    /// Route Generate requests through the legacy full-context
    /// recompute loop (`next_logits` once per token) instead of the
    /// KV-cache `DecodeSession`. The legacy loop costs O(prefix) per
    /// token and serializes generations; it stays around as the
    /// reference the incremental path is parity-tested against.
    pub legacy_generate: bool,
    /// Serve parameters from a DYW1 weight file
    /// ([`crate::runtime::catalog::mmap`]) mapped read-only instead of
    /// initialising them on the heap. Every shard process of a fleet
    /// maps the *same* file, so fleet resident weight bytes stay ~1×
    /// (shared page cache), not N×. Takes precedence over
    /// `checkpoint_dir`.
    pub weights_file: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backend: BackendKind::Native,
            artifacts_dir: "artifacts".into(),
            arch: "opt-mini".into(),
            variant: "dyad_it".into(),
            checkpoint_dir: None,
            max_batch: 8,
            window_ms: 5,
            seed: 7,
            n_workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            threads_per_worker: None,
            legacy_generate: false,
            weights_file: None,
        }
    }
}

/// Where a worker's reply goes: an in-process channel or a network
/// connection's frame queue.
///
/// Every consumer of [`Request`] used to hold a bare
/// `Sender<Result<..>>`; the TCP front-end (`serve::net`) needs to
/// multiplex many in-flight requests onto one connection instead, so
/// replies carry a request id and an encoder into the wire format.
/// In-process callers are unchanged (`sender.into()`); the worker loop
/// just calls [`ReplySink::send`] either way.
pub enum ReplySink<T> {
    /// In-process reply channel. Dropping it (worker crash, drained
    /// queue) disconnects the receiver, so waiting clients observe an
    /// error — never a hang.
    Chan(Sender<T>),
    /// Network reply: encode `(id, value)` into a wire frame and push
    /// it onto the connection's shared writer queue. The remote client
    /// correlates on `id`.
    Wire {
        id: u64,
        tx: Sender<Vec<u8>>,
        encode: fn(u64, T) -> Vec<u8>,
    },
}

impl<T> ReplySink<T> {
    /// Deliver the reply; a gone receiver is the receiver's problem.
    pub fn send(&self, value: T) {
        match self {
            ReplySink::Chan(tx) => {
                let _ = tx.send(value);
            }
            ReplySink::Wire { id, tx, encode } => {
                let _ = tx.send(encode(*id, value));
            }
        }
    }
}

impl<T> From<Sender<T>> for ReplySink<T> {
    fn from(tx: Sender<T>) -> Self {
        ReplySink::Chan(tx)
    }
}

pub enum Request {
    /// Sum log-probability of a token sequence.
    Score {
        tokens: Vec<i32>,
        resp: ReplySink<Result<f64, String>>,
    },
    /// Greedy continuation of a prompt.
    Generate {
        prompt: Vec<i32>,
        max_new: usize,
        resp: ReplySink<Result<Vec<i32>, String>>,
    },
    Stats {
        resp: ReplySink<ServeStats>,
    },
    Shutdown,
    /// Failure-injection hook (tests, soak runs): the receiving worker
    /// thread panics, simulating a shard crash. The router's death
    /// detection turns the fallout into error replies, never hangs.
    #[doc(hidden)]
    Crash,
}

pub struct ServerHandle {
    tx: Sender<Request>,
    join: Option<JoinHandle<Result<()>>>,
}

impl ServerHandle {
    pub fn start(cfg: ServeConfig) -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(WorkerShared::new());
        // xtask:allow(thread_spawn): the single-worker server thread is
        // a long-lived backend owner, not kernel parallelism.
        let join = std::thread::spawn(move || worker(cfg, rx, shared));
        ServerHandle { tx, join: Some(join) }
    }

    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    pub fn score(&self, tokens: Vec<i32>) -> Result<f64> {
        request_score(&self.tx, tokens)
    }

    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>> {
        request_generate(&self.tx, prompt, max_new)
    }

    pub fn stats(&self) -> Result<ServeStats> {
        request_stats(&self.tx)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Request::Shutdown);
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Round-trip a scoring request over any `Request` channel (worker or
/// router — both ends speak the same protocol).
pub(crate) fn request_score(tx: &Sender<Request>, tokens: Vec<i32>) -> Result<f64> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Score { tokens, resp: rtx.into() })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped request")?.map_err(|e| anyhow!(e))
}

pub(crate) fn request_generate(
    tx: &Sender<Request>,
    prompt: Vec<i32>,
    max_new: usize,
) -> Result<Vec<i32>> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Generate { prompt, max_new, resp: rtx.into() })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped request")?.map_err(|e| anyhow!(e))
}

pub(crate) fn request_stats(tx: &Sender<Request>) -> Result<ServeStats> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Stats { resp: rtx.into() })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped stats request")
}

struct PendingScore {
    tokens: Vec<i32>,
    resp: ReplySink<Result<f64, String>>,
    arrived: Instant,
}

/// A Generate request waiting for a free cache lane.
struct PendingGenerate {
    prompt: Vec<i32>,
    max_new: usize,
    resp: ReplySink<Result<Vec<i32>, String>>,
    arrived: Instant,
}

/// One in-flight generation occupying a KV-cache lane.
struct GenLane {
    /// Tokens currently materialised in this lane's cache rows.
    window: Vec<i32>,
    /// Tokens still to feed: the prompt on admission, the slid window
    /// after a capacity reset, or the token generated last step.
    /// While it holds more than the next token the lane is prefilling
    /// and its logits rows are ignored.
    pending: VecDeque<i32>,
    out: Vec<i32>,
    max_new: usize,
    resp: ReplySink<Result<Vec<i32>, String>>,
    arrived: Instant,
    /// Free the engine lane (resets=1) on the next step — set on
    /// admission and on window slides.
    reset: bool,
}

/// One worker's in-flight generation lanes, mapped 1:1 onto the lanes
/// of the `decode_step` artifact's resident KV cache.
///
/// The cache itself lives inside the bound `kv_cache` handle
/// (`Executable::make_decode_cache`) and never crosses the host
/// boundary; this struct tracks only per-lane request state. One call
/// to [`DecodeSession::step`] advances every active lane by a single
/// token: the worker uploads one token id and one reset flag per lane
/// and takes back one logits row per lane — O(1) traffic per
/// generated token regardless of prefix length.
///
/// Continuous batching: new requests are admitted into free lanes at
/// step boundaries ([`DecodeSession::admit`]), join the in-flight
/// batch on the very next engine call, and retire the moment they hit
/// EOS or their `max_new` budget — freeing the lane mid-flight of
/// their neighbours instead of holding the batch hostage.
struct DecodeSession {
    slots: Vec<Option<GenLane>>,
    /// Cache capacity in tokens per lane — the artifact's seq length.
    s: usize,
}

impl DecodeSession {
    fn new(lanes: usize, s: usize) -> DecodeSession {
        DecodeSession { slots: (0..lanes).map(|_| None).collect(), s }
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn has_free_lane(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Place a validated request into a free lane, or hand it back
    /// (`Some(req)`) when every lane is occupied — the caller re-queues
    /// it and retries at the next step boundary instead of this
    /// panicking on a racy `has_free_lane` check. The lane is marked
    /// for reset so the engine clears whatever the previous occupant
    /// left in the cache rows.
    fn admit(&mut self, req: PendingGenerate) -> Option<PendingGenerate> {
        let Some(slot) = self.slots.iter_mut().find(|s| s.is_none()) else {
            return Some(req);
        };
        let PendingGenerate { prompt, max_new, resp, arrived } = req;
        // keep the last `s-1` prompt tokens, not `s`: a full-`s`
        // admission is degenerate — the window hits capacity the
        // moment the first token generates, so the *second* token
        // triggers an immediate slide and re-prefills all `s` rows.
        // With `s-1` kept, token one decodes from a window with a free
        // row and token two costs a single step. The full-recompute
        // oracle truncates its prompt identically
        // (`generate_full_recompute`), keeping the two paths bitwise
        // matched across the s-1/s/s+1 prompt boundary (pinned in
        // serve_test.rs). `.max(1)` keeps a 1-token context if s == 1.
        let keep = (self.s - 1).max(1);
        let start = prompt.len().saturating_sub(keep);
        *slot = Some(GenLane {
            window: Vec::with_capacity(self.s),
            pending: prompt[start..].iter().copied().collect(),
            out: Vec::new(),
            max_new,
            resp,
            arrived,
            reset: true,
        });
        None
    }

    /// Advance every active lane by one token with a single engine
    /// call. Idle lanes ride along as `-1` sentinels the engine skips,
    /// so a lone generation on an 8-lane artifact pays for one row of
    /// compute, not eight.
    fn step(
        &mut self,
        backend: &dyn Backend,
        bind: &Bindings,
        stats: &mut ServeStats,
        shared: &WorkerShared,
    ) {
        let lanes = self.slots.len();
        let mut tokens = vec![-1i32; lanes];
        let mut resets = vec![0i32; lanes];
        for (lane, slot) in self.slots.iter_mut().enumerate() {
            let Some(l) = slot else { continue };
            if l.window.len() == self.s {
                // lane at capacity: positions are absolute, so slide
                // the window by resetting the lane and re-feeding the
                // last s-1 tokens ahead of whatever is already pending
                // — bitwise the same prefix the full-recompute oracle
                // scores after its own window slide
                let mut refeed: VecDeque<i32> = l.window[1..].iter().copied().collect();
                refeed.extend(l.pending.drain(..));
                l.pending = refeed;
                l.window.clear();
                l.reset = true;
            }
            let t = l.pending.pop_front().expect("active lane always has a token queued");
            tokens[lane] = t;
            resets[lane] = l.reset as i32;
            l.reset = false;
            l.window.push(t);
        }
        let result = (|| -> Result<Vec<f32>> {
            let dev = [
                backend.upload(Tensor::from_i32(&[lanes], tokens)?)?,
                backend.upload(Tensor::from_i32(&[lanes], resets)?)?,
            ];
            let mut res = bind.call(&[&dev[0], &dev[1]])?;
            let t = backend.take(res.swap_remove(0))?;
            Ok(t.as_f32()?.to_vec())
        })();
        let logits = match result {
            Ok(l) => l,
            Err(e) => {
                // an engine failure poisons every lane in the batch:
                // give them all an error reply rather than a hang
                let msg = format!("{e:#}");
                for slot in &mut self.slots {
                    Self::retire(slot, Err(msg.clone()), stats, shared);
                }
                return;
            }
        };
        let vocab = bind.spec().outputs[0].shape[1];
        for (lane, slot) in self.slots.iter_mut().enumerate() {
            let Some(l) = slot.as_mut() else { continue };
            if !l.pending.is_empty() {
                continue; // still prefilling: logits not meaningful yet
            }
            let row = &logits[lane * vocab..(lane + 1) * vocab];
            let Some(next) = argmax_f32(row).map(|i| i as i32) else {
                Self::retire(slot, Err("logits row is all NaN".into()), stats, shared);
                continue;
            };
            l.out.push(next);
            if next == crate::data::tokenizer::EOS || l.out.len() >= l.max_new {
                let out = std::mem::take(&mut l.out);
                Self::retire(slot, Ok(out), stats, shared);
            } else {
                l.pending.push_back(next);
            }
        }
    }

    fn retire(
        slot: &mut Option<GenLane>,
        result: Result<Vec<i32>, String>,
        stats: &mut ServeStats,
        shared: &WorkerShared,
    ) {
        let Some(l) = slot.take() else { return };
        stats
            .latencies_ms
            .push(Instant::now().duration_since(l.arrived).as_secs_f64() * 1e3);
        l.resp.send(result);
        shared.dec_pending();
    }
}

/// Session-path request validation, performed before the request can
/// occupy a cache lane — so one malformed prompt gets its own error
/// reply instead of poisoning the lanes it would be co-scheduled with.
fn validate_prompt(prompt: &[i32], vocab: usize) -> Result<(), String> {
    if prompt.is_empty() {
        return Err("cannot generate from an empty prompt".into());
    }
    match prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
        Some(t) => Err(format!("prompt token {t} out of vocab range 0..{vocab}")),
        None => Ok(()),
    }
}

/// Flips the shard's liveness flag when the worker exits — by any
/// path, panic included (the router reads this to stop dispatching
/// to a dead shard).
struct AliveGuard(Arc<WorkerShared>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.mark_dead();
    }
}

pub(crate) fn worker(
    cfg: ServeConfig,
    rx: Receiver<Request>,
    shared: Arc<WorkerShared>,
) -> Result<()> {
    let _alive = AliveGuard(shared.clone());
    // fallback pool share for a directly-started worker
    // ([`ServerHandle`], n_workers == 1). Sharded fronts never rely on
    // this truncating division — it strands `num_threads % n_workers`
    // cores — they pin `threads_per_worker` per shard from
    // [`super::router::lane_split`], which hands the remainder out
    // one core at a time.
    let threads = cfg.threads_per_worker.unwrap_or_else(|| {
        (crate::dyad::kernel::num_threads() / cfg.n_workers.max(1)).max(1)
    });
    let backend = open_backend_sized(
        cfg.backend,
        &cfg.artifacts_dir,
        crate::tensor::Precision::F32,
        threads,
    )?;
    let score_art = backend.load(&format!("{}/{}/score", cfg.arch, cfg.variant))?;
    let logits_art =
        backend.load(&format!("{}/{}/next_logits", cfg.arch, cfg.variant))?;
    let train_spec = backend
        .manifest()
        .artifact(&format!("{}/{}/train_k1", cfg.arch, cfg.variant))?
        .clone();
    // three ways to source the parameters, two memory shapes: a DYW1
    // weight file maps read-only (fleet shards all share one set of
    // page-cache pages — `weight_mapped_bytes`), while checkpoint /
    // fresh-init params live on this process's heap
    // (`weight_heap_bytes`). Serving never needs the optimizer
    // moments, so the weight-file path skips allocating them entirely.
    let (param_handles, weight_heap_bytes, weight_mapped_bytes): (Vec<DeviceTensor>, u64, u64) =
        match &cfg.weights_file {
            Some(path) => {
                let weights = MappedWeights::open(path)
                    .with_context(|| format!("open weight file {}", path.display()))?;
                let handles = weights.param_handles(backend.as_ref(), &train_spec)?;
                let bytes = weights.data_bytes();
                if weights.is_shared() {
                    (handles, 0, bytes)
                } else {
                    // mmap unavailable (non-Linux, miri): honest
                    // accounting — the fallback is a private heap copy
                    (handles, bytes, 0)
                }
            }
            None => {
                let state = match &cfg.checkpoint_dir {
                    Some(dir) => {
                        let mgr = CheckpointManager::new(dir);
                        if mgr.has_state() {
                            mgr.load_state(backend.as_ref(), &train_spec)?
                        } else {
                            TrainState::init(backend.as_ref(), &train_spec, cfg.seed)?
                        }
                    }
                    None => TrainState::init(backend.as_ref(), &train_spec, cfg.seed)?,
                };
                let handles = state.param_handles().to_vec();
                let bytes = handles.iter().map(|h| h.size_bytes() as u64).sum();
                (handles, bytes, 0)
            }
        };
    // weights resident per worker: bound once here, reused by every
    // request; the hot path uploads only the padded batches
    let mut score_bind = Bindings::new(score_art.as_ref());
    score_bind.bind_role(Role::Param, &param_handles)?;
    let mut logits_bind = Bindings::new(logits_art.as_ref());
    logits_bind.bind_role(Role::Param, &param_handles)?;
    // the decode artifact gets weights AND its KV cache bound
    // resident: the cache handle never crosses the host boundary, so
    // per decode step only the token/reset lanes and the logits rows
    // are staged
    let decode_art = if cfg.legacy_generate {
        None
    } else {
        Some(backend.load(&format!("{}/{}/decode_step", cfg.arch, cfg.variant))?)
    };
    let decode_bind = match &decode_art {
        Some(art) => {
            let mut bnd = Bindings::new(art.as_ref());
            bnd.bind_role(Role::Param, &param_handles)?;
            bnd.bind_named("kv_cache", art.make_decode_cache()?)?;
            Some(bnd)
        }
        None => None,
    };

    let b = score_art.spec().meta_usize("batch")?;
    let s = score_art.spec().meta_usize("seq")?;
    let vocab = logits_art.spec().outputs[0].shape[1];
    let lanes = match &decode_art {
        Some(art) => art.spec().meta_usize("batch")?,
        None => b,
    };
    let mut session = DecodeSession::new(lanes, s);
    let mut gen_queue: VecDeque<PendingGenerate> = VecDeque::new();
    let mut batcher = Batcher::new(cfg.max_batch.min(b), cfg.window_ms);
    let mut queue: Vec<PendingScore> = Vec::new();
    let mut stats = ServeStats::default();
    let started = Timer::start();
    // wall-clock anchor for the stats span: fleet-level merge unions
    // [t0, t0+wall] activity spans instead of max-ing wall_s, which
    // overstated throughput for staggered workers (see ServeStats)
    let t0_epoch = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);

    let flush = |queue: &mut Vec<PendingScore>, stats: &mut ServeStats| {
        if queue.is_empty() {
            return;
        }
        let seqs: Vec<Vec<i32>> = queue.iter().map(|p| p.tokens.clone()).collect();
        let t = Timer::start();
        let result = (|| -> Result<Vec<f64>> {
            let (tokens, mask) = pad_batch(&seqs, b, s)?;
            let dev = [backend.upload(tokens)?, backend.upload(mask)?];
            let mut out = score_bind.call(&[&dev[0], &dev[1]])?;
            let sums = backend.take(out.swap_remove(0))?;
            Ok(sums.as_f32()?[..seqs.len()].iter().map(|&x| x as f64).collect())
        })();
        stats.exec_ms.push(t.elapsed_ms());
        stats.batch_sizes.push(queue.len());
        let now = Instant::now();
        match result {
            Ok(scores) => {
                for (p, sc) in queue.drain(..).zip(scores) {
                    stats
                        .latencies_ms
                        .push(now.duration_since(p.arrived).as_secs_f64() * 1e3);
                    p.resp.send(Ok(sc));
                    shared.dec_pending();
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for p in queue.drain(..) {
                    p.resp.send(Err(msg.clone()));
                    shared.dec_pending();
                }
            }
        }
    };

    loop {
        let now = Instant::now();
        if batcher.window_expired(now) {
            batcher.flush();
            flush(&mut queue, &mut stats);
        }
        let mut inbox: Vec<Request> = Vec::new();
        let mut disconnected = false;
        if session.active() == 0 && gen_queue.is_empty() {
            // nothing decoding: block up to the batching window
            match rx.recv_timeout(batcher.wait_budget(Instant::now())) {
                Ok(r) => inbox.push(r),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        // drain whatever else is already queued without blocking, so
        // in-flight decode steps never wait behind the channel
        loop {
            match rx.try_recv() {
                Ok(r) => inbox.push(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        let mut shutdown = false;
        for req in inbox {
            match req {
                Request::Score { tokens, resp } => {
                    queue.push(PendingScore { tokens, resp, arrived: Instant::now() });
                    if batcher.on_arrival(Instant::now()) {
                        batcher.flush();
                        flush(&mut queue, &mut stats);
                    }
                }
                Request::Generate { prompt, max_new, resp } => {
                    if decode_bind.is_none() {
                        // legacy oracle path: flush pending scores for
                        // ordering fairness, then decode synchronously
                        batcher.flush();
                        flush(&mut queue, &mut stats);
                        let t = Instant::now();
                        let out = generate_full_recompute(
                            backend.as_ref(),
                            &logits_bind,
                            prompt,
                            max_new,
                            s,
                        );
                        stats
                            .latencies_ms
                            .push(Instant::now().duration_since(t).as_secs_f64() * 1e3);
                        resp.send(out.map_err(|e| format!("{e:#}")));
                        shared.dec_pending();
                    } else if let Err(msg) = validate_prompt(&prompt, vocab) {
                        resp.send(Err(msg));
                        shared.dec_pending();
                    } else if max_new == 0 {
                        resp.send(Ok(Vec::new()));
                        shared.dec_pending();
                    } else {
                        gen_queue.push_back(PendingGenerate {
                            prompt,
                            max_new,
                            resp,
                            arrived: Instant::now(),
                        });
                    }
                }
                Request::Stats { resp } => {
                    let mut snap = stats.clone();
                    snap.wall_s = started.elapsed_s();
                    snap.workers = 1;
                    snap.spans = vec![(t0_epoch, t0_epoch + snap.wall_s)];
                    snap.weight_heap_bytes = weight_heap_bytes;
                    snap.weight_mapped_bytes = weight_mapped_bytes;
                    resp.send(snap);
                }
                Request::Shutdown => shutdown = true,
                Request::Crash => {
                    // failure injection: die mid-run with requests
                    // possibly queued; dropping `queue`/`session`/`rx`
                    // drops their reply senders, so waiting clients
                    // observe an error reply (disconnect), never a hang
                    panic!(
                        "serve worker {}/{}: injected crash (Request::Crash)",
                        cfg.arch, cfg.variant
                    );
                }
            }
        }
        if shutdown || disconnected {
            // graceful drain: every generation admitted or queued
            // before shutdown still gets a real reply
            if let Some(bind) = &decode_bind {
                while session.active() > 0 || !gen_queue.is_empty() {
                    admit_waiting(&mut session, &mut gen_queue);
                    session.step(backend.as_ref(), bind, &mut stats, &shared);
                }
            }
            batcher.flush();
            flush(&mut queue, &mut stats);
            return Ok(());
        }
        // continuous batching: admit waiting generations into free
        // cache lanes at the step boundary, then advance every active
        // lane by one token
        if let Some(bind) = &decode_bind {
            admit_waiting(&mut session, &mut gen_queue);
            if session.active() > 0 {
                session.step(backend.as_ref(), bind, &mut stats, &shared);
            }
        }
    }
}

/// Move waiting generations into free cache lanes, preserving FIFO
/// order. If [`DecodeSession::admit`] hands a request back (no lane
/// free after all — the guarded path that used to be a panic), it goes
/// back to the queue head for the next step boundary.
fn admit_waiting(session: &mut DecodeSession, gen_queue: &mut VecDeque<PendingGenerate>) {
    while session.has_free_lane() {
        let Some(r) = gen_queue.pop_front() else { break };
        if let Some(back) = session.admit(r) {
            gen_queue.push_front(back);
            break;
        }
    }
}

/// Greedy decode oracle: full-context recompute per token via the
/// `next_logits` artifact — O(prefix) FLOPs per generated token. The
/// production path is the KV-cache `DecodeSession`; this loop stays
/// as the reference it is parity-tested against
/// ([`ServeConfig::legacy_generate`]). Weights are already resident in
/// `bind`; each step uploads one token window.
fn generate_full_recompute(
    backend: &dyn Backend,
    bind: &Bindings,
    prompt: Vec<i32>,
    max_new: usize,
    s: usize,
) -> Result<Vec<i32>> {
    if prompt.is_empty() {
        bail!("cannot generate from an empty prompt");
    }
    let b = bind.spec().meta_usize("batch")?;
    let mut tokens = prompt;
    // admission context is the last s-1 prompt tokens, matching
    // `DecodeSession::admit` bit for bit (the incremental path is
    // parity-tested against this loop); generated tokens then extend
    // the window up to `s` before the slide below kicks in
    let keep = (s - 1).max(1);
    if tokens.len() > keep {
        tokens.drain(..tokens.len() - keep);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        let window: Vec<i32> = if tokens.len() > s {
            tokens[tokens.len() - s..].to_vec()
        } else {
            tokens.clone()
        };
        let mut toks = vec![0i32; b * s];
        toks[..window.len()].copy_from_slice(&window);
        let mut lens = vec![1i32; b];
        lens[0] = window.len() as i32;
        let dev = [
            backend.upload(Tensor::from_i32(&[b, s], toks)?)?,
            backend.upload(Tensor::from_i32(&[b], lens)?)?,
        ];
        let mut res = bind.call(&[&dev[0], &dev[1]])?;
        let logits_t = backend.take(res.swap_remove(0))?;
        let logits = logits_t.as_f32()?;
        let vocab = bind.spec().outputs[0].shape[1];
        let next = argmax_f32(&logits[..vocab])
            .map(|i| i as i32)
            .ok_or_else(|| anyhow!("logits row is all NaN"))?;
        tokens.push(next);
        out.push(next);
        if next == crate::data::tokenizer::EOS {
            break;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(prompt: Vec<i32>) -> (PendingGenerate, Receiver<Result<Vec<i32>, String>>) {
        let (tx, rx) = mpsc::channel();
        let req = PendingGenerate {
            prompt,
            max_new: 4,
            resp: tx.into(),
            arrived: Instant::now(),
        };
        (req, rx)
    }

    /// Regression for the panicking lane claim: at exactly-full
    /// capacity `admit` hands the request back instead of
    /// `expect`-crashing the worker, and the queue helper re-queues it
    /// at the head.
    #[test]
    fn admit_at_full_capacity_returns_request() {
        let mut session = DecodeSession::new(2, 8);
        let (a, _arx) = pending(vec![1]);
        let (b, _brx) = pending(vec![2]);
        assert!(session.admit(a).is_none());
        assert!(session.admit(b).is_none());
        assert!(!session.has_free_lane());
        let (c, _crx) = pending(vec![3, 4, 5]);
        let back = session.admit(c).expect("full session must hand the request back");
        assert_eq!(back.prompt, vec![3, 4, 5]);

        let mut q: VecDeque<PendingGenerate> = VecDeque::new();
        q.push_back(back);
        admit_waiting(&mut session, &mut q);
        assert_eq!(q.len(), 1, "request stays queued while lanes are full");
        assert_eq!(q[0].prompt, vec![3, 4, 5], "and stays at the queue head");
    }

    /// Regression for degenerate full-window admission: the context a
    /// long prompt keeps is the last `s-1` tokens (one free cache row
    /// for the first generated token), never the full `s`.
    #[test]
    fn admit_keeps_last_s_minus_one_tokens() {
        let s = 8;
        for plen in [s - 1, s, s + 1, 3 * s] {
            let mut session = DecodeSession::new(1, s);
            let prompt: Vec<i32> = (0..plen as i32).collect();
            let (req, _rx) = pending(prompt.clone());
            assert!(session.admit(req).is_none());
            let lane = session.slots[0].as_ref().unwrap();
            let keep = plen.min(s - 1);
            let expect: Vec<i32> = prompt[plen - keep..].to_vec();
            let got: Vec<i32> = lane.pending.iter().copied().collect();
            assert_eq!(got, expect, "prompt len {plen}");
            assert!(lane.pending.len() < s, "admission must leave a free cache row");
        }
    }

    /// s == 1 edge: `.max(1)` keeps a context token instead of
    /// admitting an empty pending queue (which would panic in `step`).
    #[test]
    fn admit_with_single_token_window_keeps_one() {
        let mut session = DecodeSession::new(1, 1);
        let (req, _rx) = pending(vec![5, 6, 7]);
        assert!(session.admit(req).is_none());
        let lane = session.slots[0].as_ref().unwrap();
        assert_eq!(lane.pending.iter().copied().collect::<Vec<_>>(), vec![7]);
    }
}

//! The serving loop: backend-owning worker thread + request channels.
//!
//! Backend handles are not `Send` (the PJRT client isn't), so the
//! worker thread *creates* its own backend from the config; clients
//! interact through mpsc channels. The worker uploads the model
//! weights onto its backend **once** at startup and binds them
//! resident (`Bindings`); the per-request hot path stages only the
//! padded token batches, never the weights. Scoring requests are
//! dynamically batched (see `Batcher`); generation requests run a
//! greedy decode loop over the `next_logits` artifact with all active
//! generations stepped together (a miniature continuous batcher).
//!
//! [`ServerHandle`] runs exactly one worker — the direct,
//! single-shard path. The sharded front-end that fans requests out to
//! several of these workers is [`super::Router`]; both speak the same
//! [`Request`] enum, and the worker loop here is the unit of sharding
//! (per-worker backend, per-worker resident weights, per-worker
//! [`ServeStats`]).

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::batcher::Batcher;
use super::router::{DispatchPolicy, WorkerShared};
use super::stats::ServeStats;
use crate::coordinator::checkpoint::CheckpointManager;
use crate::data::dataset::pad_batch;
use crate::runtime::{
    open_backend_sized, Backend, BackendKind, Bindings, Executable, Role, TrainState,
};
use crate::tensor::Tensor;
use crate::util::timer::Timer;

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Which execution backend each worker opens (native by default).
    pub backend: BackendKind,
    /// Artifact dir for the xla backend (unused by native).
    pub artifacts_dir: PathBuf,
    pub arch: String,
    pub variant: String,
    /// Load params from this run dir's checkpoint if present.
    pub checkpoint_dir: Option<PathBuf>,
    pub max_batch: usize,
    pub window_ms: u64,
    pub seed: u64,
    /// Worker shards opened by [`super::Router::start`] (each one a
    /// backend-owning thread with its weights bound resident).
    /// [`ServerHandle::start`] ignores this and always runs one.
    pub n_workers: usize,
    /// How the router spreads requests over the shards.
    pub dispatch: DispatchPolicy,
    /// Worker-pool size each shard's native backend runs on. `None`
    /// (the default) splits the machine evenly:
    /// `num_threads() / n_workers`, min 1 — so a fleet never
    /// oversubscribes the cores the way N full-width shards would.
    /// `serve --threads-per-worker N` overrides the split.
    pub threads_per_worker: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backend: BackendKind::Native,
            artifacts_dir: "artifacts".into(),
            arch: "opt-mini".into(),
            variant: "dyad_it".into(),
            checkpoint_dir: None,
            max_batch: 8,
            window_ms: 5,
            seed: 7,
            n_workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            threads_per_worker: None,
        }
    }
}

pub enum Request {
    /// Sum log-probability of a token sequence.
    Score {
        tokens: Vec<i32>,
        resp: Sender<Result<f64, String>>,
    },
    /// Greedy continuation of a prompt.
    Generate {
        prompt: Vec<i32>,
        max_new: usize,
        resp: Sender<Result<Vec<i32>, String>>,
    },
    Stats {
        resp: Sender<ServeStats>,
    },
    Shutdown,
    /// Failure-injection hook (tests, soak runs): the receiving worker
    /// thread panics, simulating a shard crash. The router's death
    /// detection turns the fallout into error replies, never hangs.
    #[doc(hidden)]
    Crash,
}

pub struct ServerHandle {
    tx: Sender<Request>,
    join: Option<JoinHandle<Result<()>>>,
}

impl ServerHandle {
    pub fn start(cfg: ServeConfig) -> ServerHandle {
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(WorkerShared::new());
        // xtask:allow(thread_spawn): the single-worker server thread is
        // a long-lived backend owner, not kernel parallelism.
        let join = std::thread::spawn(move || worker(cfg, rx, shared));
        ServerHandle { tx, join: Some(join) }
    }

    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    pub fn score(&self, tokens: Vec<i32>) -> Result<f64> {
        request_score(&self.tx, tokens)
    }

    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>> {
        request_generate(&self.tx, prompt, max_new)
    }

    pub fn stats(&self) -> Result<ServeStats> {
        request_stats(&self.tx)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Request::Shutdown);
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Round-trip a scoring request over any `Request` channel (worker or
/// router — both ends speak the same protocol).
pub(crate) fn request_score(tx: &Sender<Request>, tokens: Vec<i32>) -> Result<f64> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Score { tokens, resp: rtx })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped request")?.map_err(|e| anyhow!(e))
}

pub(crate) fn request_generate(
    tx: &Sender<Request>,
    prompt: Vec<i32>,
    max_new: usize,
) -> Result<Vec<i32>> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Generate { prompt, max_new, resp: rtx })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped request")?.map_err(|e| anyhow!(e))
}

pub(crate) fn request_stats(tx: &Sender<Request>) -> Result<ServeStats> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Stats { resp: rtx })
        .map_err(|_| anyhow!("server down"))?;
    rrx.recv().context("server dropped stats request")
}

struct PendingScore {
    tokens: Vec<i32>,
    resp: Sender<Result<f64, String>>,
    arrived: Instant,
}

/// Flips the shard's liveness flag when the worker exits — by any
/// path, panic included (the router reads this to stop dispatching
/// to a dead shard).
struct AliveGuard(Arc<WorkerShared>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.mark_dead();
    }
}

pub(crate) fn worker(
    cfg: ServeConfig,
    rx: Receiver<Request>,
    shared: Arc<WorkerShared>,
) -> Result<()> {
    let _alive = AliveGuard(shared.clone());
    // per-worker pool share: N shards each get 1/N of the machine
    // (min 1) unless --threads-per-worker pins an explicit count, so
    // a fleet's kernels never oversubscribe the cores N-fold
    let threads = cfg.threads_per_worker.unwrap_or_else(|| {
        (crate::dyad::kernel::num_threads() / cfg.n_workers.max(1)).max(1)
    });
    let backend = open_backend_sized(
        cfg.backend,
        &cfg.artifacts_dir,
        crate::tensor::Precision::F32,
        threads,
    )?;
    let score_art = backend.load(&format!("{}/{}/score", cfg.arch, cfg.variant))?;
    let logits_art =
        backend.load(&format!("{}/{}/next_logits", cfg.arch, cfg.variant))?;
    let train_spec = backend
        .manifest()
        .artifact(&format!("{}/{}/train_k1", cfg.arch, cfg.variant))?
        .clone();
    let state = match &cfg.checkpoint_dir {
        Some(dir) => {
            let mgr = CheckpointManager::new(dir);
            if mgr.has_state() {
                mgr.load_state(backend.as_ref(), &train_spec)?
            } else {
                TrainState::init(backend.as_ref(), &train_spec, cfg.seed)?
            }
        }
        None => TrainState::init(backend.as_ref(), &train_spec, cfg.seed)?,
    };
    // weights resident per worker: bound once here, reused by every
    // request; the hot path uploads only the padded batches
    let mut score_bind = Bindings::new(score_art.as_ref());
    score_bind.bind_role(Role::Param, state.param_handles())?;
    let mut logits_bind = Bindings::new(logits_art.as_ref());
    logits_bind.bind_role(Role::Param, state.param_handles())?;

    let b = score_art.spec().meta_usize("batch")?;
    let s = score_art.spec().meta_usize("seq")?;
    let mut batcher = Batcher::new(cfg.max_batch.min(b), cfg.window_ms);
    let mut queue: Vec<PendingScore> = Vec::new();
    let mut stats = ServeStats::default();
    let started = Timer::start();

    let flush = |queue: &mut Vec<PendingScore>, stats: &mut ServeStats| {
        if queue.is_empty() {
            return;
        }
        let seqs: Vec<Vec<i32>> = queue.iter().map(|p| p.tokens.clone()).collect();
        let t = Timer::start();
        let result = (|| -> Result<Vec<f64>> {
            let (tokens, mask) = pad_batch(&seqs, b, s)?;
            let dev = [backend.upload(tokens)?, backend.upload(mask)?];
            let mut out = score_bind.call(&[&dev[0], &dev[1]])?;
            let sums = backend.take(out.swap_remove(0))?;
            Ok(sums.as_f32()?[..seqs.len()].iter().map(|&x| x as f64).collect())
        })();
        stats.exec_ms.push(t.elapsed_ms());
        stats.batch_sizes.push(queue.len());
        let now = Instant::now();
        match result {
            Ok(scores) => {
                for (p, sc) in queue.drain(..).zip(scores) {
                    stats
                        .latencies_ms
                        .push(now.duration_since(p.arrived).as_secs_f64() * 1e3);
                    let _ = p.resp.send(Ok(sc));
                    shared.dec_pending();
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for p in queue.drain(..) {
                    let _ = p.resp.send(Err(msg.clone()));
                    shared.dec_pending();
                }
            }
        }
    };

    loop {
        let now = Instant::now();
        if batcher.window_expired(now) {
            batcher.flush();
            flush(&mut queue, &mut stats);
        }
        let budget = batcher.wait_budget(Instant::now());
        match rx.recv_timeout(budget) {
            Ok(Request::Score { tokens, resp }) => {
                queue.push(PendingScore { tokens, resp, arrived: Instant::now() });
                if batcher.on_arrival(Instant::now()) {
                    batcher.flush();
                    flush(&mut queue, &mut stats);
                }
            }
            Ok(Request::Generate { prompt, max_new, resp }) => {
                // flush pending scores first to preserve ordering fairness
                batcher.flush();
                flush(&mut queue, &mut stats);
                let t = Instant::now();
                let out = generate(backend.as_ref(), &logits_bind, prompt, max_new, s);
                stats
                    .latencies_ms
                    .push(Instant::now().duration_since(t).as_secs_f64() * 1e3);
                let _ = resp.send(out.map_err(|e| format!("{e:#}")));
                shared.dec_pending();
            }
            Ok(Request::Stats { resp }) => {
                let mut snap = stats.clone();
                snap.wall_s = started.elapsed_s();
                snap.workers = 1;
                let _ = resp.send(snap);
            }
            Ok(Request::Shutdown) => {
                batcher.flush();
                flush(&mut queue, &mut stats);
                return Ok(());
            }
            Ok(Request::Crash) => {
                // failure injection: die mid-run with requests possibly
                // queued; dropping `queue`/`rx` drops their reply
                // senders, so waiting clients observe an error reply
                // (disconnect), never a hang
                panic!(
                    "serve worker {}/{}: injected crash (Request::Crash)",
                    cfg.arch, cfg.variant
                );
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                batcher.flush();
                flush(&mut queue, &mut stats);
                return Ok(());
            }
        }
    }
}

/// Greedy decode via the next_logits artifact (full-context recompute
/// per token; fine at these scales, documented in DESIGN.md). Weights
/// are already resident in `bind`; each step uploads one token window.
fn generate(
    backend: &dyn Backend,
    bind: &Bindings,
    prompt: Vec<i32>,
    max_new: usize,
    s: usize,
) -> Result<Vec<i32>> {
    let b = bind.spec().meta_usize("batch")?;
    let mut tokens = prompt;
    let mut out = Vec::new();
    for _ in 0..max_new {
        let window: Vec<i32> = if tokens.len() > s {
            tokens[tokens.len() - s..].to_vec()
        } else {
            tokens.clone()
        };
        let mut toks = vec![0i32; b * s];
        toks[..window.len()].copy_from_slice(&window);
        let mut lens = vec![1i32; b];
        lens[0] = window.len() as i32;
        let dev = [
            backend.upload(Tensor::from_i32(&[b, s], toks)?)?,
            backend.upload(Tensor::from_i32(&[b], lens)?)?,
        ];
        let mut res = bind.call(&[&dev[0], &dev[1]])?;
        let logits_t = backend.take(res.swap_remove(0))?;
        let logits = logits_t.as_f32()?;
        let vocab = bind.spec().outputs[0].shape[1];
        let row = &logits[..vocab];
        let next = row
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        tokens.push(next);
        out.push(next);
        if next == crate::data::tokenizer::EOS {
            break;
        }
    }
    Ok(out)
}

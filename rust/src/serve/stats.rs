//! Serving statistics: latency, throughput, batch occupancy.
//!
//! One `ServeStats` is owned by each worker thread; the router merges
//! the per-worker snapshots into a fleet-level view with [`merge`]
//! (`ServeStats::merge`), which conserves request counts: the fleet
//! `requests()` is exactly the sum of the merged workers'.

use crate::util::stats::Summary;

#[derive(Debug, Default, Clone, PartialEq)]
pub struct ServeStats {
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub exec_ms: Vec<f64>,
    pub wall_s: f64,
    /// Worker snapshots folded into this view (1 for a single worker's
    /// own snapshot, the live-shard count for a fleet merge).
    pub workers: usize,
    /// `[start, end)` activity spans in epoch seconds, one per worker
    /// snapshot folded in. [`merge`](Self::merge) derives the fleet
    /// wall clock from the *union* of these instead of `max(wall_s)` —
    /// max silently dropped the non-overlap when workers start
    /// staggered, overstating fleet throughput.
    pub spans: Vec<(f64, f64)>,
    /// Parameter bytes resident on this worker's own heap (fresh-init
    /// or checkpoint weights). Sums across a fleet merge: each worker
    /// pays for its private copy.
    pub weight_heap_bytes: u64,
    /// Parameter bytes served from a read-only shared mapping
    /// (`runtime::catalog::mmap`). Max-es across a fleet merge: every
    /// shard maps the same file, so the fleet pays once.
    pub weight_mapped_bytes: u64,
}

impl ServeStats {
    pub fn requests(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn latency(&self) -> Option<Summary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_ms))
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Requests per wall-clock second; 0.0 (never NaN/inf) when no
    /// wall time has been observed yet.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.requests() as f64 / self.wall_s
    }

    /// Fold another worker's snapshot into this one. Latency, batch
    /// and exec samples concatenate (so every percentile is over the
    /// union); wall time is the length of the **union of activity
    /// spans** — workers run concurrently, but `max(wall_s)` (the old
    /// rule) pretended they were fully overlapped, which overstated
    /// fleet throughput whenever workers start or die staggered
    /// (disjoint 2 s + 3 s spans are 5 s of serving, not 3 s).
    /// Snapshots without spans (older producers, hand-built stats)
    /// fall back to the max rule, documented and clamped: the merged
    /// wall clock is never shorter than either input's.
    pub fn merge(&mut self, other: &ServeStats) {
        self.latencies_ms.extend_from_slice(&other.latencies_ms);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.exec_ms.extend_from_slice(&other.exec_ms);
        self.spans.extend_from_slice(&other.spans);
        let unioned = Self::union_len(&self.spans);
        self.wall_s = unioned.max(self.wall_s.max(other.wall_s));
        self.workers += other.workers;
        self.weight_heap_bytes += other.weight_heap_bytes;
        self.weight_mapped_bytes = self.weight_mapped_bytes.max(other.weight_mapped_bytes);
    }

    /// Total length of the union of `[start, end)` spans (overlap
    /// counted once). Degenerate spans (end <= start) contribute 0.
    fn union_len(spans: &[(f64, f64)]) -> f64 {
        let mut sorted: Vec<(f64, f64)> =
            spans.iter().copied().filter(|(a, b)| b > a).collect();
        sorted.sort_by(|x, y| x.0.total_cmp(&y.0));
        let mut total = 0.0;
        let mut cur: Option<(f64, f64)> = None;
        for (a, b) in sorted {
            match &mut cur {
                Some((_, ce)) if a <= *ce => *ce = ce.max(b),
                _ => {
                    if let Some((cs, ce)) = cur {
                        total += ce - cs;
                    }
                    cur = Some((a, b));
                }
            }
        }
        if let Some((cs, ce)) = cur {
            total += ce - cs;
        }
        total
    }

    /// Fleet-resident parameter bytes: every worker's private heap
    /// copy plus the shared mapping (counted once — that is the point
    /// of `serve --weights`).
    pub fn weight_resident_bytes(&self) -> u64 {
        self.weight_heap_bytes + self.weight_mapped_bytes
    }

    /// Render per-shard summary lines from [`Router::worker_stats`]
    /// output (one line per worker, dead shards marked) — shared by
    /// the CLI and the serving example.
    ///
    /// [`Router::worker_stats`]: super::Router::worker_stats
    pub fn render_workers(per: &[Option<ServeStats>]) -> String {
        per.iter()
            .enumerate()
            .map(|(i, ws)| match ws {
                Some(s) => format!(
                    "  worker {i}: requests={} batches={} mean_occupancy={:.2}",
                    s.requests(),
                    s.batch_sizes.len(),
                    s.mean_batch_occupancy()
                ),
                None => format!("  worker {i}: dead"),
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    pub fn render(&self) -> String {
        let lat = match self.latency() {
            Some(l) => format!(
                "p50={:.1} p95={:.1} p99={:.1} mean={:.1}",
                l.p50, l.p95, l.p99, l.mean
            ),
            None => "n/a (no requests)".to_string(),
        };
        let exec = if self.exec_ms.is_empty() {
            "n/a".to_string()
        } else {
            format!("{:.1}", Summary::of(&self.exec_ms).mean)
        };
        format!(
            "workers={} requests={} batches={} mean_occupancy={:.2} \
             throughput={:.1} req/s\n\
             latency ms: {lat}\n\
             exec ms per batch: mean={exec}",
            self.workers,
            self.requests(),
            self.batch_sizes.len(),
            self.mean_batch_occupancy(),
            self.throughput_rps(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_throughput() {
        let s = ServeStats {
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            batch_sizes: vec![2, 2],
            exec_ms: vec![0.5, 0.6],
            wall_s: 2.0,
            workers: 1,
            ..Default::default()
        };
        assert_eq!(s.requests(), 4);
        assert_eq!(s.mean_batch_occupancy(), 2.0);
        assert_eq!(s.throughput_rps(), 2.0);
        assert!(s.render().contains("requests=4"));
        assert!(s.render().contains("p95="));
    }

    /// The zero-request case is fully defined: no NaN, no div-by-zero,
    /// renderable.
    #[test]
    fn empty_is_safe() {
        let s = ServeStats::default();
        assert!(s.latency().is_none());
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        let r = s.render();
        assert!(!r.contains("NaN") && !r.contains("inf"), "{r}");
        // requests observed but no wall time yet: still well-defined
        let s2 = ServeStats { latencies_ms: vec![1.0], workers: 1, ..Default::default() };
        assert_eq!(s2.throughput_rps(), 0.0);
        assert!(!s2.render().contains("NaN"), "{}", s2.render());
    }

    /// merge conserves request counts, concatenates samples and takes
    /// the max wall clock (workers run concurrently).
    #[test]
    fn merge_conserves_counts() {
        let mut fleet = ServeStats::default();
        let a = ServeStats {
            latencies_ms: vec![1.0, 2.0],
            batch_sizes: vec![2],
            exec_ms: vec![0.5],
            wall_s: 2.0,
            workers: 1,
            ..Default::default()
        };
        let b = ServeStats {
            latencies_ms: vec![3.0, 4.0, 5.0],
            batch_sizes: vec![1, 2],
            exec_ms: vec![0.7, 0.9],
            wall_s: 3.0,
            workers: 1,
            ..Default::default()
        };
        fleet.merge(&a);
        fleet.merge(&b);
        assert_eq!(fleet.requests(), a.requests() + b.requests());
        assert_eq!(fleet.batch_sizes.len(), 3);
        assert_eq!(fleet.exec_ms.len(), 3);
        assert_eq!(fleet.wall_s, 3.0);
        assert_eq!(fleet.workers, 2);
        // fleet throughput: total requests over the longest wall
        assert!((fleet.throughput_rps() - 5.0 / 3.0).abs() < 1e-12);
        assert!(fleet.render().contains("workers=2"));
    }

    fn span_stats(span: (f64, f64), requests: usize) -> ServeStats {
        ServeStats {
            latencies_ms: vec![1.0; requests],
            wall_s: span.1 - span.0,
            workers: 1,
            spans: vec![span],
            ..Default::default()
        }
    }

    /// Disjoint spans: staggered workers serving 2 s then 3 s are 5 s
    /// of fleet serving. The old `max(wall_s)` rule reported 3 s —
    /// overstating throughput by the gap.
    #[test]
    fn merge_disjoint_spans_sum() {
        let mut fleet = ServeStats::default();
        fleet.merge(&span_stats((0.0, 2.0), 2));
        fleet.merge(&span_stats((5.0, 8.0), 3));
        assert!((fleet.wall_s - 5.0).abs() < 1e-12, "wall_s = {}", fleet.wall_s);
        assert!((fleet.throughput_rps() - 1.0).abs() < 1e-12);
    }

    /// Overlapping spans count the overlap once — concurrent workers
    /// do not stretch the fleet wall clock.
    #[test]
    fn merge_overlapping_spans_union() {
        let mut fleet = ServeStats::default();
        fleet.merge(&span_stats((0.0, 3.0), 1));
        fleet.merge(&span_stats((1.0, 4.0), 1));
        assert!((fleet.wall_s - 4.0).abs() < 1e-12, "wall_s = {}", fleet.wall_s);
        // nested span adds nothing
        fleet.merge(&span_stats((1.5, 2.0), 1));
        assert!((fleet.wall_s - 4.0).abs() < 1e-12, "wall_s = {}", fleet.wall_s);
    }

    /// Zero-wall / degenerate spans stay well-defined, and merge order
    /// does not matter.
    #[test]
    fn merge_zero_wall_and_order_independent() {
        let mut fleet = ServeStats::default();
        fleet.merge(&span_stats((2.0, 2.0), 0));
        assert_eq!(fleet.wall_s, 0.0);
        assert_eq!(fleet.throughput_rps(), 0.0);

        let parts = [
            span_stats((0.0, 1.0), 1),
            span_stats((0.5, 2.5), 1),
            span_stats((4.0, 5.0), 1),
        ];
        let mut fwd = ServeStats::default();
        let mut rev = ServeStats::default();
        for p in &parts {
            fwd.merge(p);
        }
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        assert!((fwd.wall_s - rev.wall_s).abs() < 1e-12);
        assert!((fwd.wall_s - 3.5).abs() < 1e-12, "wall_s = {}", fwd.wall_s);
    }

    /// Span-less snapshots (hand-built stats, older producers) keep
    /// the documented max-rule fallback; mixing in spans never shrinks
    /// the wall clock below either input.
    #[test]
    fn merge_spanless_falls_back_to_max() {
        let mut fleet = ServeStats::default();
        fleet.merge(&ServeStats { wall_s: 2.0, workers: 1, ..Default::default() });
        fleet.merge(&span_stats((0.0, 1.0), 1));
        assert!((fleet.wall_s - 2.0).abs() < 1e-12, "wall_s = {}", fleet.wall_s);
    }

    /// Weight accounting: private heap copies sum across shards, the
    /// shared mapping is paid once.
    #[test]
    fn merge_weight_bytes_heap_sums_mapped_maxes() {
        let mut fleet = ServeStats::default();
        for _ in 0..3 {
            fleet.merge(&ServeStats {
                workers: 1,
                weight_heap_bytes: 100,
                weight_mapped_bytes: 4096,
                ..Default::default()
            });
        }
        assert_eq!(fleet.weight_heap_bytes, 300);
        assert_eq!(fleet.weight_mapped_bytes, 4096);
        assert_eq!(fleet.weight_resident_bytes(), 300 + 4096);
    }

    #[test]
    fn render_workers_marks_dead_shards() {
        let alive = ServeStats { latencies_ms: vec![1.0], workers: 1, ..Default::default() };
        let out = ServeStats::render_workers(&[Some(alive), None]);
        assert!(out.contains("worker 0: requests=1"), "{out}");
        assert!(out.contains("worker 1: dead"), "{out}");
    }
}

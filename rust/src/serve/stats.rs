//! Serving statistics: latency, throughput, batch occupancy.

use crate::util::stats::Summary;

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub exec_ms: Vec<f64>,
    pub wall_s: f64,
}

impl ServeStats {
    pub fn requests(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn latency(&self) -> Option<Summary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_ms))
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.requests() as f64 / self.wall_s
    }

    pub fn render(&self) -> String {
        let lat = self.latency();
        format!(
            "requests={} batches={} mean_occupancy={:.2} throughput={:.1} req/s\n\
             latency ms: p50={:.1} p90={:.1} p99={:.1} mean={:.1}\n\
             exec ms per batch: mean={:.1}",
            self.requests(),
            self.batch_sizes.len(),
            self.mean_batch_occupancy(),
            self.throughput_rps(),
            lat.map(|l| l.p50).unwrap_or(0.0),
            self.latency().map(|l| l.p90).unwrap_or(0.0),
            self.latency().map(|l| l.p99).unwrap_or(0.0),
            self.latency().map(|l| l.mean).unwrap_or(0.0),
            if self.exec_ms.is_empty() {
                0.0
            } else {
                Summary::of(&self.exec_ms).mean
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_throughput() {
        let s = ServeStats {
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            batch_sizes: vec![2, 2],
            exec_ms: vec![0.5, 0.6],
            wall_s: 2.0,
        };
        assert_eq!(s.requests(), 4);
        assert_eq!(s.mean_batch_occupancy(), 2.0);
        assert_eq!(s.throughput_rps(), 2.0);
        assert!(s.render().contains("requests=4"));
    }

    #[test]
    fn empty_is_safe() {
        let s = ServeStats::default();
        assert!(s.latency().is_none());
        assert_eq!(s.throughput_rps(), 0.0);
        let _ = s.render();
    }
}

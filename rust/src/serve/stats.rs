//! Serving statistics: latency, throughput, batch occupancy.
//!
//! One `ServeStats` is owned by each worker thread; the router merges
//! the per-worker snapshots into a fleet-level view with [`merge`]
//! (`ServeStats::merge`), which conserves request counts: the fleet
//! `requests()` is exactly the sum of the merged workers'.

use crate::util::stats::Summary;

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub latencies_ms: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub exec_ms: Vec<f64>,
    pub wall_s: f64,
    /// Worker snapshots folded into this view (1 for a single worker's
    /// own snapshot, the live-shard count for a fleet merge).
    pub workers: usize,
}

impl ServeStats {
    pub fn requests(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn latency(&self) -> Option<Summary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_ms))
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Requests per wall-clock second; 0.0 (never NaN/inf) when no
    /// wall time has been observed yet.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.requests() as f64 / self.wall_s
    }

    /// Fold another worker's snapshot into this one. Latency, batch
    /// and exec samples concatenate (so every percentile is over the
    /// union); wall time is the max, since workers run concurrently —
    /// fleet throughput is total requests over the longest-lived
    /// worker's wall clock.
    pub fn merge(&mut self, other: &ServeStats) {
        self.latencies_ms.extend_from_slice(&other.latencies_ms);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.exec_ms.extend_from_slice(&other.exec_ms);
        self.wall_s = self.wall_s.max(other.wall_s);
        self.workers += other.workers;
    }

    /// Render per-shard summary lines from [`Router::worker_stats`]
    /// output (one line per worker, dead shards marked) — shared by
    /// the CLI and the serving example.
    ///
    /// [`Router::worker_stats`]: super::Router::worker_stats
    pub fn render_workers(per: &[Option<ServeStats>]) -> String {
        per.iter()
            .enumerate()
            .map(|(i, ws)| match ws {
                Some(s) => format!(
                    "  worker {i}: requests={} batches={} mean_occupancy={:.2}",
                    s.requests(),
                    s.batch_sizes.len(),
                    s.mean_batch_occupancy()
                ),
                None => format!("  worker {i}: dead"),
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    pub fn render(&self) -> String {
        let lat = match self.latency() {
            Some(l) => format!(
                "p50={:.1} p95={:.1} p99={:.1} mean={:.1}",
                l.p50, l.p95, l.p99, l.mean
            ),
            None => "n/a (no requests)".to_string(),
        };
        let exec = if self.exec_ms.is_empty() {
            "n/a".to_string()
        } else {
            format!("{:.1}", Summary::of(&self.exec_ms).mean)
        };
        format!(
            "workers={} requests={} batches={} mean_occupancy={:.2} \
             throughput={:.1} req/s\n\
             latency ms: {lat}\n\
             exec ms per batch: mean={exec}",
            self.workers,
            self.requests(),
            self.batch_sizes.len(),
            self.mean_batch_occupancy(),
            self.throughput_rps(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_throughput() {
        let s = ServeStats {
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            batch_sizes: vec![2, 2],
            exec_ms: vec![0.5, 0.6],
            wall_s: 2.0,
            workers: 1,
        };
        assert_eq!(s.requests(), 4);
        assert_eq!(s.mean_batch_occupancy(), 2.0);
        assert_eq!(s.throughput_rps(), 2.0);
        assert!(s.render().contains("requests=4"));
        assert!(s.render().contains("p95="));
    }

    /// The zero-request case is fully defined: no NaN, no div-by-zero,
    /// renderable.
    #[test]
    fn empty_is_safe() {
        let s = ServeStats::default();
        assert!(s.latency().is_none());
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        let r = s.render();
        assert!(!r.contains("NaN") && !r.contains("inf"), "{r}");
        // requests observed but no wall time yet: still well-defined
        let s2 = ServeStats { latencies_ms: vec![1.0], workers: 1, ..Default::default() };
        assert_eq!(s2.throughput_rps(), 0.0);
        assert!(!s2.render().contains("NaN"), "{}", s2.render());
    }

    /// merge conserves request counts, concatenates samples and takes
    /// the max wall clock (workers run concurrently).
    #[test]
    fn merge_conserves_counts() {
        let mut fleet = ServeStats::default();
        let a = ServeStats {
            latencies_ms: vec![1.0, 2.0],
            batch_sizes: vec![2],
            exec_ms: vec![0.5],
            wall_s: 2.0,
            workers: 1,
        };
        let b = ServeStats {
            latencies_ms: vec![3.0, 4.0, 5.0],
            batch_sizes: vec![1, 2],
            exec_ms: vec![0.7, 0.9],
            wall_s: 3.0,
            workers: 1,
        };
        fleet.merge(&a);
        fleet.merge(&b);
        assert_eq!(fleet.requests(), a.requests() + b.requests());
        assert_eq!(fleet.batch_sizes.len(), 3);
        assert_eq!(fleet.exec_ms.len(), 3);
        assert_eq!(fleet.wall_s, 3.0);
        assert_eq!(fleet.workers, 2);
        // fleet throughput: total requests over the longest wall
        assert!((fleet.throughput_rps() - 5.0 / 3.0).abs() < 1e-12);
        assert!(fleet.render().contains("workers=2"));
    }

    #[test]
    fn render_workers_marks_dead_shards() {
        let alive = ServeStats { latencies_ms: vec![1.0], workers: 1, ..Default::default() };
        let out = ServeStats::render_workers(&[Some(alive), None]);
        assert!(out.contains("worker 0: requests=1"), "{out}");
        assert!(out.contains("worker 1: dead"), "{out}");
    }
}

//! The fleet wire format: length-prefixed binary frames over TCP.
//!
//! The process-shard fleet (`serve::fleet`) needs [`Request`]s to
//! cross a process boundary, so this module gives the serve protocol
//! a network shape: every message is one *frame* —
//!
//! ```text
//!   magic  b"DYF1"
//!   u8     version (1)
//!   u8     kind (request 0x01..; reply 0x81..)
//!   u32    payload length (LE, <= MAX_FRAME)
//!   ...    payload (kind-specific, little-endian)
//! ```
//!
//! Encoding is hand-rolled and total: every scalar is fixed-width LE
//! (`f64::to_le_bytes`, so scores survive the wire **bitwise** — the
//! fleet parity tests compare `to_bits`). Decoding goes through a
//! bounds-checked cursor: corrupt or truncated input produces an
//! error, never a panic and never an oversized allocation (lengths
//! are validated against the remaining bytes before any `Vec` is
//! reserved). Pinned by the roundtrip + mutation tests below.
//!
//! Three consumers:
//! * [`serve_connection`] — the shard-side loop turning frames into
//!   [`Request`]s whose [`ReplySink::Wire`] encodes replies back onto
//!   the connection's writer queue (one writer thread per connection
//!   multiplexes replies from the worker).
//! * `serve::fleet` — the front-end speaks this to its shard
//!   processes (requests, heartbeat pings, shutdown).
//! * [`NetClient`] — a plain blocking client for CLI demos, tests and
//!   external callers.

use std::io::{self, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::router::{reply_error, WorkerShared};
use super::server::{ReplySink, Request};
use super::stats::ServeStats;

const MAGIC: &[u8; 4] = b"DYF1";
const VERSION: u8 = 1;
/// Frame header bytes: magic + version + kind + payload length.
const HEADER: usize = 4 + 1 + 1 + 4;
/// Upper bound on a payload — large enough for any real batch of
/// tokens or stats snapshot, small enough that a corrupt length field
/// cannot drive a multi-GiB allocation.
pub const MAX_FRAME: usize = 1 << 24;

const K_SCORE: u8 = 0x01;
const K_GENERATE: u8 = 0x02;
const K_STATS: u8 = 0x03;
const K_PING: u8 = 0x04;
const K_SHUTDOWN: u8 = 0x05;
const K_SCORE_REPLY: u8 = 0x81;
const K_GEN_REPLY: u8 = 0x82;
const K_STATS_REPLY: u8 = 0x83;
const K_PONG: u8 = 0x84;

/// A serve request on the wire. `id` is caller-chosen and echoed on
/// the matching reply, so one connection can carry many in-flight
/// requests (the fleet front-end correlates on it).
#[derive(Debug, Clone, PartialEq)]
pub enum WireRequest {
    Score { id: u64, tokens: Vec<i32> },
    Generate { id: u64, prompt: Vec<i32>, max_new: u64 },
    Stats { id: u64 },
    /// Heartbeat: answered inline by the connection loop (not the
    /// worker) iff the worker is still alive.
    Ping { id: u64 },
    Shutdown,
}

#[derive(Debug, Clone, PartialEq)]
pub enum WireReply {
    Score { id: u64, result: Result<f64, String> },
    Generate { id: u64, result: Result<Vec<i32>, String> },
    Stats { id: u64, stats: ServeStats },
    Pong { id: u64 },
}

fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut out = Vec::with_capacity(HEADER + payload.len());
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32s(out: &mut Vec<u8>, vs: &[i32]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        put_f64(out, *v);
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut p = Vec::new();
    match req {
        WireRequest::Score { id, tokens } => {
            put_u64(&mut p, *id);
            put_i32s(&mut p, tokens);
            frame(K_SCORE, &p)
        }
        WireRequest::Generate { id, prompt, max_new } => {
            put_u64(&mut p, *id);
            put_i32s(&mut p, prompt);
            put_u64(&mut p, *max_new);
            frame(K_GENERATE, &p)
        }
        WireRequest::Stats { id } => {
            put_u64(&mut p, *id);
            frame(K_STATS, &p)
        }
        WireRequest::Ping { id } => {
            put_u64(&mut p, *id);
            frame(K_PING, &p)
        }
        WireRequest::Shutdown => frame(K_SHUTDOWN, &p),
    }
}

/// `fn(u64, T) -> Vec<u8>` encoders with exactly the shape
/// [`ReplySink::Wire`] stores.
pub fn encode_score_reply(id: u64, result: Result<f64, String>) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, id);
    match result {
        Ok(v) => {
            p.push(0);
            put_f64(&mut p, v);
        }
        Err(e) => {
            p.push(1);
            put_str(&mut p, &e);
        }
    }
    frame(K_SCORE_REPLY, &p)
}

pub fn encode_gen_reply(id: u64, result: Result<Vec<i32>, String>) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, id);
    match result {
        Ok(tokens) => {
            p.push(0);
            put_i32s(&mut p, &tokens);
        }
        Err(e) => {
            p.push(1);
            put_str(&mut p, &e);
        }
    }
    frame(K_GEN_REPLY, &p)
}

pub fn encode_stats_reply(id: u64, stats: ServeStats) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, id);
    put_f64s(&mut p, &stats.latencies_ms);
    put_u32(&mut p, stats.batch_sizes.len() as u32);
    for b in &stats.batch_sizes {
        put_u64(&mut p, *b as u64);
    }
    put_f64s(&mut p, &stats.exec_ms);
    put_f64(&mut p, stats.wall_s);
    put_u64(&mut p, stats.workers as u64);
    put_u32(&mut p, stats.spans.len() as u32);
    for (a, b) in &stats.spans {
        put_f64(&mut p, *a);
        put_f64(&mut p, *b);
    }
    put_u64(&mut p, stats.weight_heap_bytes);
    put_u64(&mut p, stats.weight_mapped_bytes);
    frame(K_STATS_REPLY, &p)
}

pub fn encode_pong(id: u64) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, id);
    frame(K_PONG, &p)
}

pub fn encode_reply(reply: &WireReply) -> Vec<u8> {
    match reply {
        WireReply::Score { id, result } => encode_score_reply(*id, result.clone()),
        WireReply::Generate { id, result } => encode_gen_reply(*id, result.clone()),
        WireReply::Stats { id, stats } => encode_stats_reply(*id, stats.clone()),
        WireReply::Pong { id } => encode_pong(*id),
    }
}

/// Bounds-checked little-endian reads over a frame payload. Every
/// `take` validates against the remaining bytes, so malformed input
/// errors instead of panicking; list lengths are additionally checked
/// element-width-times-count against the remainder before reserving.
struct Dec<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("corrupt frame: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// List length header, validated so `count * width` fits in the
    /// remaining payload before anything is allocated.
    fn list_len(&mut self, width: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.checked_mul(width).is_none_or(|total| total > self.remaining()) {
            bail!("corrupt frame: list of {n} x {width}B exceeds {} remaining", self.remaining());
        }
        Ok(n)
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.list_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            out.push(i32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        Ok(out)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.list_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.list_len(1)?;
        String::from_utf8(self.take(n)?.to_vec()).context("corrupt frame: string not utf-8")
    }

    fn finish(self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("corrupt frame: {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

pub fn decode_request(kind: u8, payload: &[u8]) -> Result<WireRequest> {
    let mut d = Dec::new(payload);
    let req = match kind {
        K_SCORE => WireRequest::Score { id: d.u64()?, tokens: d.i32s()? },
        K_GENERATE => {
            WireRequest::Generate { id: d.u64()?, prompt: d.i32s()?, max_new: d.u64()? }
        }
        K_STATS => WireRequest::Stats { id: d.u64()? },
        K_PING => WireRequest::Ping { id: d.u64()? },
        K_SHUTDOWN => WireRequest::Shutdown,
        other => bail!("unknown request frame kind 0x{other:02x}"),
    };
    d.finish()?;
    Ok(req)
}

fn decode_result_f64(d: &mut Dec) -> Result<Result<f64, String>> {
    match d.u8()? {
        0 => Ok(Ok(d.f64()?)),
        1 => Ok(Err(d.string()?)),
        t => bail!("corrupt frame: result tag {t}"),
    }
}

fn decode_result_tokens(d: &mut Dec) -> Result<Result<Vec<i32>, String>> {
    match d.u8()? {
        0 => Ok(Ok(d.i32s()?)),
        1 => Ok(Err(d.string()?)),
        t => bail!("corrupt frame: result tag {t}"),
    }
}

pub fn decode_reply(kind: u8, payload: &[u8]) -> Result<WireReply> {
    let mut d = Dec::new(payload);
    let reply = match kind {
        K_SCORE_REPLY => {
            WireReply::Score { id: d.u64()?, result: decode_result_f64(&mut d)? }
        }
        K_GEN_REPLY => {
            WireReply::Generate { id: d.u64()?, result: decode_result_tokens(&mut d)? }
        }
        K_STATS_REPLY => {
            let id = d.u64()?;
            let latencies_ms = d.f64s()?;
            let n = d.list_len(8)?;
            let mut batch_sizes = Vec::with_capacity(n);
            for _ in 0..n {
                batch_sizes.push(d.u64()? as usize);
            }
            let exec_ms = d.f64s()?;
            let wall_s = d.f64()?;
            let workers = d.u64()? as usize;
            let n = d.list_len(16)?;
            let mut spans = Vec::with_capacity(n);
            for _ in 0..n {
                spans.push((d.f64()?, d.f64()?));
            }
            let weight_heap_bytes = d.u64()?;
            let weight_mapped_bytes = d.u64()?;
            WireReply::Stats {
                id,
                stats: ServeStats {
                    latencies_ms,
                    batch_sizes,
                    exec_ms,
                    wall_s,
                    workers,
                    spans,
                    weight_heap_bytes,
                    weight_mapped_bytes,
                },
            }
        }
        K_PONG => WireReply::Pong { id: d.u64()? },
        other => bail!("unknown reply frame kind 0x{other:02x}"),
    };
    d.finish()?;
    Ok(reply)
}

/// Read one frame. `Ok(None)` on clean EOF (connection closed between
/// frames); anything else short of a full valid header + payload is an
/// error — a torn frame means the peer died mid-write and the
/// connection is unusable.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(u8, Vec<u8>)>> {
    let mut first = [0u8; 1];
    match r.read_exact(&mut first) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("read frame header"),
    }
    let mut rest = [0u8; HEADER - 1];
    r.read_exact(&mut rest).context("truncated frame header")?;
    let mut header = [0u8; HEADER];
    header[0] = first[0];
    header[1..].copy_from_slice(&rest);
    if &header[..4] != MAGIC {
        bail!("bad frame magic {:02x?} (not a DYF1 peer?)", &header[..4]);
    }
    if header[4] != VERSION {
        bail!("frame version {} (this build speaks {VERSION})", header[4]);
    }
    let kind = header[5];
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("truncated frame payload")?;
    Ok(Some((kind, payload)))
}

/// Serve one TCP connection against a worker's request channel: the
/// reader (this call) decodes request frames into [`Request`]s whose
/// [`ReplySink::Wire`] pushes encoded replies onto a queue drained by
/// a per-connection writer thread — so many requests can be in flight
/// and replies interleave in completion order, correlated by id.
///
/// Pings are answered inline iff the worker is still alive: a dead
/// worker means no pong and (on the next request) a closed connection,
/// which is exactly the signal the fleet front-end routes around.
/// A Shutdown frame is forwarded to the worker and raises `stop` so
/// the enclosing accept loop exits too. Returns when the peer closes,
/// errors on torn/corrupt frames.
pub(crate) fn serve_connection(
    stream: TcpStream,
    tx: &Sender<Request>,
    shared: &Arc<WorkerShared>,
    stop: &Arc<AtomicBool>,
) -> Result<()> {
    let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
    let mut wstream = stream.try_clone().context("clone connection for writer")?;
    // xtask:allow(thread_spawn): per-connection reply writer — a
    // long-lived mux drain, not kernel parallelism.
    let writer = std::thread::Builder::new()
        .name("serve-net-writer".into())
        .spawn(move || {
            for f in wrx {
                if wstream.write_all(&f).is_err() {
                    break; // peer gone: replies have nowhere to go
                }
            }
        })
        .context("spawn connection writer")?;
    let mut reader = BufReader::new(stream);
    let result = (|| -> Result<()> {
        while let Some((kind, payload)) = read_frame(&mut reader)? {
            let req = match decode_request(kind, &payload)? {
                WireRequest::Score { id, tokens } => Request::Score {
                    tokens,
                    resp: ReplySink::Wire { id, tx: wtx.clone(), encode: encode_score_reply },
                },
                WireRequest::Generate { id, prompt, max_new } => Request::Generate {
                    prompt,
                    max_new: max_new as usize,
                    resp: ReplySink::Wire { id, tx: wtx.clone(), encode: encode_gen_reply },
                },
                WireRequest::Stats { id } => Request::Stats {
                    resp: ReplySink::Wire { id, tx: wtx.clone(), encode: encode_stats_reply },
                },
                WireRequest::Ping { id } => {
                    if shared.is_alive() {
                        let _ = wtx.send(encode_pong(id));
                        continue;
                    }
                    // dead worker: stop ponging and hang up, so the
                    // front-end's heartbeat flags this shard
                    break;
                }
                WireRequest::Shutdown => {
                    let _ = tx.send(Request::Shutdown);
                    stop.store(true, Ordering::Release);
                    break;
                }
            };
            if let Err(mpsc::SendError(back)) = tx.send(req) {
                // worker gone: explicit error reply, then hang up
                reply_error(back, "serve worker is down");
                break;
            }
        }
        Ok(())
    })();
    drop(wtx); // writer drains queued replies, then exits
    let _ = writer.join();
    result
}

/// Blocking client for the fleet front-end (or a single shard): one
/// request in flight at a time, so the next reply frame is always the
/// matching one — id correlation is still checked, as self-diagnosis.
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to serve front-end {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(NetClient { stream, next_id: 1 })
    }

    fn roundtrip(&mut self, req: &WireRequest) -> Result<WireReply> {
        self.stream.write_all(&encode_request(req))?;
        match read_frame(&mut self.stream)? {
            Some((kind, payload)) => decode_reply(kind, &payload),
            None => bail!("connection closed before reply (serve fleet down?)"),
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    pub fn score(&mut self, tokens: Vec<i32>) -> Result<f64> {
        let id = self.fresh_id();
        match self.roundtrip(&WireRequest::Score { id, tokens })? {
            WireReply::Score { id: rid, result } if rid == id => {
                result.map_err(|e| anyhow!(e))
            }
            other => bail!("mismatched reply to score #{id}: {other:?}"),
        }
    }

    pub fn generate(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>> {
        let id = self.fresh_id();
        let req = WireRequest::Generate { id, prompt, max_new: max_new as u64 };
        match self.roundtrip(&req)? {
            WireReply::Generate { id: rid, result } if rid == id => {
                result.map_err(|e| anyhow!(e))
            }
            other => bail!("mismatched reply to generate #{id}: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<ServeStats> {
        let id = self.fresh_id();
        match self.roundtrip(&WireRequest::Stats { id })? {
            WireReply::Stats { id: rid, stats } if rid == id => Ok(stats),
            other => bail!("mismatched reply to stats #{id}: {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        let id = self.fresh_id();
        match self.roundtrip(&WireRequest::Ping { id })? {
            WireReply::Pong { id: rid } if rid == id => Ok(()),
            other => bail!("mismatched reply to ping #{id}: {other:?}"),
        }
    }

    /// Fire-and-forget: the peer drains everything sent before this,
    /// then exits (TCP ordering makes Shutdown arrive last).
    pub fn shutdown(mut self) -> Result<()> {
        self.stream.write_all(&encode_request(&WireRequest::Shutdown))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats() -> ServeStats {
        ServeStats {
            latencies_ms: vec![1.5, 2.25, f64::MAX],
            batch_sizes: vec![1, 8, 64],
            exec_ms: vec![0.125],
            wall_s: 12.5,
            workers: 3,
            spans: vec![(1e9, 1e9 + 3.5), (1e9 + 10.0, 1e9 + 11.0)],
            weight_heap_bytes: 123,
            weight_mapped_bytes: 1 << 20,
        }
    }

    fn requests() -> Vec<WireRequest> {
        vec![
            WireRequest::Score { id: 0, tokens: vec![] },
            WireRequest::Score { id: u64::MAX, tokens: vec![i32::MIN, -1, 0, 1, i32::MAX] },
            WireRequest::Generate { id: 7, prompt: vec![3, 1, 4, 1, 5], max_new: 32 },
            WireRequest::Generate { id: 8, prompt: vec![0], max_new: u64::MAX },
            WireRequest::Stats { id: 42 },
            WireRequest::Ping { id: 99 },
            WireRequest::Shutdown,
        ]
    }

    fn replies() -> Vec<WireReply> {
        vec![
            WireReply::Score { id: 1, result: Ok(-1234.5678) },
            // bit-exactness matters: NaN payloads and negative zero
            WireReply::Score { id: 2, result: Ok(-0.0) },
            WireReply::Score { id: 3, result: Err("prompt token 9 out of vocab".into()) },
            WireReply::Generate { id: 4, result: Ok(vec![5, 6, 7]) },
            WireReply::Generate { id: 5, result: Ok(vec![]) },
            WireReply::Generate { id: 6, result: Err("no live serve workers".into()) },
            WireReply::Stats { id: 7, stats: sample_stats() },
            WireReply::Stats { id: 8, stats: ServeStats::default() },
            WireReply::Pong { id: 9 },
        ]
    }

    fn read_one(bytes: &[u8]) -> Result<Option<(u8, Vec<u8>)>> {
        read_frame(&mut io::Cursor::new(bytes))
    }

    /// Exhaustive roundtrip over every variant, including edge values
    /// (empty lists, extremes, -0.0).
    #[test]
    fn requests_roundtrip() {
        for req in requests() {
            let bytes = encode_request(&req);
            let (kind, payload) = read_one(&bytes).unwrap().expect("one frame");
            assert_eq!(decode_request(kind, &payload).unwrap(), req);
            // request kinds are not reply kinds
            assert!(decode_reply(kind, &payload).is_err());
        }
    }

    #[test]
    fn replies_roundtrip() {
        for reply in replies() {
            let bytes = encode_reply(&reply);
            let (kind, payload) = read_one(&bytes).unwrap().expect("one frame");
            assert_eq!(decode_reply(kind, &payload).unwrap(), reply);
            assert!(decode_request(kind, &payload).is_err());
        }
    }

    /// f64 crosses the wire bitwise: NaN stays the same NaN, -0.0
    /// stays negative.
    #[test]
    fn floats_are_bitwise() {
        let nan = f64::from_bits(0x7ff8_0000_dead_beef);
        let bytes = encode_score_reply(1, Ok(nan));
        let (kind, payload) = read_one(&bytes).unwrap().unwrap();
        let WireReply::Score { result: Ok(back), .. } =
            decode_reply(kind, &payload).unwrap()
        else {
            panic!("wrong reply shape")
        };
        assert_eq!(back.to_bits(), nan.to_bits());
    }

    /// Two frames back to back parse as two frames; zero bytes is a
    /// clean EOF, not an error.
    #[test]
    fn streams_of_frames() {
        let mut bytes = encode_request(&WireRequest::Ping { id: 1 });
        bytes.extend_from_slice(&encode_request(&WireRequest::Stats { id: 2 }));
        let mut cur = io::Cursor::new(bytes.as_slice());
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().0, K_PING);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().0, K_STATS);
        assert!(read_frame(&mut cur).unwrap().is_none());
        assert!(read_one(&[]).unwrap().is_none());
    }

    /// Every strict prefix of a valid frame is a torn frame: an error,
    /// never a hang, never a panic (prefix 0 is the clean EOF).
    #[test]
    fn truncated_frames_error() {
        let bytes = encode_request(&WireRequest::Score { id: 5, tokens: vec![1, 2, 3] });
        for cut in 1..bytes.len() {
            let r = read_one(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut}/{} bytes must error", bytes.len());
        }
    }

    /// Header corruption is caught by name: magic, version, oversized
    /// length.
    #[test]
    fn corrupt_headers_error() {
        let good = encode_request(&WireRequest::Ping { id: 1 });
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(read_one(&bad_magic).unwrap_err().to_string().contains("magic"));
        let mut bad_version = good.clone();
        bad_version[4] = 9;
        assert!(read_one(&bad_version).unwrap_err().to_string().contains("version"));
        let mut bad_len = good.clone();
        bad_len[6..10].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_one(&bad_len).unwrap_err().to_string().contains("MAX_FRAME"));
        // unknown kinds fail decode, both directions
        let (_, payload) = read_one(&good).unwrap().unwrap();
        assert!(decode_request(0x7f, &payload).is_err());
        assert!(decode_reply(0x00, &payload).is_err());
    }

    /// A length header claiming more elements than the payload holds
    /// must error before allocating, and trailing bytes are rejected.
    #[test]
    fn corrupt_payloads_error() {
        // i32 list claiming u32::MAX entries in an 8-byte payload
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u32(&mut p, u32::MAX);
        assert!(decode_request(K_SCORE, &p).is_err());
        // trailing garbage after a well-formed body
        let mut ok = Vec::new();
        put_u64(&mut ok, 1);
        put_i32s(&mut ok, &[4, 5]);
        assert!(decode_request(K_SCORE, &ok).is_ok());
        ok.push(0);
        assert!(decode_request(K_SCORE, &ok).is_err());
        // bad result tag
        let mut r = Vec::new();
        put_u64(&mut r, 1);
        r.push(7);
        assert!(decode_reply(K_SCORE_REPLY, &r).is_err());
    }

    /// Fuzz-ish sweep: pseudo-random byte soup and single-byte
    /// mutations of valid frames must decode to Ok or Err — never
    /// panic, never allocate absurdly. (Deterministic LCG, no RNG
    /// dependency.)
    #[test]
    fn hostile_bytes_never_panic() {
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u8
        };
        for len in [0usize, 1, 9, 10, 64, 257] {
            for _ in 0..50 {
                let bytes: Vec<u8> = (0..len).map(|_| next()).collect();
                let _ = read_one(&bytes); // must return, not panic
            }
        }
        // every single-byte mutation of every valid frame
        let mut corpus: Vec<Vec<u8>> = requests().iter().map(encode_request).collect();
        corpus.extend(replies().iter().map(encode_reply));
        for frame_bytes in corpus {
            for i in 0..frame_bytes.len() {
                let mut mutant = frame_bytes.clone();
                mutant[i] ^= 0xa5;
                if let Ok(Some((kind, payload))) = read_one(&mutant) {
                    let _ = decode_request(kind, &payload);
                    let _ = decode_reply(kind, &payload);
                }
            }
        }
    }
}

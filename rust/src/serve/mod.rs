//! Batched inference server: dynamic batcher over backend executables.
//!
//! The L3 "router" component: clients submit scoring or greedy-
//! generation requests from any thread; a dedicated backend thread
//! (backend handles are not Send) accumulates them into padded batches
//! (up to `max_batch`, bounded by `window_ms`), executes one backend
//! call per batch, and reports latency/throughput/occupancy statistics
//! — the serving-shaped face of the DYAD speedup story. Runs on the
//! native backend by default (`ServeConfig::backend`).

mod batcher;
mod server;
mod stats;

pub use batcher::Batcher;
pub use server::{Request, ServeConfig, ServerHandle};
pub use stats::ServeStats;

//! Batched inference serving: dynamic batcher + sharded worker fleet.
//!
//! The L3 serving subsystem. Clients submit scoring or greedy-
//! generation requests from any thread over a `Sender<Request>`.
//! Behind it, each **worker** is a dedicated backend-owning thread
//! (backend handles are not Send) that binds the model weights
//! resident once (`Bindings`), accumulates requests into padded
//! batches (up to `max_batch`, bounded by `window_ms`), and executes
//! one backend call per batch — the serving-shaped face of the DYAD
//! speedup story. Generation is KV-cache incremental with continuous
//! batching: each worker binds a resident decode cache
//! (`decode_step` artifact), advances every in-flight generation by
//! one token per engine call, admits new prompts into free cache
//! lanes at step boundaries, and retires finished ones immediately —
//! O(1) staged bytes and O(d) FLOPs per generated token instead of
//! re-scoring the whole prefix. Runs on the native backend by default
//! (`ServeConfig::backend`).
//!
//! Three front-ends share the [`Request`] protocol, at two sharding
//! levels:
//!
//! * [`ServerHandle`] — exactly one worker (the original
//!   single-threaded path, still the simplest embedding);
//! * [`Router`] — **thread-level** sharding: `n_workers` worker
//!   shards in this process behind a dispatcher thread with pluggable
//!   dispatch ([`DispatchPolicy`]: round-robin or least-pending),
//!   per-worker [`ServeStats`] merged into a fleet view, worker-death
//!   detection (error replies, never hangs) and graceful drain on
//!   shutdown. Weight residency is per worker: `n` shards hold `n`
//!   copies.
//! * [`Fleet`] — **process-level** sharding: `n_shards` shard
//!   *processes* (`repro serve --shard`, each running the same worker
//!   loop behind a TCP accept loop) behind the same dispatch policies,
//!   speaking the [`net`] length-prefixed wire format. Processes add
//!   crash isolation (heartbeat + reconnect route around a killed
//!   shard) and, with a DYW1 weight file
//!   ([`ServeConfig::weights_file`],
//!   [`crate::runtime::catalog::mmap`]), shared read-only weight
//!   pages — fleet resident weight bytes stay ~1×, not `n`×. Remote
//!   clients connect through [`Fleet::serve_net`] with [`NetClient`].
//!
//! The dispatch logic itself is shared (`router::pick_shard`), so the
//! two sharding levels cannot drift in routing behaviour.

mod batcher;
mod fleet;
pub mod net;
mod router;
mod server;
mod stats;

pub use batcher::Batcher;
pub use fleet::{run_shard, Fleet, FleetConfig};
pub use net::NetClient;
pub use router::{DispatchPolicy, Router};
pub use server::{ReplySink, Request, ServeConfig, ServerHandle};
pub use stats::ServeStats;

//! Sharded serving: a router front-end over N backend-owning workers.
//!
//! [`Router::start`] spawns `cfg.n_workers` copies of the
//! [`super::server`] worker loop — each one opens its **own** backend
//! (they are cheap to open natively) and binds its weights resident
//! once (`Bindings`), so per-worker weight residency is the unit of
//! sharding — plus one dispatcher thread that owns the client-facing
//! [`Request`] receiver and fans requests out:
//!
//! ```text
//!  clients ──Sender<Request>──▶ dispatcher ──┬──▶ worker 0 (backend + resident weights)
//!            (round-robin /                  ├──▶ worker 1 (backend + resident weights)
//!             least-pending)                 └──▶ worker n-1 ...
//! ```
//!
//! **Thread budget.** Each shard's native backend owns a persistent
//! [`crate::runtime::pool::ThreadPool`] sized to its share of the
//! machine, computed by [`lane_split`]: `num_threads()` cores divided
//! over the workers with the remainder handed out one core at a time
//! (so 8 cores over 3 workers is `[3, 3, 2]`, not `[2, 2, 2]` with
//! two cores stranded — the old truncating `num_threads() / n_workers`
//! split lost up to `n_workers - 1` cores), min 1 each, unless the
//! explicit `ServeConfig::threads_per_worker` / CLI
//! `serve --threads-per-worker N` override pins every shard. Before
//! any split, every shard's kernels spawned `num_threads()` scoped
//! threads per call, so an `n`-worker fleet could oversubscribe the
//! machine `n`-fold under concurrent load; now the fleet's resident
//! worker threads total at most `num_threads()` under the default
//! split. Pool size does not affect results — kernels are bitwise
//! thread-count-deterministic — only contention.
//!
//! Contracts held by the test suite (`tests/serve_test.rs`,
//! `tests/failure_injection.rs`):
//!
//! * **Parity** — scoring through `n` workers is bitwise identical to
//!   one worker (same seed ⇒ same resident weights per shard; the
//!   kernels are bitwise thread-deterministic).
//! * **Stats conservation** — the fleet view is
//!   [`ServeStats::merge`]d from per-worker snapshots, so fleet
//!   `requests()` equals the sum over shards.
//! * **Death, not hangs** — a dead shard (panic, failed startup) is
//!   detected via its [`WorkerShared`] liveness flag and failed
//!   channel sends; its in-flight requests resolve as error replies
//!   (dropped reply senders disconnect), new requests re-route to
//!   live shards, and only when no shard is left do clients get an
//!   explicit "no live serve workers" error.
//! * **Graceful drain** — `shutdown` forwards every already-accepted
//!   request before the workers flush their final batches and exit,
//!   then reports any shard that exited abnormally (startup failure
//!   or crash) instead of returning Ok on a fleet that never served.

use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::server::{
    request_generate, request_score, request_stats, worker, Request, ServeConfig,
};
use super::stats::ServeStats;

/// How long stats gathers wait on a single worker before skipping it
/// (a worker only lags this far behind if it is mid-crash).
const GATHER_TIMEOUT: Duration = Duration::from_secs(10);

/// How the dispatcher picks a shard for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle through the live workers in index order — deterministic,
    /// perfectly balanced under uniform request cost.
    RoundRobin,
    /// Pick the live worker with the fewest in-flight requests
    /// (lowest index on ties) — adapts to uneven request cost
    /// (e.g. long generations pinning one shard).
    LeastPending,
}

impl DispatchPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastPending => "least-pending",
        }
    }
}

impl FromStr for DispatchPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<DispatchPolicy> {
        match s {
            "round-robin" | "rr" => Ok(DispatchPolicy::RoundRobin),
            "least-pending" | "lp" => Ok(DispatchPolicy::LeastPending),
            other => bail!(
                "unknown dispatch policy {other:?} (expected round-robin|least-pending)"
            ),
        }
    }
}

/// Per-shard state shared between the worker thread and the
/// dispatcher: in-flight request count (for least-pending dispatch)
/// and a liveness flag flipped when the worker exits by any path,
/// panic included.
#[derive(Debug)]
pub(crate) struct WorkerShared {
    pending: AtomicUsize,
    alive: AtomicBool,
}

impl WorkerShared {
    pub(crate) fn new() -> WorkerShared {
        WorkerShared { pending: AtomicUsize::new(0), alive: AtomicBool::new(true) }
    }

    pub(crate) fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    pub(crate) fn mark_dead(&self) {
        self.alive.store(false, Ordering::Release);
    }

    pub(crate) fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    pub(crate) fn inc_pending(&self) {
        self.pending.fetch_add(1, Ordering::AcqRel);
    }

    /// Saturating decrement: the standalone [`super::ServerHandle`]
    /// path runs a worker with nobody incrementing, so replies there
    /// must not wrap the counter.
    pub(crate) fn dec_pending(&self) {
        let _ = self
            .pending
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1));
    }
}

struct WorkerLink {
    tx: Sender<Request>,
    shared: Arc<WorkerShared>,
    join: Option<JoinHandle<Result<()>>>,
}

/// The sharded serving front-end. Clients talk to it exactly like a
/// [`super::ServerHandle`] (same [`Request`] enum, same helpers), so
/// swapping one worker for a fleet is a config change.
pub struct Router {
    tx: Sender<Request>,
    worker_txs: Vec<Sender<Request>>,
    shares: Vec<Arc<WorkerShared>>,
    dispatcher: Option<JoinHandle<Result<()>>>,
}

/// Divide `total` units (cores) over `n` lanes: every lane gets at
/// least `total / n` and the first `total % n` lanes get one extra, so
/// nothing is stranded by truncating division. Each share is min 1 —
/// lanes beyond `total` oversubscribe rather than sit threadless.
pub(crate) fn lane_split(total: usize, n: usize) -> Vec<usize> {
    let n = n.max(1);
    let base = total / n;
    let extra = total % n;
    (0..n).map(|i| (base + usize::from(i < extra)).max(1)).collect()
}

impl Router {
    /// Spawn `cfg.n_workers` worker shards (at least one) and the
    /// dispatcher that routes per `cfg.dispatch`.
    pub fn start(cfg: ServeConfig) -> Router {
        let n = cfg.n_workers.max(1);
        let policy = cfg.dispatch;
        // remainder-aware thread split (unless the config pins an
        // explicit per-worker count): workers have no index, so their
        // shares are assigned here
        let split = lane_split(crate::dyad::kernel::num_threads(), n);
        let mut links = Vec::with_capacity(n);
        for i in 0..n {
            let (wtx, wrx) = mpsc::channel();
            let shared = Arc::new(WorkerShared::new());
            let mut wcfg = cfg.clone();
            if wcfg.threads_per_worker.is_none() {
                wcfg.threads_per_worker = Some(split[i]);
            }
            let wshared = shared.clone();
            // xtask:allow(thread_spawn): serve workers are long-lived
            // backend-owning threads, not kernel parallelism — the pool
            // covers kernels inside each worker.
            let join = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || worker(wcfg, wrx, wshared))
                .expect("spawn serve worker thread");
            links.push(WorkerLink { tx: wtx, shared, join: Some(join) });
        }
        let worker_txs: Vec<_> = links.iter().map(|l| l.tx.clone()).collect();
        let shares: Vec<_> = links.iter().map(|l| l.shared.clone()).collect();
        let (tx, rx) = mpsc::channel();
        // xtask:allow(thread_spawn): the dispatcher is a long-lived
        // routing thread, not kernel parallelism.
        let dispatcher = std::thread::Builder::new()
            .name("serve-router".into())
            .spawn(move || dispatch_loop(rx, links, policy))
            .expect("spawn serve router thread");
        Router { tx, worker_txs, shares, dispatcher: Some(dispatcher) }
    }

    /// A clonable handle for client threads.
    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    pub fn score(&self, tokens: Vec<i32>) -> Result<f64> {
        request_score(&self.tx, tokens)
    }

    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>> {
        request_generate(&self.tx, prompt, max_new)
    }

    /// Fleet-level stats: per-worker snapshots merged by the
    /// dispatcher ([`ServeStats::merge`]); `workers` counts the live
    /// shards that answered.
    pub fn stats(&self) -> Result<ServeStats> {
        request_stats(&self.tx)
    }

    /// Per-shard snapshots, in worker-index order; `None` for a shard
    /// that is dead (or died before answering). Queries all shards
    /// first, then collects, so one slow shard delays the gather once
    /// rather than serially.
    pub fn worker_stats(&self) -> Vec<Option<ServeStats>> {
        let waits: Vec<_> = self
            .worker_txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request::Stats { resp: rtx.into() }).ok().map(|_| rrx)
            })
            .collect();
        waits
            .into_iter()
            .map(|w| w.and_then(|rrx| rrx.recv_timeout(GATHER_TIMEOUT).ok()))
            .collect()
    }

    pub fn n_workers(&self) -> usize {
        self.shares.len()
    }

    /// Indices of shards whose worker thread has exited (crash or
    /// startup failure).
    pub fn dead_workers(&self) -> Vec<usize> {
        self.shares
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.is_alive())
            .map(|(i, _)| i)
            .collect()
    }

    /// In-flight request count per shard (dispatched, not yet
    /// replied) — the signal least-pending dispatch routes on.
    pub fn pending_per_worker(&self) -> Vec<usize> {
        self.shares.iter().map(|s| s.pending()).collect()
    }

    /// Failure injection (tests, soak runs): crash one shard. Its
    /// queued requests resolve as error replies; the fleet keeps
    /// serving on the remaining shards.
    #[doc(hidden)]
    pub fn kill_worker(&self, index: usize) -> Result<()> {
        let tx = self
            .worker_txs
            .get(index)
            .ok_or_else(|| anyhow!("no worker {index} (fleet of {})", self.n_workers()))?;
        tx.send(Request::Crash)
            .map_err(|_| anyhow!("worker {index} is already dead"))
    }

    /// Graceful drain: every request accepted before this call is
    /// dispatched and flushed by its worker before the fleet exits.
    /// Errors if any worker exited abnormally — a startup failure
    /// (bad arch, missing artifacts) or a crash — naming the shard,
    /// so a fleet that never really served cannot shut down silently.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Request::Shutdown);
        match self.dispatcher.take() {
            Some(j) => j.join().map_err(|_| anyhow!("serve router thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }
}

fn dispatch_loop(
    rx: Receiver<Request>,
    mut links: Vec<WorkerLink>,
    policy: DispatchPolicy,
) -> Result<()> {
    let mut rr = 0usize;
    loop {
        match rx.recv() {
            // fleet-level stats are answered here: gather + merge
            Ok(Request::Stats { resp }) => {
                resp.send(fleet_stats(&links));
            }
            Ok(Request::Shutdown) => break,
            Ok(req) => dispatch_one(req, &links, policy, &mut rr),
            // every client sender (Router included) dropped
            Err(_) => break,
        }
    }
    // graceful drain: workers see Shutdown only after everything the
    // dispatcher already forwarded, flush their batches, then exit;
    // abnormal worker exits are collected and surfaced by shutdown()
    for l in &links {
        let _ = l.tx.send(Request::Shutdown);
    }
    let mut failures = Vec::new();
    for (i, l) in links.iter_mut().enumerate() {
        if let Some(j) = l.join.take() {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(format!("worker {i}: {e:#}")),
                Err(_) => failures.push(format!("worker {i}: panicked")),
            }
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        bail!("serve worker failures: {}", failures.join("; "))
    }
}

/// Route one request. A failed send means the shard's receiver is
/// gone: mark it dead, take the request back (mpsc returns it) and
/// retry on the next live shard; with no shard left, reply an
/// explicit error — the client never hangs.
fn dispatch_one(mut req: Request, links: &[WorkerLink], policy: DispatchPolicy, rr: &mut usize) {
    for _ in 0..links.len() {
        let Some(i) = pick(links, policy, rr) else { break };
        links[i].shared.inc_pending();
        match links[i].tx.send(req) {
            Ok(()) => return,
            Err(mpsc::SendError(back)) => {
                links[i].shared.dec_pending();
                links[i].shared.mark_dead();
                req = back;
            }
        }
    }
    reply_error(req, "no live serve workers");
}

fn pick(links: &[WorkerLink], policy: DispatchPolicy, rr: &mut usize) -> Option<usize> {
    pick_shard(
        links.len(),
        |i| links[i].shared.is_alive(),
        |i| links[i].shared.pending(),
        policy,
        rr,
    )
}

/// Policy-driven shard selection over any fleet shape — thread-level
/// ([`Router`]) and process-level ([`super::fleet::Fleet`]) fronts
/// both route through this, so the two sharding levels cannot drift
/// in dispatch behaviour. Allocation-free: runs once per request.
pub(crate) fn pick_shard(
    n: usize,
    alive: impl Fn(usize) -> bool,
    pending: impl Fn(usize) -> usize,
    policy: DispatchPolicy,
    rr: &mut usize,
) -> Option<usize> {
    let live = || (0..n).filter(|&i| alive(i));
    match policy {
        DispatchPolicy::RoundRobin => {
            let n_live = live().count();
            if n_live == 0 {
                return None;
            }
            let k = *rr % n_live;
            *rr += 1;
            // a shard can die between the count and this scan (flags
            // only flip live -> dead): fall back to the first live one
            live().nth(k).or_else(|| live().next())
        }
        // min_by_key keeps the first minimum: lowest index wins ties
        DispatchPolicy::LeastPending => live().min_by_key(|&i| pending(i)),
    }
}

pub(crate) fn reply_error(req: Request, msg: &str) {
    match req {
        Request::Score { resp, .. } => {
            resp.send(Err(msg.to_string()));
        }
        Request::Generate { resp, .. } => {
            resp.send(Err(msg.to_string()));
        }
        // Stats is answered by the dispatcher and never dispatched, so
        // it cannot land here; dropping the reply sender (not sending
        // fake zeroed stats) keeps the client erroring if that changes
        Request::Stats { .. } | Request::Shutdown | Request::Crash => {}
    }
}

/// Merge per-worker snapshots into the fleet view. Dead shards are
/// skipped (their samples died with them); `workers` ends up as the
/// number of live shards that answered.
fn fleet_stats(links: &[WorkerLink]) -> ServeStats {
    let mut waits = Vec::new();
    for l in links {
        if !l.shared.is_alive() {
            continue;
        }
        let (rtx, rrx) = mpsc::channel();
        if l.tx.send(Request::Stats { resp: rtx.into() }).is_ok() {
            waits.push(rrx);
        }
    }
    let mut fleet = ServeStats::default();
    for rrx in waits {
        if let Ok(snap) = rrx.recv_timeout(GATHER_TIMEOUT) {
            fleet.merge(&snap);
        }
    }
    fleet
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression for the stranded-core split: the remainder of
    /// `total / n` is handed out one core at a time instead of lost.
    #[test]
    fn lane_split_distributes_remainder() {
        assert_eq!(lane_split(8, 3), vec![3, 3, 2]);
        assert_eq!(lane_split(7, 4), vec![2, 2, 2, 1]);
        assert_eq!(lane_split(9, 2), vec![5, 4]);
        assert_eq!(lane_split(6, 3), vec![2, 2, 2]);
        // non-dividing pairs always use every core
        for total in 1..=16 {
            for n in 1..=total {
                let split = lane_split(total, n);
                assert_eq!(split.len(), n);
                assert_eq!(split.iter().sum::<usize>(), total, "({total}, {n})");
                let (min, max) = (split.iter().min().unwrap(), split.iter().max().unwrap());
                assert!(max - min <= 1, "({total}, {n}): uneven split {split:?}");
            }
        }
    }

    /// More lanes than cores: everyone still gets a thread (min 1),
    /// and n = 0 is clamped to one lane.
    #[test]
    fn lane_split_clamps_degenerate_shapes() {
        assert_eq!(lane_split(2, 5), vec![1, 1, 1, 1, 1]);
        assert_eq!(lane_split(4, 0), vec![4]);
        assert_eq!(lane_split(0, 3), vec![1, 1, 1]);
    }

    #[test]
    fn pick_shard_skips_dead_and_balances() {
        let alive = [true, false, true];
        let pending = [5usize, 0, 2];
        let mut rr = 0;
        // round-robin cycles the two live shards
        let a = pick_shard(3, |i| alive[i], |i| pending[i], DispatchPolicy::RoundRobin, &mut rr);
        let b = pick_shard(3, |i| alive[i], |i| pending[i], DispatchPolicy::RoundRobin, &mut rr);
        let c = pick_shard(3, |i| alive[i], |i| pending[i], DispatchPolicy::RoundRobin, &mut rr);
        assert_eq!((a, b, c), (Some(0), Some(2), Some(0)));
        // least-pending picks the live shard with the smallest load
        let mut rr = 0;
        let lp =
            pick_shard(3, |i| alive[i], |i| pending[i], DispatchPolicy::LeastPending, &mut rr);
        assert_eq!(lp, Some(2));
        // all dead: no pick, never a panic
        let mut rr = 0;
        assert_eq!(pick_shard(3, |_| false, |_| 0, DispatchPolicy::RoundRobin, &mut rr), None);
        assert_eq!(pick_shard(3, |_| false, |_| 0, DispatchPolicy::LeastPending, &mut rr), None);
    }
}

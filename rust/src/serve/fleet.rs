//! Process-shard serving: shard *processes* behind a TCP front-end.
//!
//! [`super::Router`] shards at the thread level — N backend-owning
//! threads in one process. [`Fleet`] promotes the same topology one
//! level up: N shard **processes** (each running the identical
//! [`super::server::worker`] loop behind [`run_shard`]'s TCP accept
//! loop), a dispatcher that speaks the [`super::net`] wire format to
//! them, and the same [`DispatchPolicy`] routing via the shared
//! [`pick_shard`] — thread- and process-level fronts cannot drift in
//! dispatch behaviour.
//!
//! ```text
//!  clients ──Sender<Request>──▶ dispatcher ──TCP──▶ shard 0 (process: worker + weights)
//!    (or TCP via Fleet::serve_net           ──TCP──▶ shard 1 ...
//!     + NetClient)                          ──TCP──▶ shard n-1
//! ```
//!
//! What a process boundary buys over threads:
//! * **Isolation** — a shard can segfault, abort or be OOM-killed
//!   without taking the fleet down; the router's dead-thread handling
//!   generalises to dead processes (heartbeat + connection EOF).
//! * **Shared weights** — every shard maps the same read-only DYW1
//!   weight file (`serve.weights_file`,
//!   [`crate::runtime::catalog::mmap`]), so fleet resident weight
//!   bytes stay ~1× rather than N× (asserted by
//!   `benches/fleet_sweep.rs`).
//!
//! Failure contract (pinned in `tests/fleet_test.rs`): a killed shard
//! process is detected by the heartbeat (`try_wait` + wire pings) and
//! by its connection closing; its in-flight requests resolve as error
//! replies naming the shard, new requests route around it, and
//! [`Fleet::shutdown`] names every corpse instead of hanging on it.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::net::{
    decode_reply, encode_request, read_frame, serve_connection, WireReply, WireRequest,
};
use super::router::{lane_split, pick_shard, reply_error, DispatchPolicy, WorkerShared};
use super::server::{
    request_generate, request_score, request_stats, worker, ReplySink, Request, ServeConfig,
};
use super::stats::ServeStats;

/// How long a stats gather waits per shard before skipping it.
const GATHER_TIMEOUT: Duration = Duration::from_secs(10);
/// How long shutdown waits for a shard process to drain and exit
/// before killing it and naming the corpse.
const SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(60);
/// Missed-pong budget: a shard is declared dead after this many
/// heartbeat intervals without a pong.
const PONG_GRACE: u32 = 4;

#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-shard serve config, forwarded to every shard process
    /// (`n_workers`/`dispatch` describe the *fleet* here: each shard
    /// process runs exactly one worker).
    pub serve: ServeConfig,
    pub n_shards: usize,
    /// The `repro` binary to spawn shards from —
    /// `std::env::current_exe()` for the CLI,
    /// `env!("CARGO_BIN_EXE_repro")` in tests and benches.
    pub shard_binary: PathBuf,
    /// Heartbeat interval (process poll + wire ping per live shard).
    pub heartbeat_ms: u64,
}

impl FleetConfig {
    pub fn new(serve: ServeConfig, n_shards: usize, shard_binary: PathBuf) -> FleetConfig {
        FleetConfig { serve, n_shards, shard_binary, heartbeat_ms: 200 }
    }
}

/// What the front-end holds per in-flight request: where the reply
/// goes once the shard's frame comes back (or an error if it never
/// does).
enum PendingReply {
    Score(ReplySink<Result<f64, String>>),
    Generate(ReplySink<Result<Vec<i32>, String>>),
    /// Stats gathers fan out to every live shard; each snapshot lands
    /// on this channel and the dispatcher merges.
    Stats(Sender<ServeStats>),
}

/// Front-end state for one shard process. `shared` reuses the
/// router's per-shard liveness + pending counters, so
/// [`pick_shard`] routes identically at both sharding levels.
struct ShardLink {
    index: usize,
    addr: String,
    child: Mutex<Child>,
    /// Write half of the connection (`None` once the shard is dead).
    /// Locked per frame, so dispatcher writes and heartbeat pings
    /// never interleave mid-frame.
    writer: Mutex<Option<TcpStream>>,
    pending: Mutex<HashMap<u64, PendingReply>>,
    shared: Arc<WorkerShared>,
    last_pong: Mutex<Instant>,
}

impl ShardLink {
    fn child_running(&self) -> bool {
        matches!(self.lock_child().try_wait(), Ok(None))
    }

    fn lock_child(&self) -> std::sync::MutexGuard<'_, Child> {
        self.child.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_writer(&self) -> std::sync::MutexGuard<'_, Option<TcpStream>> {
        self.writer.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_pending(&self) -> std::sync::MutexGuard<'_, HashMap<u64, PendingReply>> {
        self.pending.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Write one frame under the writer lock. False means the
    /// connection is gone (shard dead or dying).
    fn write_frame(&self, frame: &[u8]) -> bool {
        let mut guard = self.lock_writer();
        match guard.as_mut() {
            Some(stream) => stream.write_all(frame).is_ok(),
            None => false,
        }
    }

    /// Declare the shard dead: stop routing to it, close the write
    /// half, and resolve everything in flight as an error naming the
    /// shard — clients never hang on a corpse.
    fn declare_dead(&self, why: &str) {
        self.shared.mark_dead();
        *self.lock_writer() = None;
        self.fail_pending(why);
    }

    fn fail_pending(&self, why: &str) {
        let drained: Vec<PendingReply> = self.lock_pending().drain().map(|(_, p)| p).collect();
        let msg = format!("shard {} {}", self.index, why);
        for p in drained {
            match p {
                PendingReply::Score(sink) => {
                    sink.send(Err(msg.clone()));
                    self.shared.dec_pending();
                }
                PendingReply::Generate(sink) => {
                    sink.send(Err(msg.clone()));
                    self.shared.dec_pending();
                }
                // dropping the sender unblocks the gather's recv
                PendingReply::Stats(_) => {}
            }
        }
    }

    /// Route one decoded reply frame to its waiting client.
    fn complete(&self, reply: WireReply) {
        match reply {
            WireReply::Score { id, result } => {
                if let Some(PendingReply::Score(sink)) = self.lock_pending().remove(&id) {
                    sink.send(result);
                    self.shared.dec_pending();
                }
            }
            WireReply::Generate { id, result } => {
                if let Some(PendingReply::Generate(sink)) = self.lock_pending().remove(&id) {
                    sink.send(result);
                    self.shared.dec_pending();
                }
            }
            WireReply::Stats { id, stats } => {
                if let Some(PendingReply::Stats(tx)) = self.lock_pending().remove(&id) {
                    let _ = tx.send(stats);
                }
            }
            WireReply::Pong { .. } => {
                *self.last_pong.lock().unwrap_or_else(|e| e.into_inner()) = Instant::now();
            }
        }
    }
}

/// The process-shard serving front-end. In-process clients talk to it
/// exactly like a [`super::ServerHandle`] or [`super::Router`] (same
/// [`Request`] enum and helpers); remote clients connect through
/// [`Fleet::serve_net`] + [`super::net::NetClient`].
pub struct Fleet {
    tx: Sender<Request>,
    shards: Vec<Arc<ShardLink>>,
    /// Fleet-level liveness (any shard alive) — what the TCP
    /// front-end's connections consult for pings.
    fleet_shared: Arc<WorkerShared>,
    hb_stop: Arc<AtomicBool>,
    dispatcher: Option<JoinHandle<Result<()>>>,
    heartbeat: Option<JoinHandle<()>>,
}

impl Fleet {
    /// Spawn `cfg.n_shards` shard processes (at least one), handshake
    /// with each, and start the dispatcher + heartbeat. Fails fast —
    /// and reaps what it already spawned — if any shard dies during
    /// startup.
    pub fn start(cfg: FleetConfig) -> Result<Fleet> {
        let n = cfg.n_shards.max(1);
        // same remainder-aware core split as the thread-level router,
        // one level up: shard processes never strand `cores % n`
        let split = lane_split(crate::dyad::kernel::num_threads(), n);
        let mut shards: Vec<Arc<ShardLink>> = Vec::with_capacity(n);
        for (i, &threads) in split.iter().enumerate() {
            match spawn_shard(&cfg, i, threads) {
                Ok(link) => shards.push(Arc::new(link)),
                Err(e) => {
                    for link in &shards {
                        let mut child = link.lock_child();
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                    return Err(e.context(format!("start shard {i}/{n}")));
                }
            }
        }
        for link in &shards {
            let rlink = link.clone();
            // xtask:allow(thread_spawn): per-shard reply reader — a
            // long-lived connection drain, not kernel parallelism.
            std::thread::Builder::new()
                .name(format!("fleet-reader-{}", link.index))
                .spawn(move || shard_reader(&rlink))
                .context("spawn shard reader")?;
        }
        let fleet_shared = Arc::new(WorkerShared::new());
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_shards = shards.clone();
        let hb_flag = hb_stop.clone();
        let hb_fleet = fleet_shared.clone();
        let interval = Duration::from_millis(cfg.heartbeat_ms.max(10));
        // xtask:allow(thread_spawn): fleet heartbeat — liveness
        // polling, not kernel parallelism.
        let heartbeat = std::thread::Builder::new()
            .name("fleet-heartbeat".into())
            .spawn(move || heartbeat_loop(&hb_shards, &hb_flag, &hb_fleet, interval))
            .context("spawn fleet heartbeat")?;
        let (tx, rx) = mpsc::channel();
        let d_shards = shards.clone();
        let d_stop = hb_stop.clone();
        let policy = cfg.serve.dispatch;
        // xtask:allow(thread_spawn): the fleet dispatcher — a
        // long-lived routing thread, not kernel parallelism.
        let dispatcher = std::thread::Builder::new()
            .name("fleet-dispatcher".into())
            .spawn(move || dispatch_loop(rx, d_shards, policy, d_stop))
            .context("spawn fleet dispatcher")?;
        Ok(Fleet {
            tx,
            shards,
            fleet_shared,
            hb_stop,
            dispatcher: Some(dispatcher),
            heartbeat: Some(heartbeat),
        })
    }

    /// A clonable handle for client threads — same protocol as
    /// [`super::Router::sender`].
    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    pub fn score(&self, tokens: Vec<i32>) -> Result<f64> {
        request_score(&self.tx, tokens)
    }

    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>> {
        request_generate(&self.tx, prompt, max_new)
    }

    /// Fleet-level stats: per-shard snapshots gathered over the wire
    /// and [`ServeStats::merge`]d (union-of-spans wall clock, heap
    /// weight bytes summed, mapped weight bytes counted once).
    pub fn stats(&self) -> Result<ServeStats> {
        request_stats(&self.tx)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Indices of shards declared dead (process exit, lost
    /// connection, stale heartbeat).
    pub fn dead_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .filter(|l| !l.shared.is_alive())
            .map(|l| l.index)
            .collect()
    }

    /// Failure injection (tests, soak runs): SIGKILL one shard
    /// process — the hard variant of [`super::Router::kill_worker`].
    /// Detection happens the same way a real crash would be noticed:
    /// connection EOF and heartbeat, not this call.
    #[doc(hidden)]
    pub fn kill_shard(&self, index: usize) -> Result<()> {
        let link = self
            .shards
            .get(index)
            .ok_or_else(|| anyhow!("no shard {index} (fleet of {})", self.n_shards()))?;
        let mut child = link.lock_child();
        child.kill().with_context(|| format!("kill shard {index}"))
    }

    /// Serve remote clients on `listener`: each connection speaks the
    /// [`super::net`] wire format and fans into the same dispatcher as
    /// in-process callers. Blocks until a client sends Shutdown (which
    /// also shuts the fleet down) or every shard is dead.
    pub fn serve_net(&self, listener: TcpListener) -> Result<()> {
        let stop = Arc::new(AtomicBool::new(false));
        listener.set_nonblocking(true).context("front-end listener nonblocking")?;
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Acquire) && self.fleet_shared.is_alive() {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(false).ok();
                    let tx = self.tx.clone();
                    let shared = self.fleet_shared.clone();
                    let cstop = stop.clone();
                    // xtask:allow(thread_spawn): per-client connection
                    // loop, not kernel parallelism.
                    let h = std::thread::Builder::new()
                        .name("fleet-client-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(stream, &tx, &shared, &cstop);
                        })
                        .context("spawn client connection")?;
                    conns.push(h);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("front-end accept"),
            }
        }
        for h in conns {
            let _ = h.join();
        }
        Ok(())
    }

    /// Graceful drain, then reap: every accepted request is answered
    /// before the shards exit; any shard that crashed, was killed, or
    /// would not exit is named in the error — a fleet that lost a
    /// shard cannot shut down silently.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Request::Shutdown);
        let result = match self.dispatcher.take() {
            Some(j) => j.join().map_err(|_| anyhow!("fleet dispatcher panicked"))?,
            None => Ok(()),
        };
        self.hb_stop.store(true, Ordering::Release);
        if let Some(j) = self.heartbeat.take() {
            let _ = j.join();
        }
        result
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
        self.hb_stop.store(true, Ordering::Release);
        if let Some(j) = self.heartbeat.take() {
            let _ = j.join();
        }
        // belt and braces: no shard process outlives its front-end
        for link in &self.shards {
            let mut child = link.lock_child();
            if matches!(child.try_wait(), Ok(None)) {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Spawn one shard process and complete the startup handshake: the
/// child binds an ephemeral port and prints `SHARD_READY <addr>` on
/// stdout; we connect to that address.
fn spawn_shard(cfg: &FleetConfig, index: usize, threads: usize) -> Result<ShardLink> {
    let sc = &cfg.serve;
    // xtask:allow(process_spawn): shard processes are the point of the
    // fleet — isolation the thread-level router cannot give.
    let mut command = Command::new(&cfg.shard_binary);
    command
        .arg("serve")
        .arg("--shard")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--backend")
        .arg(sc.backend.name())
        .arg("--artifacts")
        .arg(&sc.artifacts_dir)
        .arg("--arch")
        .arg(&sc.arch)
        .arg("--variant")
        .arg(&sc.variant)
        .arg("--max-batch")
        .arg(sc.max_batch.to_string())
        .arg("--window-ms")
        .arg(sc.window_ms.to_string())
        .arg("--seed")
        .arg(sc.seed.to_string())
        .arg("--threads-per-worker")
        .arg(threads.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::piped());
    if let Some(w) = &sc.weights_file {
        command.arg("--weights").arg(w);
    }
    if let Some(d) = &sc.checkpoint_dir {
        command.arg("--ckpt").arg(d);
    }
    if sc.legacy_generate {
        command.arg("--legacy-generate");
    }
    let mut child = command
        .spawn()
        .with_context(|| format!("spawn shard binary {}", cfg.shard_binary.display()))?;
    let stdout = child.stdout.take().context("shard stdout not piped")?;
    let mut line = String::new();
    let handshake = BufReader::new(stdout).read_line(&mut line);
    let addr = match handshake {
        Ok(0) | Err(_) => None, // EOF: the child died before binding
        Ok(_) => line.trim().strip_prefix("SHARD_READY ").map(str::to_string),
    };
    let Some(addr) = addr else {
        let _ = child.kill();
        let _ = child.wait();
        bail!("shard {index} did not hand over an address (got {line:?})");
    };
    let stream = TcpStream::connect(&addr)
        .with_context(|| format!("connect to shard {index} at {addr}"))?;
    stream.set_nodelay(true).ok();
    Ok(ShardLink {
        index,
        addr,
        child: Mutex::new(child),
        writer: Mutex::new(Some(stream)),
        pending: Mutex::new(HashMap::new()),
        shared: Arc::new(WorkerShared::new()),
        last_pong: Mutex::new(Instant::now()),
    })
}

/// Per-shard reply pump: drain reply frames into [`ShardLink::complete`]
/// until the connection drops, then either reconnect (process still
/// running — e.g. a torn connection) or declare the shard dead. Either
/// way the in-flight requests of the dropped connection resolve as
/// errors: their replies are gone with it.
fn shard_reader(link: &Arc<ShardLink>) {
    loop {
        let stream = link.lock_writer().as_ref().and_then(|s| s.try_clone().ok());
        let Some(stream) = stream else {
            break; // declared dead elsewhere
        };
        let mut reader = BufReader::new(stream);
        loop {
            match read_frame(&mut reader) {
                Ok(Some((kind, payload))) => match decode_reply(kind, &payload) {
                    Ok(reply) => link.complete(reply),
                    Err(_) => break, // corrupt stream: unusable
                },
                Ok(None) | Err(_) => break,
            }
        }
        link.fail_pending("connection lost (in-flight replies dropped)");
        if !link.child_running() {
            link.declare_dead("process exited");
            break;
        }
        // the process is still up (torn connection, not a crash): one
        // reconnect attempt against its accept loop
        std::thread::sleep(Duration::from_millis(100));
        match TcpStream::connect(&link.addr) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                *link.lock_writer() = Some(stream);
            }
            Err(_) => {
                link.declare_dead("unreachable after reconnect attempt");
                break;
            }
        }
    }
}

/// Liveness poll: reap exited shard processes, flag stale heartbeats,
/// ping the survivors, and mirror "any shard alive" onto the
/// fleet-level flag the TCP front-end consults.
fn heartbeat_loop(
    shards: &[Arc<ShardLink>],
    stop: &AtomicBool,
    fleet_shared: &Arc<WorkerShared>,
    interval: Duration,
) {
    let grace = interval * PONG_GRACE;
    let mut ping_id = u64::MAX / 2; // disjoint from dispatcher ids
    while !stop.load(Ordering::Acquire) {
        for link in shards {
            if !link.shared.is_alive() {
                continue;
            }
            if !link.child_running() {
                link.declare_dead("process exited");
                continue;
            }
            let stale = link.last_pong.lock().unwrap_or_else(|e| e.into_inner()).elapsed() > grace;
            if stale {
                link.declare_dead("heartbeat timed out");
                continue;
            }
            ping_id += 1;
            // a failed write is the reader's signal to handle
            let _ = link.write_frame(&encode_request(&WireRequest::Ping { id: ping_id }));
        }
        if shards.iter().all(|l| !l.shared.is_alive()) {
            fleet_shared.mark_dead();
        }
        std::thread::sleep(interval);
    }
}

fn dispatch_loop(
    rx: Receiver<Request>,
    shards: Vec<Arc<ShardLink>>,
    policy: DispatchPolicy,
    hb_stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut rr = 0usize;
    let mut next_id = 1u64;
    loop {
        match rx.recv() {
            Ok(Request::Stats { resp }) => {
                resp.send(gather_stats(&shards, &mut next_id));
            }
            Ok(Request::Shutdown) => break,
            Ok(Request::Crash) => {
                // in-process failure injection maps to the process
                // level: hard-kill the first live shard
                if let Some(link) = shards.iter().find(|l| l.shared.is_alive()) {
                    let _ = link.lock_child().kill();
                }
            }
            Ok(req) => dispatch_one(req, &shards, policy, &mut rr, &mut next_id),
            Err(_) => break, // every client sender dropped
        }
    }
    // graceful drain: Shutdown frames queue behind everything already
    // written (TCP ordering), each shard's connection loop forwards
    // them after the earlier requests, and the shard process exits
    // only after its worker drained — replies stream back meanwhile.
    hb_stop.store(true, Ordering::Release);
    for link in &shards {
        if link.shared.is_alive() {
            let _ = link.write_frame(&encode_request(&WireRequest::Shutdown));
        }
    }
    let mut corpses = Vec::new();
    for link in &shards {
        let mut child = link.lock_child();
        let pid = child.id();
        let deadline = Instant::now() + SHUTDOWN_TIMEOUT;
        let status = loop {
            match child.try_wait() {
                Ok(Some(st)) => break Some(st),
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Ok(None) | Err(_) => break None,
            }
        };
        match status {
            Some(st) if st.success() => {}
            Some(st) => {
                corpses.push(format!("shard {} (pid {pid}): exited with {st}", link.index));
            }
            None => {
                let _ = child.kill();
                let _ = child.wait();
                corpses.push(format!(
                    "shard {} (pid {pid}): hung past shutdown timeout, killed",
                    link.index
                ));
            }
        }
        drop(child);
        link.declare_dead("fleet shut down");
    }
    if corpses.is_empty() {
        Ok(())
    } else {
        bail!("fleet shard failures: {}", corpses.join("; "))
    }
}

/// Route one request: pick a live shard (same policy logic as the
/// thread-level router), register the reply sink under a fresh id,
/// write the frame. A failed write declares that shard dead and
/// retries the next; with nobody left the client gets an explicit
/// error, never a hang.
fn dispatch_one(
    req: Request,
    shards: &[Arc<ShardLink>],
    policy: DispatchPolicy,
    rr: &mut usize,
    next_id: &mut u64,
) {
    let mut req = req;
    for _ in 0..shards.len() {
        let picked = pick_shard(
            shards.len(),
            |i| shards[i].shared.is_alive(),
            |i| shards[i].shared.pending(),
            policy,
            rr,
        );
        let Some(i) = picked else { break };
        match send_to_shard(&shards[i], req, next_id) {
            Ok(()) => return,
            Err(back) => {
                shards[i].declare_dead("rejected a request (connection down)");
                req = back;
            }
        }
    }
    reply_error(req, "no live serve shards");
}

/// Translate one [`Request`] into a wire frame on `link`, with the
/// reply sink parked in the pending map. On a failed write the sink is
/// recovered and the whole request handed back for a retry elsewhere.
/// The pending entry is registered *before* the write: a reply can
/// race back between write and bookkeeping otherwise.
fn send_to_shard(
    link: &Arc<ShardLink>,
    req: Request,
    next_id: &mut u64,
) -> std::result::Result<(), Request> {
    let id = *next_id;
    *next_id += 1;
    match req {
        Request::Score { tokens, resp } => {
            let frame = encode_request(&WireRequest::Score { id, tokens: tokens.clone() });
            link.lock_pending().insert(id, PendingReply::Score(resp));
            link.shared.inc_pending();
            if link.write_frame(&frame) {
                return Ok(());
            }
            link.shared.dec_pending();
            match link.lock_pending().remove(&id) {
                Some(PendingReply::Score(resp)) => Err(Request::Score { tokens, resp }),
                // raced with the reader's drain: the client already
                // got an error reply, nothing left to retry
                _ => Ok(()),
            }
        }
        Request::Generate { prompt, max_new, resp } => {
            let frame = encode_request(&WireRequest::Generate {
                id,
                prompt: prompt.clone(),
                max_new: max_new as u64,
            });
            link.lock_pending().insert(id, PendingReply::Generate(resp));
            link.shared.inc_pending();
            if link.write_frame(&frame) {
                return Ok(());
            }
            link.shared.dec_pending();
            match link.lock_pending().remove(&id) {
                Some(PendingReply::Generate(resp)) => {
                    Err(Request::Generate { prompt, max_new, resp })
                }
                _ => Ok(()),
            }
        }
        // Stats is answered by the dispatcher, Shutdown/Crash are
        // control flow — none of them are routed here
        other => {
            reply_error(other, "unroutable request");
            Ok(())
        }
    }
}

/// Fan a Stats frame to every live shard, merge what comes back
/// within the gather timeout.
fn gather_stats(shards: &[Arc<ShardLink>], next_id: &mut u64) -> ServeStats {
    let mut waits = Vec::new();
    for link in shards {
        if !link.shared.is_alive() {
            continue;
        }
        let id = *next_id;
        *next_id += 1;
        let (stx, srx) = mpsc::channel();
        let frame = encode_request(&WireRequest::Stats { id });
        link.lock_pending().insert(id, PendingReply::Stats(stx));
        if link.write_frame(&frame) {
            waits.push(srx);
        } else {
            link.lock_pending().remove(&id);
        }
    }
    let mut fleet = ServeStats::default();
    for srx in waits {
        if let Ok(snap) = srx.recv_timeout(GATHER_TIMEOUT) {
            fleet.merge(&snap);
        }
    }
    fleet
}

/// The shard-process entry point (`repro serve --shard --listen ADDR`,
/// spawned by [`Fleet::start`]): bind, print the `SHARD_READY <addr>`
/// handshake, then accept front-end connections and pump them into
/// this process's single backend-owning worker until a Shutdown frame
/// arrives or the worker dies. Worker death ends the accept loop and
/// the process — the closed TCP connection and reaped pid are how the
/// front-end finds out, exactly like a real crash.
pub fn run_shard(cfg: ServeConfig, listen: &str) -> Result<()> {
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("bind shard listener on {listen}"))?;
    let addr = listener.local_addr()?;
    // the handshake line the spawning front-end blocks on
    println!("SHARD_READY {addr}");
    std::io::stdout().flush().ok();
    let (tx, rx) = mpsc::channel();
    let shared = Arc::new(WorkerShared::new());
    let wshared = shared.clone();
    let wcfg = ServeConfig { n_workers: 1, ..cfg };
    // xtask:allow(thread_spawn): the shard's single backend-owning
    // worker, not kernel parallelism.
    let join = std::thread::Builder::new()
        .name("shard-worker".into())
        .spawn(move || worker(wcfg, rx, wshared))
        .context("spawn shard worker")?;
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true).context("shard listener nonblocking")?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) && shared.is_alive() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nodelay(true).ok();
                stream.set_nonblocking(false).ok();
                let ctx = tx.clone();
                let cshared = shared.clone();
                let cstop = stop.clone();
                // xtask:allow(thread_spawn): per-connection loop, not
                // kernel parallelism.
                let h = std::thread::Builder::new()
                    .name("shard-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, &ctx, &cshared, &cstop);
                    })
                    .context("spawn shard connection")?;
                conns.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("shard accept"),
        }
    }
    // graceful drain: connection loops already forwarded everything
    // (including Shutdown); the worker answers it all before exiting
    for h in conns {
        let _ = h.join();
    }
    drop(tx);
    match join.join() {
        Ok(result) => result,
        Err(_) => bail!("shard worker panicked"),
    }
}

//! Dynamic batching policy: collect requests until the batch is full
//! or the window expires, never holding a lone request longer than the
//! window. Pure logic — tested without threads or PJRT.

use std::time::{Duration, Instant};

/// Decision state for one batch accumulation cycle.
#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pub window: Duration,
    opened_at: Option<Instant>,
    pending: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, window_ms: u64) -> Batcher {
        Batcher {
            max_batch,
            window: Duration::from_millis(window_ms),
            opened_at: None,
            pending: 0,
        }
    }

    /// Record an arrival; returns true if the batch should be flushed
    /// immediately (full).
    pub fn on_arrival(&mut self, now: Instant) -> bool {
        if self.pending == 0 {
            self.opened_at = Some(now);
        }
        self.pending += 1;
        self.pending >= self.max_batch
    }

    /// Should we flush now even though the batch isn't full?
    ///
    /// Saturating on both sides: a `now` before the window opened
    /// (stale caller timestamp) reads as zero elapsed, a `now` far
    /// past the deadline compares as expired — never panics.
    pub fn window_expired(&self, now: Instant) -> bool {
        match self.opened_at {
            Some(t) => self.pending > 0 && now.saturating_duration_since(t) >= self.window,
            None => false,
        }
    }

    /// How long the worker may block waiting for more requests.
    /// Saturates to zero once `now` is at or past the deadline (and
    /// treats a stale `now` before the window opened as a full
    /// budget) — no underflow panic either way.
    pub fn wait_budget(&self, now: Instant) -> Duration {
        match self.opened_at {
            None => self.window, // idle: just poll at window granularity
            Some(t) => self
                .window
                .checked_sub(now.saturating_duration_since(t))
                .unwrap_or(Duration::ZERO),
        }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Mark the batch flushed.
    pub fn flush(&mut self) -> usize {
        let n = self.pending;
        self.pending = 0;
        self.opened_at = None;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max() {
        let mut b = Batcher::new(3, 10);
        let t = Instant::now();
        assert!(!b.on_arrival(t));
        assert!(!b.on_arrival(t));
        assert!(b.on_arrival(t)); // full -> flush
        assert_eq!(b.flush(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn window_expiry() {
        let mut b = Batcher::new(8, 5);
        let t0 = Instant::now();
        b.on_arrival(t0);
        assert!(!b.window_expired(t0));
        let later = t0 + Duration::from_millis(6);
        assert!(b.window_expired(later));
        b.flush();
        assert!(!b.window_expired(later + Duration::from_millis(10)));
    }

    /// `wait_budget` saturates to zero when `now` is past the
    /// deadline, however far past — no Duration underflow.
    #[test]
    fn wait_budget_saturates_past_deadline() {
        let mut b = Batcher::new(8, 5);
        let t0 = Instant::now();
        b.on_arrival(t0);
        assert_eq!(b.wait_budget(t0 + Duration::from_secs(3600)), Duration::ZERO);
        // a stale `now` from *before* the window opened must not
        // panic either: elapsed saturates to zero -> full budget
        let mut b2 = Batcher::new(8, 5);
        b2.on_arrival(t0 + Duration::from_millis(50));
        assert_eq!(b2.wait_budget(t0), Duration::from_millis(5));
    }

    /// `window_expired` is total over time: far-past deadlines read as
    /// expired, stale pre-open timestamps as not expired — no panics.
    #[test]
    fn window_expired_saturates_past_deadline() {
        let mut b = Batcher::new(8, 5);
        let t0 = Instant::now();
        b.on_arrival(t0 + Duration::from_millis(50));
        assert!(!b.window_expired(t0), "stale now must read as unexpired");
        assert!(b.window_expired(t0 + Duration::from_secs(3600)));
    }

    /// `flush` on an empty batcher is a no-op: returns 0, leaves no
    /// window open, and the batcher keeps working afterwards.
    #[test]
    fn flush_empty_is_noop() {
        let mut b = Batcher::new(3, 5);
        assert_eq!(b.flush(), 0);
        assert_eq!(b.pending(), 0);
        let t = Instant::now();
        assert!(!b.window_expired(t + Duration::from_secs(60)));
        assert_eq!(b.wait_budget(t), Duration::from_millis(5));
        // still accumulates normally after the no-op flush
        assert!(!b.on_arrival(t));
        assert_eq!(b.pending(), 1);
        assert_eq!(b.flush(), 1);
    }

    #[test]
    fn wait_budget_shrinks() {
        let mut b = Batcher::new(8, 10);
        let t0 = Instant::now();
        assert_eq!(b.wait_budget(t0), Duration::from_millis(10));
        b.on_arrival(t0);
        let mid = t0 + Duration::from_millis(4);
        let budget = b.wait_budget(mid);
        assert!(budget <= Duration::from_millis(6));
        let past = t0 + Duration::from_millis(20);
        assert_eq!(b.wait_budget(past), Duration::ZERO);
    }
}

//! Read-only memory-mapped buffers and f32 views over them.
//!
//! The fleet's shared-weight story (ISSUE: Fig. 8 / Table 11 turned
//! into a serving win) needs N shard *processes* to map one weight
//! file instead of each holding a private heap copy. The container
//! ships no `libc`/`memmap2`, so [`Mapping`] issues the `mmap`/
//! `munmap` syscalls directly via inline asm on Linux x86_64/aarch64 —
//! `PROT_READ` + `MAP_SHARED`, so every process shares the same page
//! cache pages — and falls back to a private 4-byte-aligned heap copy
//! everywhere else (other targets, Miri, or an mmap failure).
//! [`Mapping::is_shared`] reports which path was taken so memory
//! accounting ([`crate::serve::ServeStats`]) never lies about sharing.
//!
//! [`MappedF32`] is a bounds- and alignment-checked `&[f32]` view into
//! a mapping; `tensor::Data::F32Mapped` wraps one so a mapped weight
//! tensor flows through the native backend zero-copy.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// The targets where the raw-syscall mmap path is compiled in.
#[cfg(all(
    target_os = "linux",
    not(miri),
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
macro_rules! mmap_supported {
    () => {
        true
    };
}
#[cfg(not(all(
    target_os = "linux",
    not(miri),
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
macro_rules! mmap_supported {
    () => {
        false
    };
}

/// An immutable byte buffer: a shared read-only file mapping where
/// supported, a private aligned heap copy otherwise.
pub struct Mapping(Repr);

enum Repr {
    #[cfg(all(
        target_os = "linux",
        not(miri),
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    Mmap { ptr: *const u8, len: usize },
    /// Heap fallback. Backed by `u32` words so any 4-byte-aligned
    /// offset yields a validly aligned `f32` view; `len` is the real
    /// byte length (the last word may be padding).
    Heap { words: Vec<u32>, len: usize },
}

// SAFETY: the mapped pages are PROT_READ and never written through
// this type (there is no &mut accessor), so concurrent reads from any
// thread are safe; the heap variant is an ordinary owned Vec. The
// mapping is unmapped only in Drop, which runs once.
unsafe impl Send for Mapping {}
// SAFETY: all accessors take &self and only read; see Send above.
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only (falling back to a heap copy where mmap is
    /// unavailable or fails). The `Arc` is what views hang on to.
    pub fn open(path: &Path) -> Result<Arc<Mapping>> {
        #[cfg(all(
            target_os = "linux",
            not(miri),
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        {
            use std::os::fd::AsRawFd;
            let file = std::fs::File::open(path)
                .with_context(|| format!("open {}", path.display()))?;
            let len = file.metadata()?.len() as usize;
            if len > 0 {
                // SAFETY: fd is a live O_RDONLY file descriptor for the
                // duration of the call; a PROT_READ MAP_SHARED mapping
                // of it cannot alias any Rust-owned memory. On failure
                // the syscall returns an errno and nothing was mapped.
                if let Ok(ptr) = unsafe { sys::mmap_readonly(len, file.as_raw_fd()) } {
                    return Ok(Arc::new(Mapping(Repr::Mmap { ptr, len })));
                }
            }
        }
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        Ok(Arc::new(Mapping::from_heap_bytes(bytes)))
    }

    /// Wrap bytes in the aligned heap representation (tests, fallback).
    fn from_heap_bytes(bytes: Vec<u8>) -> Mapping {
        let len = bytes.len();
        let mut words = vec![0u32; len.div_ceil(4)];
        for (i, chunk) in bytes.chunks(4).enumerate() {
            let mut b = [0u8; 4];
            b[..chunk.len()].copy_from_slice(chunk);
            words[i] = u32::from_ne_bytes(b);
        }
        Mapping(Repr::Heap { words, len })
    }

    pub fn len(&self) -> usize {
        match &self.0 {
            #[cfg(all(
                target_os = "linux",
                not(miri),
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Repr::Mmap { len, .. } => *len,
            Repr::Heap { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this buffer is a real shared file mapping (page cache
    /// shared across processes) rather than a private heap copy.
    pub fn is_shared(&self) -> bool {
        match &self.0 {
            #[cfg(all(
                target_os = "linux",
                not(miri),
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Repr::Mmap { .. } => true,
            Repr::Heap { .. } => false,
        }
    }

    pub fn as_bytes(&self) -> &[u8] {
        match &self.0 {
            #[cfg(all(
                target_os = "linux",
                not(miri),
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            // SAFETY: ptr..ptr+len is the live PROT_READ mapping
            // established in `open`; it stays mapped until Drop, which
            // cannot run while &self is borrowed.
            Repr::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Repr::Heap { words, len } => {
                // SAFETY: `words` owns at least `len` bytes (len <=
                // words.len()*4) and lives as long as &self; u8 has no
                // alignment or validity requirements.
                unsafe { std::slice::from_raw_parts(words.as_ptr().cast::<u8>(), *len) }
            }
        }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        match &self.0 {
            #[cfg(all(
                target_os = "linux",
                not(miri),
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Repr::Mmap { ptr, len } => {
                // SAFETY: ptr/len are exactly what mmap returned; Drop
                // runs once and no view can outlive the owning Arc.
                unsafe { sys::munmap(*ptr, *len) };
            }
            Repr::Heap { .. } => {}
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping")
            .field("len", &self.len())
            .field("shared", &self.is_shared())
            .finish()
    }
}

/// Raw Linux mmap/munmap via inline asm — no libc in the container.
#[cfg(all(
    target_os = "linux",
    not(miri),
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod sys {
    const PROT_READ: usize = 1;
    const MAP_SHARED: usize = 1;

    /// `mmap(NULL, len, PROT_READ, MAP_SHARED, fd, 0)`.
    ///
    /// # Safety
    /// `fd` must be a valid readable file descriptor; the caller owns
    /// the returned region and must `munmap` it exactly once.
    pub(super) unsafe fn mmap_readonly(len: usize, fd: i32) -> Result<*const u8, i64> {
        let ret: i64;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the mmap syscall (nr 9) with these operands only
        // creates a new mapping; rcx/r11 are declared clobbered per the
        // syscall ABI and no Rust memory is read or written.
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") 9i64 => ret,
                in("rdi") 0usize,
                in("rsi") len,
                in("rdx") PROT_READ,
                in("r10") MAP_SHARED,
                in("r8") fd as i64,
                in("r9") 0usize,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above via svc #0 with the aarch64 mmap nr (222).
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") 222i64,
                inlateout("x0") 0usize => ret,
                in("x1") len,
                in("x2") PROT_READ,
                in("x3") MAP_SHARED,
                in("x4") fd as i64,
                in("x5") 0usize,
                options(nostack)
            );
        }
        // kernel returns -errno in [-4095, -1] on failure
        if (-4095..0).contains(&ret) {
            Err(-ret)
        } else {
            Ok(ret as *const u8)
        }
    }

    /// `munmap(ptr, len)`. Failure is ignored — there is no recovery
    /// from a failed unmap at drop time.
    ///
    /// # Safety
    /// `ptr`/`len` must be a region previously returned by
    /// [`mmap_readonly`] and not yet unmapped; no live reference into
    /// the region may exist.
    pub(super) unsafe fn munmap(ptr: *const u8, len: usize) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: munmap (nr 11) only removes the caller-owned mapping.
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") 11i64 => _,
                in("rdi") ptr,
                in("rsi") len,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above via the aarch64 munmap nr (215).
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") 215i64,
                inlateout("x0") ptr => _,
                in("x1") len,
                options(nostack)
            );
        }
    }
}

/// A checked, immutable `&[f32]` view into a [`Mapping`].
///
/// Cloning shares the mapping (`Arc`); the constructor rejects
/// out-of-bounds and misaligned views, so `as_slice` is always valid.
/// Byte order is little-endian in the file — identical to the in-memory
/// layout on every supported target.
#[derive(Clone)]
pub struct MappedF32 {
    map: Arc<Mapping>,
    byte_off: usize,
    len: usize,
}

impl MappedF32 {
    /// View `len` f32 values starting `byte_off` bytes into `map`.
    pub fn new(map: Arc<Mapping>, byte_off: usize, len: usize) -> Result<MappedF32> {
        let byte_len = len
            .checked_mul(4)
            .and_then(|b| b.checked_add(byte_off))
            .ok_or_else(|| anyhow::anyhow!("mapped f32 view overflows usize"))?;
        if byte_len > map.len() {
            bail!(
                "mapped f32 view [{byte_off}..{byte_len}) exceeds mapping of {} bytes",
                map.len()
            );
        }
        if (map.as_bytes().as_ptr() as usize + byte_off) % 4 != 0 {
            bail!("mapped f32 view at byte offset {byte_off} is not 4-byte aligned");
        }
        Ok(MappedF32 { map, byte_off, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the underlying storage is a shared file mapping.
    pub fn is_shared(&self) -> bool {
        self.map.is_shared()
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: the constructor proved byte_off + len*4 fits in the
        // mapping and that the base address is 4-byte aligned; the
        // mapping outlives &self via the Arc, is never written, and
        // every bit pattern is a valid f32.
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_bytes().as_ptr().add(self.byte_off).cast::<f32>(),
                self.len,
            )
        }
    }
}

impl std::fmt::Debug for MappedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedF32")
            .field("len", &self.len)
            .field("byte_off", &self.byte_off)
            .field("shared", &self.is_shared())
            .finish()
    }
}

impl PartialEq for MappedF32 {
    fn eq(&self, other: &MappedF32) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// True when this build can use real shared mappings (informational:
/// the bench asserts the fleet memory claim only where this holds).
pub fn mmap_available() -> bool {
    mmap_supported!()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dyad-repro-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_f32s(name: &str, values: &[f32]) -> std::path::PathBuf {
        let path = tmpfile(name);
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn mapping_roundtrips_values() {
        let vals = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let path = write_f32s("mapped_roundtrip.bin", &vals);
        let map = Mapping::open(&path).unwrap();
        assert_eq!(map.len(), vals.len() * 4);
        let view = MappedF32::new(map.clone(), 0, vals.len()).unwrap();
        assert_eq!(view.as_slice(), &vals);
        // offset view
        let tail = MappedF32::new(map, 8, 3).unwrap();
        assert_eq!(tail.as_slice(), &vals[2..]);
    }

    #[test]
    fn linux_mappings_are_shared() {
        let path = write_f32s("mapped_shared.bin", &[1.0, 2.0]);
        let map = Mapping::open(&path).unwrap();
        // on the CI target the real mmap path must be taken — the
        // fleet memory claim depends on it
        assert_eq!(map.is_shared(), mmap_available());
    }

    #[test]
    fn heap_fallback_matches_mmap() {
        let vals = [3.25f32, -0.5, 42.0];
        let path = write_f32s("mapped_fallback.bin", &vals);
        let bytes = std::fs::read(&path).unwrap();
        let heap = Arc::new(Mapping::from_heap_bytes(bytes));
        assert!(!heap.is_shared());
        assert_eq!(heap.as_bytes(), std::fs::read(&path).unwrap().as_slice());
        let view = MappedF32::new(heap, 0, vals.len()).unwrap();
        assert_eq!(view.as_slice(), &vals);
    }

    #[test]
    fn rejects_out_of_bounds_and_misaligned() {
        let path = write_f32s("mapped_bounds.bin", &[1.0, 2.0, 3.0]);
        let map = Mapping::open(&path).unwrap();
        assert!(MappedF32::new(map.clone(), 0, 4).is_err());
        assert!(MappedF32::new(map.clone(), 8, 2).is_err());
        assert!(MappedF32::new(map.clone(), 2, 1).is_err(), "misaligned offset");
        assert!(MappedF32::new(map, usize::MAX, 2).is_err(), "overflow");
    }

    #[test]
    fn empty_file_is_fine() {
        let path = tmpfile("mapped_empty.bin");
        std::fs::write(&path, b"").unwrap();
        let map = Mapping::open(&path).unwrap();
        assert!(map.is_empty());
        let view = MappedF32::new(map, 0, 0).unwrap();
        assert!(view.as_slice().is_empty());
    }

    #[test]
    fn many_clones_share_one_mapping() {
        let path = write_f32s("mapped_clone.bin", &[7.0; 16]);
        let map = Mapping::open(&path).unwrap();
        let v1 = MappedF32::new(map.clone(), 0, 16).unwrap();
        let v2 = v1.clone();
        assert_eq!(v1, v2);
        assert_eq!(v1.as_slice().as_ptr(), v2.as_slice().as_ptr());
        drop(map);
        assert_eq!(v2.as_slice()[0], 7.0);
    }
}

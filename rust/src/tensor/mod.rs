//! Host-side tensors and the DYT checkpoint format.

mod io;
#[allow(clippy::module_inception)]
mod tensor;

pub use io::{load_checkpoint, save_checkpoint};
pub use tensor::{DType, InitSpec, Precision, Tensor};

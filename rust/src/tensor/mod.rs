//! Host-side tensors and the DYT checkpoint format.

mod io;
mod tensor;

pub use io::{load_checkpoint, save_checkpoint};
pub use tensor::{DType, InitSpec, Precision, Tensor};

//! Host-side tensors, the DYT checkpoint format, and read-only
//! memory-mapped weight storage (`mapped`).

mod io;
pub mod mapped;
mod tensor;

pub use io::{load_checkpoint, save_checkpoint};
pub use mapped::{MappedF32, Mapping};
pub use tensor::{DType, InitSpec, Precision, Tensor};

//! DYT checkpoint format: named tensors in one binary file.
//!
//! Layout (little-endian):
//! ```text
//!   magic   b"DYT1"
//!   u32     entry count
//!   entry*  { u32 name_len, name bytes (utf-8),
//!             u8 dtype (0=f32, 1=i32),
//!             u32 ndim, u64 dims[ndim],
//!             u64 byte_len, data bytes }
//! ```
//! Used for model checkpoints (Table 11's "Checkpoint Size" is measured
//! on these files) and for staging eval features.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor};

const MAGIC: &[u8; 4] = b"DYT1";

pub fn save_checkpoint(path: &Path, entries: &[(String, &Tensor)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path).context("create checkpoint")?);
    w.write_all(MAGIC)?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, t) in entries {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[match t.dtype() {
            DType::F32 => 0u8,
            DType::I32 => 1u8,
        }])?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        let bytes = t.to_bytes();
        w.write_all(&(bytes.len() as u64).to_le_bytes())?;
        w.write_all(&bytes)?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = BufReader::new(File::open(path).context("open checkpoint")?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a DYT1 checkpoint", path.display());
    }
    let count = read_u32(&mut r)? as usize;
    // A count a corrupt header can't weaponize: each entry needs >= 17
    // bytes, so bound by file size before preallocating.
    let file_len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0) as usize;
    if count > file_len / 17 {
        bail!("corrupt checkpoint: entry count {count} exceeds file size");
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("checkpoint name utf-8")?;
        let mut dt = [0u8; 1];
        r.read_exact(&mut dt)?;
        let dtype = match dt[0] {
            0 => DType::F32,
            1 => DType::I32,
            x => bail!("corrupt checkpoint: dtype tag {x}"),
        };
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 16 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u64(&mut r)? as usize);
        }
        let byte_len = read_u64(&mut r)? as usize;
        let expect: usize = shape.iter().product::<usize>() * 4;
        if byte_len != expect {
            bail!("corrupt checkpoint: {name}: {byte_len} bytes for shape {shape:?}");
        }
        let mut data = vec![0u8; byte_len];
        r.read_exact(&mut data)?;
        out.push((name, Tensor::from_bytes(&shape, dtype, &data)?));
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dyad-repro-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let a = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::from_i32(&[4], vec![-1, 0, 1, 2]).unwrap();
        let c = Tensor::scalar_f32(42.0);
        let path = tmpfile("roundtrip.dyt");
        save_checkpoint(
            &path,
            &[("w".into(), &a), ("toks".into(), &b), ("step".into(), &c)],
        )
        .unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, "w");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        assert_eq!(loaded[2].1.scalar_value_f32().unwrap(), 42.0);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.dyt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let a = Tensor::from_f32(&[64], vec![0.5; 64]).unwrap();
        let path = tmpfile("trunc.dyt");
        save_checkpoint(&path, &[("a".into(), &a)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load_checkpoint(&path).is_err());
    }

    #[test]
    fn checkpoint_size_scales_with_params() {
        // Table 11's measurement primitive: file size ~ total param bytes.
        let big = Tensor::zeros(&[1000], DType::F32);
        let small = Tensor::zeros(&[10], DType::F32);
        let p1 = tmpfile("big.dyt");
        let p2 = tmpfile("small.dyt");
        save_checkpoint(&p1, &[("w".into(), &big)]).unwrap();
        save_checkpoint(&p2, &[("w".into(), &small)]).unwrap();
        let s1 = std::fs::metadata(&p1).unwrap().len();
        let s2 = std::fs::metadata(&p2).unwrap().len();
        assert!(s1 > s2 + 3800);
    }
}

//! Host tensor: shape + flat row-major data (f32 or i32).
//!
//! Deliberately minimal — the heavy math happens inside PJRT
//! executables; host tensors exist to initialise, stage, checkpoint and
//! inspect values. `dyad::math` adds the CPU reference ops used by
//! property tests.

use anyhow::{bail, Result};

use super::mapped::MappedF32;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Compute precision for a linear layer's weight stream.
///
/// Master weights stay f32 everywhere (init, Adam, checkpoints); the
/// tag only selects how the *kernel* streams a layer's weights —
/// full f32, bf16 truncated storage, or per-block-row symmetric int8
/// with dequantisation in registers (`dyad::quant`). `F32` is the
/// default and is bit-identical to the pre-precision code paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    #[default]
    F32,
    Bf16,
    I8,
}

impl Precision {
    pub fn from_str(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            "i8" | "int8" => Ok(Precision::I8),
            _ => bail!("unknown precision {s:?} (expected f32 | bf16 | i8)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::I8 => "i8",
        }
    }

    /// Bits per stored weight (i8 carries one extra f32 scale per
    /// block row, not counted here).
    pub fn weight_bits(&self) -> usize {
        match self {
            Precision::F32 => 32,
            Precision::Bf16 => 16,
            Precision::I8 => 8,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Parameter initialisation, mirroring the manifest's `init` specs
/// (which in turn mirror the paper's §2.3 reference implementation).
#[derive(Debug, Clone, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    /// U(-bound, bound) — nn.Linear / DYAD style, k = 1/sqrt(f_in).
    Uniform { bound: f32 },
    /// N(0, std) — embedding style.
    Normal { std: f32 },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// A read-only view into a shared weight mapping
    /// ([`super::mapped::Mapping`]) — same `f32` semantics as `F32`
    /// everywhere except mutation, which errors.
    F32Mapped(MappedF32),
}

/// Equality is by dtype + element values, regardless of storage: a
/// mapped tensor equals the heap tensor holding the same f32s.
impl PartialEq for Data {
    fn eq(&self, other: &Data) -> bool {
        match (self, other) {
            (Data::I32(a), Data::I32(b)) => a == b,
            (Data::I32(_), _) | (_, Data::I32(_)) => false,
            (a, b) => a.f32_slice() == b.f32_slice(),
        }
    }
}

impl Data {
    /// The f32 elements for either f32 storage kind (panics on I32 —
    /// callers have already matched dtype).
    fn f32_slice(&self) -> &[f32] {
        match self {
            Data::F32(v) => v,
            Data::F32Mapped(m) => m.as_slice(),
            Data::I32(_) => unreachable!("f32_slice on i32 data"),
        }
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n = shape.iter().product();
        let data = match dtype {
            DType::F32 => Data::F32(vec![0.0; n]),
            DType::I32 => Data::I32(vec![0; n]),
        };
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn from_f32(shape: &[usize], values: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if values.len() != n {
            bail!("shape {shape:?} needs {n} values, got {}", values.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data: Data::F32(values) })
    }

    pub fn from_i32(shape: &[usize], values: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if values.len() != n {
            bail!("shape {shape:?} needs {n} values, got {}", values.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data: Data::I32(values) })
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor { shape: vec![], data: Data::I32(vec![v]) }
    }

    /// Wrap a read-only mapped f32 view as a tensor (no copy; clones
    /// share the underlying [`super::mapped::Mapping`]).
    pub fn from_mapped(shape: &[usize], view: MappedF32) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if view.len() != n {
            bail!("shape {shape:?} needs {n} values, mapped view has {}", view.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data: Data::F32Mapped(view) })
    }

    /// Whether this tensor's storage is a shared read-only mapping
    /// (memory accounting: mapped bytes are shared across processes).
    pub fn is_mapped(&self) -> bool {
        matches!(self.data, Data::F32Mapped(_))
    }

    /// Initialise a parameter tensor per spec (deterministic given rng).
    pub fn init(shape: &[usize], spec: &InitSpec, rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let values = match spec {
            InitSpec::Zeros => vec![0.0; n],
            InitSpec::Ones => vec![1.0; n],
            InitSpec::Uniform { bound } => {
                (0..n).map(|_| rng.uniform(-bound, *bound)).collect()
            }
            InitSpec::Normal { std } => {
                (0..n).map(|_| rng.normal_f32(0.0, *std)).collect()
            }
        };
        Tensor { shape: shape.to_vec(), data: Data::F32(values) }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) | Data::F32Mapped(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::F32Mapped(m) => Ok(m.as_slice()),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::F32Mapped(_) => {
                bail!("memory-mapped tensor is read-only (shared weight storage)")
            }
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Raw little-endian bytes (PJRT literal staging / checkpoints).
    pub fn to_bytes(&self) -> Vec<u8> {
        match &self.data {
            Data::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Data::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Data::F32Mapped(m) => {
                m.as_slice().iter().flat_map(|x| x.to_le_bytes()).collect()
            }
        }
    }

    pub fn from_bytes(shape: &[usize], dtype: DType, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("expected {} bytes for {shape:?} {dtype:?}, got {}", n * 4, bytes.len());
        }
        match dtype {
            DType::F32 => {
                let v = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::from_f32(shape, v)
            }
            DType::I32 => {
                let v = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::from_i32(shape, v)
            }
        }
    }

    /// Scalar read (step counters, losses).
    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// Max |a - b| — convergence / parity checks in tests.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max))
    }

    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape
            && self.max_abs_diff(other).map(|d| d <= tol).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shapes() {
        let t = Tensor::zeros(&[2, 3], DType::F32);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.as_f32().unwrap(), &[0.0; 6]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_f32(&[2, 2], vec![1.0; 3]).is_err());
        let t = Tensor::from_i32(&[2], vec![7, 8]).unwrap();
        assert_eq!(t.dtype(), DType::I32);
    }

    #[test]
    fn bytes_roundtrip() {
        let t = Tensor::from_f32(&[2, 2], vec![1.5, -2.25, 0.0, 3.0]).unwrap();
        let b = t.to_bytes();
        let t2 = Tensor::from_bytes(&[2, 2], DType::F32, &b).unwrap();
        assert_eq!(t, t2);
        let ti = Tensor::from_i32(&[3], vec![-1, 0, i32::MAX]).unwrap();
        let t2i = Tensor::from_bytes(&[3], DType::I32, &ti.to_bytes()).unwrap();
        assert_eq!(ti, t2i);
    }

    #[test]
    fn init_uniform_respects_bound() {
        let mut rng = Rng::new(0);
        let t = Tensor::init(&[1000], &InitSpec::Uniform { bound: 0.1 }, &mut rng);
        let v = t.as_f32().unwrap();
        assert!(v.iter().all(|x| x.abs() <= 0.1));
        let mean: f32 = v.iter().sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.02);
        // not all equal
        assert!(v.iter().any(|&x| (x - v[0]).abs() > 1e-6));
    }

    #[test]
    fn init_normal_std() {
        let mut rng = Rng::new(1);
        let t = Tensor::init(&[5000], &InitSpec::Normal { std: 0.02 }, &mut rng);
        let v = t.as_f32().unwrap();
        let var: f32 = v.iter().map(|x| x * x).sum::<f32>() / 5000.0;
        assert!((var.sqrt() - 0.02).abs() < 0.002);
    }

    #[test]
    fn mapped_tensor_behaves_like_f32() {
        let dir = std::env::temp_dir().join("dyad-repro-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tensor_mapped.bin");
        let vals = vec![1.0f32, -2.5, 3.25, 4.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let map = super::super::mapped::Mapping::open(&path).unwrap();
        let view = MappedF32::new(map, 0, 4).unwrap();
        let t = Tensor::from_mapped(&[2, 2], view.clone()).unwrap();
        assert!(t.is_mapped());
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.numel(), 4);
        assert_eq!(t.as_f32().unwrap(), &vals[..]);
        // equality and byte export are storage-independent
        let heap = Tensor::from_f32(&[2, 2], vals).unwrap();
        assert_eq!(t, heap);
        assert_eq!(heap, t);
        assert_eq!(t.to_bytes(), heap.to_bytes());
        assert!(!heap.is_mapped());
        // mapped storage is read-only
        let err = t.clone().as_f32_mut().unwrap_err().to_string();
        assert!(err.contains("read-only"), "{err}");
        // shape validation still applies
        assert!(Tensor::from_mapped(&[3], view).is_err());
    }

    #[test]
    fn scalar_and_diff() {
        assert_eq!(Tensor::scalar_f32(2.5).scalar_value_f32().unwrap(), 2.5);
        let a = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_f32(&[2], vec![1.0, 2.5]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
        assert!(a.allclose(&b, 0.6));
        assert!(!a.allclose(&b, 0.4));
    }
}

//! `check`: run a seeded-random property many times, report the first
//! failing case with its seed so it can be replayed deterministically.
//!
//! ```
//! use dyad_repro::testing::prop::check;
//! use dyad_repro::util::rng::Rng;
//! check("addition commutes", 100, |rng: &mut Rng| {
//!     let (a, b) = (rng.f32(), rng.f32());
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Run `cases` random trials of `property`. Each trial gets an
/// independent RNG derived from the trial index, so failures print a
/// directly replayable seed. Panics on the first failure.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1AD_5EEDu64);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay with \
                 PROP_SEED={base} / case seed {seed}):\n  {msg}"
            );
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("replay seed {seed} failed:\n  {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("below bound", 50, |rng| {
            let n = rng.range(1, 100);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failure_with_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }
}

//! Quantized weight codecs + micro-ops for the block kernels.
//!
//! Two storage formats, both lossy only on the *weight* stream —
//! activations, partial sums and master weights stay f32:
//!
//! - **bf16**: f32 truncated to the top 16 bits with round-to-nearest
//!   -even. Same exponent range as f32, 8-bit significand, so the
//!   worst-case relative error per weight is 2^-8 ≈ 0.4% (half the
//!   2^-7 ulp).
//! - **int8**: per-block-row symmetric quantisation. Each weight row
//!   (one output neuron's slice of a block) gets one f32 scale
//!   `max_abs / 127`; entries are `round(v / scale)` clamped to
//!   [-127, 127], and the kernels dequantise in registers — the i8
//!   dot product is accumulated in f32 and multiplied by the row
//!   scale once at the end, so the result is deterministic and the
//!   roundtrip error per weight is at most `max_abs / 254` (half a
//!   quantisation step).
//!
//! The micro-ops (`dot_bf16` / `axpy_bf16` / `dot_i8` / `axpy_i8`)
//! mirror `kernel.rs`'s 8-wide unrolled scalar style and share its
//! debug-asserted equal-length contract. They deliberately stay
//! scalar even under `--features simd`: the decode step dominates and
//! the f32 side of every fused kernel already vectorises, so the
//! quantized paths trade peak FLOPs for bytes moved — the
//! compute-per-byte argument of PAPER.md §3.4 / Compute Better Spent.

/// Encode one f32 as bf16 (round-to-nearest-even, NaN-safe).
pub fn bf16_from_f32(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // quiet the NaN so truncation can't produce an infinity
        return ((bits >> 16) as u16) | 0x0040;
    }
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    (rounded >> 16) as u16
}

/// Decode bf16 back to f32 (exact: bf16 values are a subset of f32).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode a whole slice as bf16.
pub fn encode_bf16(w: &[f32]) -> Vec<u16> {
    let mut out = vec![0u16; w.len()];
    encode_bf16_into(w, &mut out);
    out
}

/// [`encode_bf16`] into a caller-owned buffer of the same length —
/// the kernels' per-call encode scratch is recycled, not reallocated.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn encode_bf16_into(w: &[f32], out: &mut [u16]) {
    assert_eq!(w.len(), out.len());
    for (o, &v) in out.iter_mut().zip(w) {
        *o = bf16_from_f32(v);
    }
}

/// Per-row symmetric int8 quantisation of `rows = w.len() / row_len`
/// weight rows. Returns `(q, scales)`; an all-zero row gets scale 0.
pub fn quantize_rows_i8(w: &[f32], row_len: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(row_len > 0 && w.len() % row_len == 0, "w.len() must be a multiple of row_len");
    let mut q = vec![0i8; w.len()];
    let mut scales = vec![0.0f32; w.len() / row_len];
    quantize_rows_i8_into(w, row_len, &mut q, &mut scales);
    (q, scales)
}

/// [`quantize_rows_i8`] into caller-owned `q` (`w.len()`) and `scales`
/// (`w.len() / row_len`) buffers, for recycled encode scratch.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn quantize_rows_i8_into(w: &[f32], row_len: usize, q: &mut [i8], scales: &mut [f32]) {
    assert!(row_len > 0 && w.len() % row_len == 0, "w.len() must be a multiple of row_len");
    let rows = w.len() / row_len;
    assert_eq!(q.len(), w.len());
    assert_eq!(scales.len(), rows);
    for r in 0..rows {
        let row = &w[r * row_len..(r + 1) * row_len];
        let qrow = &mut q[r * row_len..(r + 1) * row_len];
        let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = max_abs / 127.0;
        scales[r] = scale;
        if scale == 0.0 {
            qrow.fill(0);
        } else {
            for (o, &v) in qrow.iter_mut().zip(row) {
                *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

/// Dequantise per-row int8 back to f32 (the values the kernels see).
pub fn dequantize_rows_i8(q: &[i8], scales: &[f32], row_len: usize) -> Vec<f32> {
    assert_eq!(q.len(), scales.len() * row_len);
    q.iter()
        .enumerate()
        .map(|(i, &qv)| qv as f32 * scales[i / row_len])
        .collect()
}

/// dot over a bf16 weight row and f32 activations. Decodes in
/// registers; accumulation order matches `kernel::dot`'s scalar path
/// (8 parallel accumulators, pairwise-summed).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dot_bf16(w: &[u16], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len(), "dot_bf16: length mismatch");
    let n = w.len().min(x.len());
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            acc[l] += bf16_to_f32(w[i + l]) * x[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    while i < n {
        s += bf16_to_f32(w[i]) * x[i];
        i += 1;
    }
    s
}

/// `out[j] += a * decode(w[j])` over a bf16 weight row.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn axpy_bf16(out: &mut [f32], a: f32, w: &[u16]) {
    debug_assert_eq!(out.len(), w.len(), "axpy_bf16: length mismatch");
    let n = out.len().min(w.len());
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            out[i + l] += a * bf16_to_f32(w[i + l]);
        }
        i += 8;
    }
    while i < n {
        out[i] += a * bf16_to_f32(w[i]);
        i += 1;
    }
}

/// dot over an int8 weight row and f32 activations, *without* the row
/// scale — the caller multiplies the scale exactly once, so the f32
/// accumulation is identical no matter how the row was scaled.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dot_i8(q: &[i8], x: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), x.len(), "dot_i8: length mismatch");
    let n = q.len().min(x.len());
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            acc[l] += q[i + l] as f32 * x[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    while i < n {
        s += q[i] as f32 * x[i];
        i += 1;
    }
    s
}

/// `out[j] += a * q[j]` over an int8 weight row; the caller folds the
/// row scale into `a` (`a = coeff * scale[row]`).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn axpy_i8(out: &mut [f32], a: f32, q: &[i8]) {
    debug_assert_eq!(out.len(), q.len(), "axpy_i8: length mismatch");
    let n = out.len().min(q.len());
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            out[i + l] += a * q[i + l] as f32;
        }
        i += 8;
    }
    while i < n {
        out[i] += a * q[i] as f32;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_roundtrip_error_bound_and_ties() {
        // worst-case relative error of RNE truncation is 2^-8
        let mut rng = Rng::new(7);
        for _ in 0..4000 {
            let v = rng.uniform(-8.0, 8.0);
            let d = bf16_to_f32(bf16_from_f32(v));
            assert!(
                (d - v).abs() <= v.abs() / 256.0 + f32::MIN_POSITIVE,
                "v={v} decoded={d}"
            );
        }
        // exact values survive
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5] {
            assert_eq!(bf16_to_f32(bf16_from_f32(v)), v);
        }
        // tie rounds to even mantissa: 0x3F80_8000 is exactly halfway
        // between 0x3F80 and 0x3F81 -> stays at even 0x3F80
        assert_eq!(bf16_from_f32(f32::from_bits(0x3F80_8000)), 0x3F80);
        // just above the tie rounds up
        assert_eq!(bf16_from_f32(f32::from_bits(0x3F80_8001)), 0x3F81);
        // odd-mantissa tie rounds up to even: 0x3F81_8000 -> 0x3F82
        assert_eq!(bf16_from_f32(f32::from_bits(0x3F81_8000)), 0x3F82);
        // NaN stays NaN, infinities survive
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn i8_roundtrip_per_row_error_bound() {
        // property: for every row, |deq - v| <= max_abs(row) / 254
        // (half a quantisation step), including sign-asymmetric rows
        let mut rng = Rng::new(13);
        for (rows, row_len) in [(4, 16), (3, 7), (1, 1), (5, 19)] {
            let w: Vec<f32> =
                (0..rows * row_len).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let (q, scales) = quantize_rows_i8(&w, row_len);
            assert_eq!(scales.len(), rows);
            let deq = dequantize_rows_i8(&q, &scales, row_len);
            for r in 0..rows {
                let row = &w[r * row_len..(r + 1) * row_len];
                let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                for k in 0..row_len {
                    let err = (deq[r * row_len + k] - row[k]).abs();
                    assert!(
                        err <= max_abs / 254.0 + 1e-6,
                        "row {r} k {k}: err {err} > bound {}",
                        max_abs / 254.0
                    );
                }
                // the max-magnitude entry maps to exactly +-127
                let kmax = (0..row_len)
                    .max_by(|&a, &b| row[a].abs().partial_cmp(&row[b].abs()).unwrap())
                    .unwrap();
                assert_eq!(q[r * row_len + kmax].unsigned_abs(), 127);
            }
        }
    }

    #[test]
    fn i8_zero_row_is_exact() {
        let w = vec![0.0f32; 12];
        let (q, scales) = quantize_rows_i8(&w, 4);
        assert!(q.iter().all(|&v| v == 0));
        assert!(scales.iter().all(|&s| s == 0.0));
        assert_eq!(dequantize_rows_i8(&q, &scales, 4), w);
    }

    #[test]
    fn quantized_microkernels_match_dequantized_reference() {
        // dot_* / axpy_* over encoded rows must equal the plain scalar
        // ops over the dequantised row, at every remainder length
        let mut rng = Rng::new(31);
        for n in [0usize, 1, 7, 8, 9, 16, 19] {
            let w: Vec<f32> = (0..n.max(1)).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let w = &w[..n];
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();

            let wb = encode_bf16(w);
            let deq_b: Vec<f32> = wb.iter().map(|&b| bf16_to_f32(b)).collect();
            let want: f32 = crate::dyad::kernel::dot(&deq_b, &x);
            let got = dot_bf16(&wb, &x);
            assert!((got - want).abs() <= 1e-5 * (1.0 + want.abs()), "n={n}");

            let mut o1 = vec![0.25f32; n];
            let mut o2 = o1.clone();
            axpy_bf16(&mut o1, 0.7, &wb);
            crate::dyad::kernel::axpy(&mut o2, 0.7, &deq_b);
            // tolerance, not bitwise: under --features simd the f32
            // axpy reference fuses the multiply-add
            for (a, b) in o1.iter().zip(&o2) {
                assert!((a - b).abs() <= 1e-6, "axpy_bf16 n={n}");
            }

            if n > 0 {
                let (q, scales) = quantize_rows_i8(w, n);
                let deq_q = dequantize_rows_i8(&q, &scales, n);
                let want_q: f32 = deq_q.iter().zip(&x).map(|(a, b)| a * b).sum();
                let got_q = dot_i8(&q, &x) * scales[0];
                assert!(
                    (got_q - want_q).abs() <= 1e-4 * (1.0 + want_q.abs()),
                    "dot_i8 n={n}: {got_q} vs {want_q}"
                );
                let mut o3 = vec![0.5f32; n];
                let mut o4 = o3.clone();
                axpy_i8(&mut o3, 0.7 * scales[0], &q);
                crate::dyad::kernel::axpy(&mut o4, 0.7, &deq_q);
                for (a, b) in o3.iter().zip(&o4) {
                    assert!((a - b).abs() <= 1e-5, "axpy_i8 n={n}");
                }
            }
        }
    }
}

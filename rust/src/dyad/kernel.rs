//! Fast native DYAD + dense kernels: cache-blocked, multi-threaded.
//!
//! This is the hot path of the native CPU backend. Unlike the oracles
//! in [`super::math`] (kept simple for property tests), these kernels:
//!
//! * split work across row panels on the resident
//!   [`crate::runtime::pool`] worker pool, one panel per lane, so no
//!   synchronisation is needed inside a call and no OS thread is
//!   spawned after warmup (the legacy `std::thread::scope` path stays
//!   reachable via `pool::with_scoped_spawns` for parity tests and
//!   `benches/pool_overhead.rs` — the pool split is bitwise identical
//!   to it at equal thread count);
//! * block the dense matmul over the inner dimension so the B panel
//!   stays cache-resident while a row panel streams through it;
//! * run the fused DYAD forward (paper Eqs 3-10) *row-wise*: each
//!   output row accumulates its BLOCKDIAG and BLOCKTRANS contributions
//!   in one pass ([`axpy2`]) — permuted rows are written in place,
//!   with no per-block `x2` gather allocation and no temporary `y_i`
//!   buffer;
//! * run the DYAD *backward* the same way: [`dyad_backward_dx`] is the
//!   mirror of the forward schedule over `W^T` (input rows own their
//!   accumulation) and [`dyad_backward_dw`] accumulates each `dwl`/
//!   `dwu` block row directly from the activation/gradient streams —
//!   no `(f_out, f_in)` materialisation anywhere in training.
//!
//! Every output row is produced by exactly one thread in a fixed
//! sequential accumulation order, so results are bitwise identical for
//! any thread count (asserted by the determinism property tests).
//!
//! # Microkernel length contract
//!
//! Every microkernel ([`axpy`], [`axpy2`], [`dot`], and the quantized
//! `dot_bf16`/`axpy_bf16`/`dot_i8`/`axpy_i8` in [`super::quant`])
//! requires all operand slices to have equal length. The contract is
//! `debug_assert`ed uniformly: a mismatch is a shape bug upstream and
//! fails loudly in debug builds; release builds clamp to the shortest
//! slice rather than reading out of bounds.
//!
//! # -CAT fused schedule (paper §3.4.3)
//!
//! [`Variant::ItCat`] computes exactly the IT operator but through a
//! concatenated schedule: one gather builds a block-grouped panel
//! `[x block i | IT-permuted view of block i]`, after which *both*
//! components of every output row (forward) or weight row (dw) stream
//! one contiguous slice — the strided Eq-9 reads are paid once per
//! call instead of once per row. The IT `dx` pass is already a
//! contiguous single pass (its output permutation is the identity),
//! so -CAT reuses it unchanged ([`dyad_cat_backward_dx`]).
//!
//! # Precision
//!
//! The `*_prec` entry points stream the *weight* operand in
//! [`Precision::Bf16`] or [`Precision::I8`] (per-block-row symmetric
//! scale, dequantised in registers; see [`super::quant`]) while
//! activations, partial sums and the stored master weights stay f32.
//! `Precision::F32` routes to the exact pre-existing kernels — it is
//! bitwise identical to not using the `_prec` APIs at all. The weight
//! gradient (`dw`) has no weight-stream operand and is always f32.
//!
//! # SIMD
//!
//! With `--features simd` on x86_64, [`axpy`]/[`axpy2`]/[`dot`]
//! dispatch to explicit AVX2+FMA lanes when the host supports them
//! (runtime-detected once). FMA contracts the multiply-add, so simd
//! results differ from the scalar path in the last bits — the
//! determinism guarantee (same kernel, same thread-count-independent
//! bits) still holds; only *cross*-schedule bitwise comparisons are
//! scalar-build-only.

use std::sync::OnceLock;

use super::layout::{DyadDims, Variant};
use super::quant::{
    axpy_bf16, axpy_i8, bf16_to_f32, dot_bf16, dot_i8, encode_bf16_into, quantize_rows_i8_into,
};
use crate::runtime::pool;
use crate::tensor::Precision;

/// Thread-local best-fit recyclers for kernel-internal scratch: the
/// -CAT gather panels, transpose intermediates, and the quantized
/// weight-encode buffers. A `take_*` that misses the free list counts
/// as a kernel allocation ([`pool::counters`]); after warmup the same
/// call sequence hits every time, so the steady state allocates
/// nothing. Buffers are zero-filled on `take_f32`/`take_u16`/`take_i8`
/// so recycled scratch is indistinguishable from a fresh `vec![0; _]`.
pub(crate) mod scratch {
    use crate::runtime::pool::counters;
    use std::cell::RefCell;

    /// Free-list cap per type per thread — bounds idle memory without
    /// ever evicting in a steady-state loop. Sized for a full
    /// transformer train step, which recycles every tape frame,
    /// activation and gradient buffer it touched (a few per layer).
    /// When the list is full an incoming `put` is dropped (newest
    /// loses); listed buffers are never evicted.
    pub(crate) const MAX_FREE: usize = 256;

    macro_rules! recycler {
        ($take:ident, $put:ident, $contains:ident, $free_len:ident, $list:ident, $t:ty, $zero:expr) => {
            thread_local! {
                static $list: RefCell<Vec<Vec<$t>>> = const { RefCell::new(Vec::new()) };
            }

            /// Zero-filled buffer of `len`, reusing the smallest free
            /// buffer whose capacity fits (best fit, so a repeating
            /// size sequence converges to all-hits).
            pub(crate) fn $take(len: usize) -> Vec<$t> {
                let hit = $list.with(|l| {
                    let mut l = l.borrow_mut();
                    let mut best: Option<usize> = None;
                    for (i, v) in l.iter().enumerate() {
                        if v.capacity() < len {
                            continue;
                        }
                        let better = match best {
                            None => true,
                            Some(b) => v.capacity() < l[b].capacity(),
                        };
                        if better {
                            best = Some(i);
                        }
                    }
                    best.map(|i| l.swap_remove(i))
                });
                match hit {
                    Some(mut v) => {
                        counters::note_arena_hit();
                        v.clear();
                        v.resize(len, $zero);
                        v
                    }
                    None => {
                        counters::note_kernel_alloc();
                        vec![$zero; len]
                    }
                }
            }

            /// Poisoning probe: is a buffer with this base address
            /// already on this thread's free list? A true hit inside
            /// `put` means the same allocation was returned twice —
            /// two live `Vec`s would alias one heap block, and
            /// dropping either would free the other's storage.
            pub(crate) fn $contains(p: *const $t) -> bool {
                $list.with(|l| l.borrow().iter().any(|v| std::ptr::eq(v.as_ptr(), p)))
            }

            /// Number of buffers currently on this thread's free list.
            #[cfg(test)]
            pub(crate) fn $free_len() -> usize {
                $list.with(|l| l.borrow().len())
            }

            /// Return a buffer to this thread's free list. Buffers
            /// past the [`MAX_FREE`] cap (and zero-capacity buffers)
            /// are dropped instead. Debug builds poison double puts:
            /// a duplicate is detected by base address and the call
            /// panics *without dropping the duplicate* — the storage
            /// still belongs to the copy already on the list, so
            /// unwinding must not free it.
            pub(crate) fn $put(v: Vec<$t>) {
                if v.capacity() == 0 {
                    return;
                }
                // No drop rights until the buffer is proven not to
                // alias a listed one (see the doc above).
                let v = std::mem::ManuallyDrop::new(v);
                if cfg!(debug_assertions) && $contains(v.as_ptr()) {
                    panic!(concat!(
                        "scratch::",
                        stringify!($put),
                        ": double put — buffer is already on the free list"
                    ));
                }
                let v = std::mem::ManuallyDrop::into_inner(v);
                $list.with(|l| {
                    let mut l = l.borrow_mut();
                    if l.len() < MAX_FREE {
                        l.push(v);
                    }
                });
            }
        };
    }

    recycler!(take_f32, put_f32, contains_f32, free_len_f32, F32_FREE, f32, 0.0f32);
    recycler!(take_u16, put_u16, contains_u16, free_len_u16, U16_FREE, u16, 0u16);
    recycler!(take_i8, put_i8, contains_i8, free_len_i8, I8_FREE, i8, 0i8);
}

/// Edge-case coverage for the scratch recycler. Each test runs on its
/// own libtest thread, so every test starts from empty thread-local
/// free lists.
#[cfg(test)]
mod scratch_tests {
    use super::scratch;
    use crate::runtime::pool::counters;

    #[test]
    fn take_put_roundtrip_recycles_the_same_allocation() {
        let before = counters::snapshot();
        let v = scratch::take_f32(64);
        let p = v.as_ptr();
        assert_eq!(v, vec![0.0f32; 64]);
        scratch::put_f32(v);
        assert!(scratch::contains_f32(p));
        assert_eq!(scratch::free_len_f32(), 1);
        let v2 = scratch::take_f32(64);
        assert_eq!(v2.as_ptr(), p, "second take must reuse the block");
        assert_eq!(v2, vec![0.0f32; 64], "recycled buffer must be re-zeroed");
        let d = counters::snapshot().since(&before);
        assert_eq!(d.kernel_allocs, 1, "only the first take allocates");
        assert_eq!(d.arena_hits, 1, "the second take must hit the list");
    }

    #[test]
    fn take_prefers_the_smallest_fitting_buffer() {
        let small = scratch::take_u16(4);
        let big = scratch::take_u16(1024);
        let ps = small.as_ptr();
        scratch::put_u16(big);
        scratch::put_u16(small);
        assert_eq!(scratch::free_len_u16(), 2);
        let got = scratch::take_u16(4);
        assert_eq!(got.as_ptr(), ps, "best fit must pick the 4-slot buffer");
    }

    #[test]
    fn zero_capacity_buffers_are_never_listed() {
        let v = scratch::take_u16(0);
        assert_eq!(v.len(), 0);
        scratch::put_u16(v);
        assert_eq!(scratch::free_len_u16(), 0);
        scratch::put_u16(Vec::new());
        assert_eq!(scratch::free_len_u16(), 0);
    }

    #[test]
    fn free_list_is_capped_and_newest_put_loses() {
        for _ in 0..scratch::MAX_FREE {
            scratch::put_i8(vec![0i8; 1]);
        }
        assert_eq!(scratch::free_len_i8(), scratch::MAX_FREE);
        let extra = vec![7i8; 9];
        let p = extra.as_ptr();
        scratch::put_i8(extra);
        assert_eq!(scratch::free_len_i8(), scratch::MAX_FREE, "cap must hold");
        assert!(!scratch::contains_i8(p), "the over-cap put is dropped, not listed");
    }

    #[test]
    fn free_lists_are_per_thread() {
        scratch::put_f32(vec![1.0f32; 8]);
        assert_eq!(scratch::free_len_f32(), 1);
        std::thread::spawn(|| {
            assert_eq!(scratch::free_len_f32(), 0, "fresh thread, fresh list");
            scratch::put_f32(vec![2.0f32; 8]);
            assert_eq!(scratch::free_len_f32(), 1);
        })
        .join()
        .unwrap();
        assert_eq!(scratch::free_len_f32(), 1, "other thread's puts stay there");
    }

    /// The poisoning detector itself: manufacture a second `Vec` over
    /// the same heap block and verify the debug-build `put` panics
    /// without touching the storage. `put` holds its argument in
    /// `ManuallyDrop` until the aliasing check passes, so no path
    /// double-frees. Miri's aliasing model would (rightly) flag the
    /// manufactured alias itself, so this test is host-only.
    #[cfg(not(miri))]
    #[test]
    fn double_put_is_poisoned_in_debug_builds() {
        if !cfg!(debug_assertions) {
            return;
        }
        let mut v = scratch::take_f32(16);
        let (p, len, cap) = (v.as_mut_ptr(), v.len(), v.capacity());
        scratch::put_f32(v);
        // SAFETY: same raw parts as the Vec just listed. `put` wraps
        // the alias in ManuallyDrop and panics before any drop, so the
        // heap block is only ever freed through the listed copy.
        let alias = unsafe { Vec::from_raw_parts(p, len, cap) };
        let r = std::panic::catch_unwind(|| scratch::put_f32(alias));
        assert!(r.is_err(), "double put must panic in debug builds");
        assert_eq!(scratch::free_len_f32(), 1, "original entry must survive");
        let back = scratch::take_f32(16);
        assert_eq!(back.as_ptr(), p as *const f32, "listed copy stays usable");
    }
}

/// A kernel-output buffer from the thread-local recycler. The
/// `Vec`-returning entry points draw every output from here, so a
/// steady-state loop that recycles its buffers (the layer stack does,
/// via `Workspace::recycle`) allocates nothing after warmup; a miss
/// counts as a kernel allocation and the zero-alloc tests assert the
/// steady state has none.
fn fresh_out(len: usize) -> Vec<f32> {
    scratch::take_f32(len)
}

/// Worker count: `DYAD_NUM_THREADS` env override, else the machine's
/// available parallelism, else 1.
///
/// Resolved once per process and cached in a [`OnceLock`] — kernels
/// call this on every dispatch, and re-reading the environment is a
/// syscall in the hot path. The cache only pins the *default*:
/// explicit pool construction ([`pool::ThreadPool::new`],
/// [`pool::sized`]) and the `*_with_threads` escape hatches honor the
/// caller's count and never consult it. Tests that need a specific
/// count use those instead of mutating the env.
pub fn num_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("DYAD_NUM_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Explicit AVX2+FMA microkernels behind `--features simd`. Each is
/// `#[target_feature]`-compiled and only ever called after
/// [`simd::enabled`] has verified the host supports both ISA
/// extensions, so the `unsafe` is the intrinsic calls alone — slices
/// are still bounds-managed by length like the scalar paths.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    use std::arch::x86_64::*;
    use std::sync::OnceLock;

    /// Runtime gate, resolved once: AVX2 and FMA both present.
    pub fn enabled() -> bool {
        static CACHED: OnceLock<bool> = OnceLock::new();
        *CACHED
            .get_or_init(|| is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"))
    }

    /// Horizontal sum of 8 lanes (extract/add halves, then the
    /// movehdup/movehl shuffle ladder down to one lane).
    ///
    /// # Safety
    ///
    /// The host must support AVX2+FMA; call only after [`enabled`].
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        // SAFETY: caller verified AVX2+FMA via `enabled()`; pure
        // register shuffles, no memory access.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps::<1>(v);
            let q = _mm_add_ps(lo, hi);
            let shuf = _mm_movehdup_ps(q);
            let sums = _mm_add_ps(q, shuf);
            let hi2 = _mm_movehl_ps(shuf, sums);
            _mm_cvtss_f32(_mm_add_ss(sums, hi2))
        }
    }

    /// AVX2+FMA dot product (8-wide FMA lanes + scalar tail).
    ///
    /// # Safety
    ///
    /// The host must support AVX2+FMA; call only after [`enabled`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut i = 0;
        // SAFETY: caller verified AVX2+FMA via `enabled()`; every
        // unaligned load stays below `n = min(len)` by the `i + 8`
        // guard, and the tail is scalar-indexed.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            while i + 8 <= n {
                let av = _mm256_loadu_ps(a.as_ptr().add(i));
                let bv = _mm256_loadu_ps(b.as_ptr().add(i));
                acc = _mm256_fmadd_ps(av, bv, acc);
                i += 8;
            }
            let mut s = hsum(acc);
            while i < n {
                s += a[i] * b[i];
                i += 1;
            }
            s
        }
    }

    /// AVX2+FMA `out += a * x` (8-wide FMA lanes + scalar tail).
    ///
    /// # Safety
    ///
    /// The host must support AVX2+FMA; call only after [`enabled`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len().min(x.len());
        let mut i = 0;
        // SAFETY: caller verified AVX2+FMA via `enabled()`; loads and
        // stores stay below `n = min(len)` by the `i + 8` guard.
        unsafe {
            let av = _mm256_set1_ps(a);
            while i + 8 <= n {
                let xv = _mm256_loadu_ps(x.as_ptr().add(i));
                let ov = _mm256_loadu_ps(out.as_ptr().add(i));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(av, xv, ov));
                i += 8;
            }
        }
        while i < n {
            out[i] += a * x[i];
            i += 1;
        }
    }

    /// AVX2+FMA fused `out += a * x + b * z` (one store stream).
    ///
    /// # Safety
    ///
    /// The host must support AVX2+FMA; call only after [`enabled`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(out: &mut [f32], a: f32, x: &[f32], b: f32, z: &[f32]) {
        let n = out.len().min(x.len()).min(z.len());
        let mut i = 0;
        // SAFETY: caller verified AVX2+FMA via `enabled()`; loads and
        // stores stay below `n = min(len)` by the `i + 8` guard.
        unsafe {
            let av = _mm256_set1_ps(a);
            let bv = _mm256_set1_ps(b);
            while i + 8 <= n {
                let xv = _mm256_loadu_ps(x.as_ptr().add(i));
                let zv = _mm256_loadu_ps(z.as_ptr().add(i));
                let ov = _mm256_loadu_ps(out.as_ptr().add(i));
                let t = _mm256_fmadd_ps(av, xv, ov);
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(bv, zv, t));
                i += 8;
            }
        }
        while i < n {
            out[i] += a * x[i] + b * z[i];
            i += 1;
        }
    }
}

/// `out[j] += a * x[j]` over one row, 8-wide unrolled so the
/// autovectoriser emits full-width lanes.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len(), "axpy: length mismatch");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::enabled() {
        // SAFETY: enabled() checked AVX2+FMA at runtime
        return unsafe { simd::axpy(out, a, x) };
    }
    let n = out.len().min(x.len());
    let mut oc = out[..n].chunks_exact_mut(8);
    let mut xc = x[..n].chunks_exact(8);
    for (o8, x8) in (&mut oc).zip(&mut xc) {
        for i in 0..8 {
            o8[i] += a * x8[i];
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * v;
    }
}

/// Fused dual-source update `out[j] += a * x[j] + b * z[j]`: one pass
/// over the output row for both DYAD components, so the store stream
/// (and the loop overhead) is paid once instead of twice.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
#[inline]
pub fn axpy2(out: &mut [f32], a: f32, x: &[f32], b: f32, z: &[f32]) {
    debug_assert_eq!(out.len(), x.len(), "axpy2: x length mismatch");
    debug_assert_eq!(out.len(), z.len(), "axpy2: z length mismatch");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::enabled() {
        // SAFETY: enabled() checked AVX2+FMA at runtime
        return unsafe { simd::axpy2(out, a, x, b, z) };
    }
    let n = out.len().min(x.len()).min(z.len());
    let mut oc = out[..n].chunks_exact_mut(8);
    let mut xc = x[..n].chunks_exact(8);
    let mut zc = z[..n].chunks_exact(8);
    for ((o8, x8), z8) in (&mut oc).zip(&mut xc).zip(&mut zc) {
        for i in 0..8 {
            o8[i] += a * x8[i] + b * z8[i];
        }
    }
    for ((o, &xv), &zv) in oc
        .into_remainder()
        .iter_mut()
        .zip(xc.remainder())
        .zip(zc.remainder())
    {
        *o += a * xv + b * zv;
    }
}

/// Dot product with 8 independent accumulators (full-width ILP on long
/// rows). The operands must be the same length — a mismatch is a shape
/// bug upstream and fails loudly in debug builds instead of silently
/// truncating to the shorter slice.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(
        a.len(),
        b.len(),
        "dot: length mismatch {} vs {}",
        a.len(),
        b.len()
    );
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::enabled() {
        // SAFETY: enabled() checked AVX2+FMA at runtime
        return unsafe { simd::dot(a, b) };
    }
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; 8];
    let mut ac = a[..n].chunks_exact(8);
    let mut bc = b[..n].chunks_exact(8);
    for (a8, b8) in (&mut ac).zip(&mut bc) {
        for i in 0..8 {
            acc[i] += a8[i] * b8[i];
        }
    }
    let mut s =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        s += x * y;
    }
    s
}

/// A weight matrix viewed as fixed-length rows, at some storage
/// precision. The fused kernels are generic over this, monomorphised
/// per precision: [`F32Rows`] delegates straight to the f32
/// microkernels (bitwise identical to the pre-precision code), while
/// [`Bf16Rows`]/[`I8Rows`] decode in registers ([`super::quant`]).
trait WeightRows: Sync {
    /// Single entry `w[r, j]`, dequantised.
    fn at(&self, r: usize, j: usize) -> f32;
    /// `dot(w[r, :], x)`.
    fn dot_row(&self, r: usize, x: &[f32]) -> f32;
    /// `out[j] += a * w[r, j]`.
    fn axpy_row(&self, out: &mut [f32], a: f32, r: usize);
}

/// Borrowed f32 rows — the exact existing kernels.
struct F32Rows<'a> {
    w: &'a [f32],
    row_len: usize,
}

impl<'a> F32Rows<'a> {
    fn new(w: &'a [f32], row_len: usize) -> Self {
        debug_assert!(row_len > 0 && w.len() % row_len == 0);
        F32Rows { w, row_len }
    }
}

impl WeightRows for F32Rows<'_> {
    #[inline]
    fn at(&self, r: usize, j: usize) -> f32 {
        self.w[r * self.row_len + j]
    }

    #[inline]
    fn dot_row(&self, r: usize, x: &[f32]) -> f32 {
        dot(&self.w[r * self.row_len..(r + 1) * self.row_len], x)
    }

    #[inline]
    fn axpy_row(&self, out: &mut [f32], a: f32, r: usize) {
        axpy(out, a, &self.w[r * self.row_len..(r + 1) * self.row_len]);
    }
}

/// bf16-truncated rows (encoded once per kernel call, into recycled
/// [`scratch`] so the steady state re-encodes without allocating).
struct Bf16Rows {
    w: Vec<u16>,
    row_len: usize,
}

impl Bf16Rows {
    fn encode(w: &[f32], row_len: usize) -> Self {
        debug_assert!(row_len > 0 && w.len() % row_len == 0);
        let mut buf = scratch::take_u16(w.len());
        encode_bf16_into(w, &mut buf);
        Bf16Rows { w: buf, row_len }
    }
}

impl Drop for Bf16Rows {
    fn drop(&mut self) {
        scratch::put_u16(std::mem::take(&mut self.w));
    }
}

impl WeightRows for Bf16Rows {
    #[inline]
    fn at(&self, r: usize, j: usize) -> f32 {
        bf16_to_f32(self.w[r * self.row_len + j])
    }

    #[inline]
    fn dot_row(&self, r: usize, x: &[f32]) -> f32 {
        dot_bf16(&self.w[r * self.row_len..(r + 1) * self.row_len], x)
    }

    #[inline]
    fn axpy_row(&self, out: &mut [f32], a: f32, r: usize) {
        axpy_bf16(out, a, &self.w[r * self.row_len..(r + 1) * self.row_len]);
    }
}

/// Per-row symmetric int8 rows; the row scale is applied exactly once
/// per dot/axpy, outside the accumulation loop.
struct I8Rows {
    q: Vec<i8>,
    scale: Vec<f32>,
    row_len: usize,
}

impl I8Rows {
    fn encode(w: &[f32], row_len: usize) -> Self {
        debug_assert!(row_len > 0 && w.len() % row_len == 0);
        let mut q = scratch::take_i8(w.len());
        let mut scale = scratch::take_f32(w.len() / row_len);
        quantize_rows_i8_into(w, row_len, &mut q, &mut scale);
        I8Rows { q, scale, row_len }
    }
}

impl Drop for I8Rows {
    fn drop(&mut self) {
        scratch::put_i8(std::mem::take(&mut self.q));
        scratch::put_f32(std::mem::take(&mut self.scale));
    }
}

impl WeightRows for I8Rows {
    #[inline]
    fn at(&self, r: usize, j: usize) -> f32 {
        self.q[r * self.row_len + j] as f32 * self.scale[r]
    }

    #[inline]
    fn dot_row(&self, r: usize, x: &[f32]) -> f32 {
        dot_i8(&self.q[r * self.row_len..(r + 1) * self.row_len], x) * self.scale[r]
    }

    #[inline]
    fn axpy_row(&self, out: &mut [f32], a: f32, r: usize) {
        axpy_i8(
            out,
            a * self.scale[r],
            &self.q[r * self.row_len..(r + 1) * self.row_len],
        );
    }
}

/// Run `f(row_index, row_slice)` for every `row_len`-sized row of
/// `out`, split across `threads` row panels. Rows are disjoint, so the
/// closure runs without any locking; each row sees a fixed sequential
/// execution, keeping results independent of the thread count.
///
/// Dispatches on the resident [`pool::sized`] worker pool — panel `t`
/// of the `rows_per = n_rows.div_ceil(threads)` split is lane `t`'s
/// task, the exact chunking the old scoped-spawn path used, so the
/// results are bitwise identical to it at equal thread count (and no
/// OS thread is spawned after the pool exists). The legacy spawn path
/// stays reachable via [`pool::with_scoped_spawns`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, threads: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_len;
    let threads = threads.clamp(1, n_rows.max(1));
    if threads <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row);
        }
        return;
    }
    if pool::scoped_spawns_forced() {
        return parallel_rows_scoped(out, row_len, threads, f);
    }
    parallel_rows_in(&pool::sized(threads), out, row_len, f);
}

/// [`parallel_rows`] on an explicit pool handle: the panel split uses
/// `pool.threads()` lanes (clamped to the row count), task `t` owning
/// the `t`-th `rows_per`-row panel.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn parallel_rows_in<F>(pool: &pool::ThreadPool, out: &mut [f32], row_len: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_len;
    let threads = pool.threads().clamp(1, n_rows.max(1));
    let rows_per = n_rows.div_ceil(threads);
    pool.run_chunks(out, rows_per * row_len, &|t, chunk| {
        let start = t * rows_per;
        for (i, row) in chunk.chunks_mut(row_len).enumerate() {
            f(start + i, row);
        }
    });
}

/// The pre-pool reference path: one fresh OS thread per panel via
/// `std::thread::scope`, identical split. Kept (and spawn-counted) so
/// parity tests and `benches/pool_overhead.rs` can measure the pool
/// against it through the same public entry points.
fn parallel_rows_scoped<F>(out: &mut [f32], row_len: usize, threads: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let n_rows = out.len() / row_len;
    let rows_per = n_rows.div_ceil(threads);
    pool::counters::note_spawn(out.len().div_ceil(rows_per * row_len) as u64);
    // xtask:allow(thread_spawn): legacy scoped-spawn reference path,
    // kept (spawn-counted) for pool-vs-scoped parity tests/benches.
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * row_len).enumerate() {
            let start = t * rows_per;
            s.spawn(move || {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    f(start + i, row);
                }
            });
        }
    });
}

/// Row-major `(m, k) x (k, n) -> (m, n)`, parallel over row panels and
/// blocked over `k` so each B panel is reused across a whole row panel.
pub fn matmul_fast(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_fast_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_fast_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    let mut out = fresh_out(m * n);
    matmul_fast_into(a, b, m, k, n, threads, &mut out);
    out
}

/// [`matmul_fast`] into a caller-owned `(m, n)` buffer, zeroed here —
/// hand it a recycled arena buffer and the call allocates nothing.
/// Panel schedule and accumulation order are identical to the `Vec`
/// entry point: bitwise-equal results.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn matmul_fast_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    // B panel of KB rows: KB * n * 4 bytes; 64 rows of a 4096-wide B is
    // 1 MB — L2-resident on anything we target.
    const KB: usize = 64;
    let rows_per = m.div_ceil(threads);
    let panel = |t: usize, chunk: &mut [f32]| {
        let i0 = t * rows_per;
        let rows = chunk.len() / n;
        let mut p0 = 0;
        while p0 < k {
            let p1 = (p0 + KB).min(k);
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                let orow = &mut chunk[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate().take(p1).skip(p0) {
                    if av != 0.0 {
                        axpy(orow, av, &b[p * n..(p + 1) * n]);
                    }
                }
            }
            p0 = p1;
        }
    };
    if threads <= 1 {
        panel(0, out);
        return;
    }
    if pool::scoped_spawns_forced() {
        pool::counters::note_spawn(out.len().div_ceil(rows_per * n) as u64);
        let panel = &panel;
        // xtask:allow(thread_spawn): legacy scoped-spawn reference
        // path for pool-vs-scoped parity (see parallel_rows_scoped).
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                s.spawn(move || panel(t, chunk));
            }
        });
        return;
    }
    pool::sized(threads).run_chunks(out, rows_per * n, &panel);
}

/// `a (m, k) @ b^T` where `b` is `(n, k)` row-major — the natural form
/// for `y = x @ W^T` linears. Both operands stream contiguously.
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_bt_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_bt_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    let mut out = fresh_out(m * n);
    matmul_bt_into(a, b, m, k, n, threads, &mut out);
    out
}

/// [`matmul_bt`] into a caller-owned `(m, n)` buffer. Every element is
/// overwritten (each output row is a fresh dot sweep), so a dirty
/// recycled buffer is fine.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn matmul_bt_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    parallel_rows(out, n, threads, &|i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * k..(j + 1) * k]);
        }
    });
}

/// Transpose a row-major `(m, n)` matrix into `(n, m)`.
pub fn transpose(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = fresh_out(m * n);
    transpose_into(a, m, n, &mut out);
    out
}

/// Transpose a row-major `(m, n)` matrix into a caller-owned `(n, m)`
/// buffer (the backward pass transposes weight blocks in place into
/// one scratch allocation instead of one `Vec` per block).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn transpose_into(a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), m * n);
    // simple tiled transpose; tiles keep both sides cache-friendly
    const T: usize = 32;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + T).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + T).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    out[j * m + i] = a[i * n + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Dense linear on row-major activations: `x (t, f_in) @ w^T + b`
/// with `w (f_out, f_in)` — returns `(t, f_out)`.
pub fn dense_linear(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
) -> Vec<f32> {
    dense_linear_with_threads(x, w, bias, t, f_in, f_out, num_threads())
}

pub fn dense_linear_with_threads(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(t * f_out);
    dense_linear_into(x, w, bias, t, f_in, f_out, threads, &mut y);
    y
}

/// [`dense_linear`] into a caller-owned `(t, f_out)` buffer (fully
/// overwritten).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dense_linear_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    threads: usize,
    y: &mut [f32],
) {
    matmul_bt_into(x, w, t, f_in, f_out, threads, y);
    if let Some(b) = bias {
        for row in y.chunks_mut(f_out.max(1)) {
            for (o, &bv) in row.iter_mut().zip(b) {
                *o += bv;
            }
        }
    }
}

/// [`dense_linear`] with the weight matrix streamed at a chosen
/// precision (quantised per output row). `F32` routes to the exact
/// existing kernel.
pub fn dense_linear_prec(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    prec: Precision,
) -> Vec<f32> {
    dense_linear_prec_with_threads(x, w, bias, t, f_in, f_out, prec, num_threads())
}

pub fn dense_linear_prec_with_threads(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(t * f_out);
    dense_linear_prec_into(x, w, bias, t, f_in, f_out, prec, threads, &mut y);
    y
}

/// [`dense_linear_prec`] into a caller-owned `(t, f_out)` buffer
/// (fully overwritten; the weight-encode scratch is recycled).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dense_linear_prec_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    prec: Precision,
    threads: usize,
    y: &mut [f32],
) {
    assert_eq!(x.len(), t * f_in);
    assert_eq!(w.len(), f_out * f_in);
    assert_eq!(y.len(), t * f_out);
    match prec {
        Precision::F32 => dense_linear_into(x, w, bias, t, f_in, f_out, threads, y),
        Precision::Bf16 => {
            let wm = Bf16Rows::encode(w, f_in);
            dense_linear_generic(x, &wm, bias, t, f_in, f_out, threads, y);
        }
        Precision::I8 => {
            let wm = I8Rows::encode(w, f_in);
            dense_linear_generic(x, &wm, bias, t, f_in, f_out, threads, y);
        }
    }
}

/// Per-row `y[i, j] = dot(w[j, :], x[i, :]) (+ b[j])` — the
/// [`matmul_bt`] schedule over generic weight rows.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn dense_linear_generic<W: WeightRows>(
    x: &[f32],
    wm: &W,
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    threads: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), t * f_out);
    parallel_rows(y, f_out, threads, &|i, orow| {
        let xrow = &x[i * f_in..(i + 1) * f_in];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = wm.dot_row(j, xrow);
        }
        if let Some(b) = bias {
            for (o, &bv) in orow.iter_mut().zip(b) {
                *o += bv;
            }
        }
    });
}

/// [`matmul_fast`] with the `b` operand streamed at a chosen
/// precision (quantised per row of `b`) — the dense backward's
/// `dx = dy @ W` at reduced weight precision. `F32` routes to the
/// exact existing kernel.
pub fn matmul_fast_prec_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut out = fresh_out(m * n);
    matmul_fast_prec_into(a, b, m, k, n, prec, threads, &mut out);
    out
}

/// [`matmul_fast_prec_with_threads`] into a caller-owned `(m, n)`
/// buffer (zeroed here; the weight-encode scratch is recycled).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn matmul_fast_prec_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    prec: Precision,
    threads: usize,
    out: &mut [f32],
) {
    match prec {
        Precision::F32 => matmul_fast_into(a, b, m, k, n, threads, out),
        Precision::Bf16 => {
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
            let bm = Bf16Rows::encode(b, n);
            matmul_rows_generic(a, &bm, m, k, n, threads, out);
        }
        Precision::I8 => {
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
            let bm = I8Rows::encode(b, n);
            matmul_rows_generic(a, &bm, m, k, n, threads, out);
        }
    }
}

/// `(m, k) x (k, n)` with generic rows of the right operand; same
/// per-row accumulation order (`p` ascending, zero-skip) as
/// [`matmul_fast`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn matmul_rows_generic<W: WeightRows>(
    a: &[f32],
    bm: &W,
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    parallel_rows(out, n, threads, &|i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                bm.axpy_row(orow, av, p);
            }
        }
    });
}

/// Fused DYAD forward (paper Eqs 3-10) on column-major activations:
/// `x (f_in, nb)` -> `y (f_out, nb)`, `y = (W1 + W2) x (+ bias)`.
///
/// Row-wise schedule: output row `r` receives its BLOCKDIAG
/// contribution from block `r / n_out` and its BLOCKTRANS contribution
/// from the block the output permutation maps it to — so permuted rows
/// are written in place and no `x2` gather or `y_i` temporary exists.
/// Matches `dyad::math::dyad_matmul` (the oracle) bit-for-bit in
/// structure, to float-accumulation-order tolerance in value.
pub fn dyad_fused(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_fused_with_threads(wl, wu, x, dims, variant, nb, bias, num_threads())
}

pub fn dyad_fused_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(dims.f_out() * nb);
    dyad_fused_into(wl, wu, x, dims, variant, nb, bias, threads, &mut y);
    y
}

/// [`dyad_fused`] into a caller-owned `(f_out, nb)` buffer (zeroed
/// here — recycled arena buffers are fine).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_fused_into(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
    y: &mut [f32],
) {
    assert_fused_shapes(wl, wu, x, dims, nb, bias);
    let w1m = F32Rows::new(wl, dims.n_in);
    let w2m = F32Rows::new(wu, dims.n_in);
    dyad_fused_generic(&w1m, &w2m, x, dims, variant, nb, bias, threads, y);
}

/// Fused DYAD forward at a chosen weight-stream precision. `F32`
/// routes to [`dyad_fused_with_threads`] unchanged (bitwise
/// identical); `Bf16`/`I8` encode the component rows once per call
/// and dequantise in registers.
pub fn dyad_fused_prec(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) -> Vec<f32> {
    dyad_fused_prec_with_threads(wl, wu, x, dims, variant, nb, bias, prec, num_threads())
}

pub fn dyad_fused_prec_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(dims.f_out() * nb);
    dyad_fused_prec_into(wl, wu, x, dims, variant, nb, bias, prec, threads, &mut y);
    y
}

/// [`dyad_fused_prec`] into a caller-owned `(f_out, nb)` buffer
/// (zeroed here; the weight-encode scratch is recycled).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_fused_prec_into(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    threads: usize,
    y: &mut [f32],
) {
    match prec {
        Precision::F32 => dyad_fused_into(wl, wu, x, dims, variant, nb, bias, threads, y),
        Precision::Bf16 => {
            assert_fused_shapes(wl, wu, x, dims, nb, bias);
            let w1m = Bf16Rows::encode(wl, dims.n_in);
            let w2m = Bf16Rows::encode(wu, dims.n_in);
            dyad_fused_generic(&w1m, &w2m, x, dims, variant, nb, bias, threads, y);
        }
        Precision::I8 => {
            assert_fused_shapes(wl, wu, x, dims, nb, bias);
            let w1m = I8Rows::encode(wl, dims.n_in);
            let w2m = I8Rows::encode(wu, dims.n_in);
            dyad_fused_generic(&w1m, &w2m, x, dims, variant, nb, bias, threads, y);
        }
    }
}

/// The §3.4.3 -CAT fused forward on f32 weights: identical algebra to
/// IT, concatenated single-pass schedule. Equivalent to calling
/// [`dyad_fused`] with [`Variant::ItCat`].
pub fn dyad_fused_cat(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    nb: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_fused_cat_with_threads(wl, wu, x, dims, nb, bias, num_threads())
}

pub fn dyad_fused_cat_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(dims.f_out() * nb);
    dyad_fused_cat_into(wl, wu, x, dims, nb, bias, threads, &mut y);
    y
}

/// [`dyad_fused_cat`] into a caller-owned `(f_out, nb)` buffer; the
/// gathered -CAT panel comes from recycled [`scratch`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_fused_cat_into(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
    y: &mut [f32],
) {
    assert_fused_shapes(wl, wu, x, dims, nb, bias);
    let w1m = F32Rows::new(wl, dims.n_in);
    let w2m = F32Rows::new(wu, dims.n_in);
    dyad_fused_cat_generic(&w1m, &w2m, x, dims, nb, bias, threads, y);
}

fn assert_fused_shapes(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    nb: usize,
    bias: Option<&[f32]>,
) {
    assert_eq!(wl.len(), dims.component_params());
    assert_eq!(wu.len(), dims.component_params());
    assert_eq!(x.len(), dims.f_in() * nb);
    if let Some(b) = bias {
        assert_eq!(b.len(), dims.f_out());
    }
}

/// The fused forward schedule, generic over weight-row storage.
/// [`Variant::ItCat`] detours to the concatenated -CAT schedule; every
/// other variant runs the PR 2 row-wise schedule verbatim.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn dyad_fused_generic<W1: WeightRows, W2: WeightRows>(
    w1m: &W1,
    w2m: &W2,
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
    y: &mut [f32],
) {
    if variant.is_cat() {
        return dyad_fused_cat_generic(w1m, w2m, x, dims, nb, bias, threads, y);
    }
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let in_perm = variant.in_perm();
    let out_perm = variant.out_perm();
    assert_eq!(y.len(), dims.f_out() * nb);
    y.fill(0.0);
    parallel_rows(y, nb, threads, &|r, orow| {
        if let Some(b) = bias {
            orow.fill(b[r]);
        }
        // BLOCKDIAG: row r lives in block i1 = r / n_out. BLOCKTRANS:
        // with the output permutation, row r = o2*n_dyad + i2 (the
        // Eq-9 stride swap); without it, same indexing as BLOCKDIAG.
        // Both components contribute exactly n_in terms per output
        // row, so the two passes fuse into one axpy2 sweep.
        let (i1, o1) = (r / n_out, r % n_out);
        let (i2, o2) = if out_perm {
            (r % n_dyad, r / n_dyad)
        } else {
            (i1, o1)
        };
        let (r1, r2) = (i1 * n_out + o1, i2 * n_out + o2);
        let base = i1 * n_in;
        if nb == 1 {
            let mut s = w1m.dot_row(r1, &x[base..base + n_in]);
            if in_perm {
                for k in 0..n_in {
                    s += w2m.at(r2, k) * x[k * n_dyad + i2];
                }
            } else {
                s += w2m.dot_row(r2, &x[i2 * n_in..(i2 + 1) * n_in]);
            }
            orow[0] += s;
        } else {
            for k in 0..n_in {
                let src1 = base + k;
                let src2 = if in_perm { k * n_dyad + i2 } else { i2 * n_in + k };
                axpy2(
                    orow,
                    w1m.at(r1, k),
                    &x[src1 * nb..(src1 + 1) * nb],
                    w2m.at(r2, k),
                    &x[src2 * nb..(src2 + 1) * nb],
                );
            }
        }
    });
}

/// The -CAT forward: gather the block-grouped concatenated panel
/// `xc[(2*f_in, nb)]` once — block i's segment is
/// `[x rows i*n_in..(i+1)*n_in | permuted rows k*n_dyad + i]` — then
/// every output row streams one contiguous `(2*n_in, nb)` slab. For
/// `nb == 1` both half-rows reduce to plain contiguous dots (the
/// serving-shaped win: no strided Eq-9 reads in the inner loop at
/// all); for `nb > 1` the per-`k` axpy2 sources become adjacent
/// panel rows, matching the IT schedule's values and order exactly
/// (the parity tests pin this bitwise).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn dyad_fused_cat_generic<W1: WeightRows, W2: WeightRows>(
    w1m: &W1,
    w2m: &W2,
    x: &[f32],
    dims: DyadDims,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
    y: &mut [f32],
) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let two_n_in = 2 * n_in;
    let mut xc = scratch::take_f32(2 * dims.f_in() * nb);
    parallel_rows(&mut xc, nb, threads, &|j, row| {
        let (i, r) = (j / two_n_in, j % two_n_in);
        let src = if r < n_in { i * n_in + r } else { (r - n_in) * n_dyad + i };
        row.copy_from_slice(&x[src * nb..(src + 1) * nb]);
    });
    assert_eq!(y.len(), dims.f_out() * nb);
    y.fill(0.0);
    parallel_rows(y, nb, threads, &|r, orow| {
        if let Some(b) = bias {
            orow.fill(b[r]);
        }
        // IT has no output permutation: both components read weight
        // row r and block i1 = r / n_out of the gathered panel.
        let i1 = r / n_out;
        let base = i1 * two_n_in;
        if nb == 1 {
            let s = w1m.dot_row(r, &xc[base..base + n_in])
                + w2m.dot_row(r, &xc[base + n_in..base + two_n_in]);
            orow[0] += s;
        } else {
            for k in 0..n_in {
                let src1 = base + k;
                axpy2(
                    orow,
                    w1m.at(r, k),
                    &xc[src1 * nb..(src1 + 1) * nb],
                    w2m.at(r, k),
                    &xc[(src1 + n_in) * nb..(src1 + n_in + 1) * nb],
                );
            }
        }
    });
    scratch::put_f32(xc);
}

/// DYAD linear on row-major activations (`x (t, f_in)` -> `(t, f_out)`),
/// transposing in and out around the column-major fused kernel — the
/// same one-transpose-in / one-transpose-out scheme the L2 model uses.
pub fn dyad_linear(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_linear_with_threads(wl, wu, x, dims, variant, t, bias, num_threads())
}

pub fn dyad_linear_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(t * dims.f_out());
    dyad_linear_into(wl, wu, x, dims, variant, t, bias, threads, &mut y);
    y
}

/// [`dyad_linear`] into a caller-owned `(t, f_out)` buffer; the
/// transpose intermediates come from recycled [`scratch`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_linear_into(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    threads: usize,
    y: &mut [f32],
) {
    dyad_linear_prec_into(wl, wu, x, dims, variant, t, bias, Precision::F32, threads, y);
}

/// Row-major [`dyad_fused_prec_with_threads`].
pub fn dyad_linear_prec(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) -> Vec<f32> {
    dyad_linear_prec_with_threads(wl, wu, x, dims, variant, t, bias, prec, num_threads())
}

pub fn dyad_linear_prec_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut y = fresh_out(t * dims.f_out());
    dyad_linear_prec_into(wl, wu, x, dims, variant, t, bias, prec, threads, &mut y);
    y
}

/// [`dyad_linear_prec`] into a caller-owned `(t, f_out)` buffer; the
/// transpose intermediates come from recycled [`scratch`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_linear_prec_into(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    threads: usize,
    y: &mut [f32],
) {
    assert_eq!(y.len(), t * dims.f_out());
    let mut xc = scratch::take_f32(t * dims.f_in());
    transpose_into(x, t, dims.f_in(), &mut xc);
    let mut yc = scratch::take_f32(dims.f_out() * t);
    dyad_fused_prec_into(wl, wu, &xc, dims, variant, t, bias, prec, threads, &mut yc);
    transpose_into(&yc, dims.f_out(), t, y);
    scratch::put_f32(xc);
    scratch::put_f32(yc);
}

/// Transpose each `(n_out, n_in)` block of a component tensor into
/// `(n_in, n_out)`. The backward `dx` pass streams weights along the
/// output-feature axis, which is stride-`n_in` in the stored layout —
/// one O(component_params) block transpose (2/n_dyad of dense, reused
/// across every activation column and input row) turns that into a
/// contiguous read. The *activations* are never gathered or copied.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn transpose_blocks_into(w: &[f32], dims: DyadDims, out: &mut [f32]) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(w.len(), dims.component_params());
    assert_eq!(out.len(), w.len());
    let blk = n_out * n_in;
    for i in 0..n_dyad {
        let src = &w[i * blk..(i + 1) * blk];
        transpose_into(src, n_out, n_in, &mut out[i * blk..(i + 1) * blk]);
    }
}

/// Structured DYAD backward, input-gradient half (paper training path):
/// `dx = W^T dy = (W1 + W2)^T dy` on column-major gradients
/// `dy (f_out, nb)` -> `dx (f_in, nb)`, without materialising `W`.
///
/// Mirror of [`dyad_fused`]: each *input* row owns its accumulation.
/// Input row c takes its BLOCKDIAG^T terms from block `c / n_in` and
/// its BLOCKTRANS^T terms from the block the *input* permutation maps
/// it to (`c = k2*n_dyad + i2`, the same Eq-9 stride swap the forward
/// applies on the output side) — so permuted rows are read/written in
/// place, with no gather buffers and no `dyad_full` call. Both
/// components contribute n_out terms per row; the sweeps fuse via
/// [`axpy2`]. Bitwise deterministic across thread counts.
pub fn dyad_backward_dx(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
) -> Vec<f32> {
    dyad_backward_dx_with_threads(wl, wu, dy, dims, variant, nb, num_threads())
}

pub fn dyad_backward_dx_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    threads: usize,
) -> Vec<f32> {
    dyad_backward_dx_prec_with_threads(wl, wu, dy, dims, variant, nb, Precision::F32, threads)
}

/// [`dyad_backward_dx`] with the transposed weight blocks streamed at
/// a chosen precision (quantised *after* the block transpose, i.e.
/// per transposed block row — each row is one input feature's slice).
/// `F32` is bitwise identical to [`dyad_backward_dx`].
pub fn dyad_backward_dx_prec_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut dx = fresh_out(dims.f_in() * nb);
    dyad_backward_dx_prec_into(wl, wu, dy, dims, variant, nb, prec, threads, &mut dx);
    dx
}

/// [`dyad_backward_dx_prec_with_threads`] into a caller-owned
/// `(f_in, nb)` buffer; the block-transpose (and quantized-encode)
/// scratch is recycled.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_backward_dx_prec_into(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    prec: Precision,
    threads: usize,
    dx: &mut [f32],
) {
    assert_eq!(wl.len(), dims.component_params());
    assert_eq!(wu.len(), dims.component_params());
    assert_eq!(dy.len(), dims.f_out() * nb);
    let mut wlt = scratch::take_f32(wl.len());
    let mut wut = scratch::take_f32(wu.len());
    transpose_blocks_into(wl, dims, &mut wlt);
    transpose_blocks_into(wu, dims, &mut wut);
    match prec {
        Precision::F32 => {
            let w1m = F32Rows::new(&wlt, dims.n_out);
            let w2m = F32Rows::new(&wut, dims.n_out);
            dyad_backward_dx_generic(&w1m, &w2m, dy, dims, variant, nb, threads, dx);
        }
        Precision::Bf16 => {
            let w1m = Bf16Rows::encode(&wlt, dims.n_out);
            let w2m = Bf16Rows::encode(&wut, dims.n_out);
            dyad_backward_dx_generic(&w1m, &w2m, dy, dims, variant, nb, threads, dx);
        }
        Precision::I8 => {
            let w1m = I8Rows::encode(&wlt, dims.n_out);
            let w2m = I8Rows::encode(&wut, dims.n_out);
            dyad_backward_dx_generic(&w1m, &w2m, dy, dims, variant, nb, threads, dx);
        }
    }
    scratch::put_f32(wlt);
    scratch::put_f32(wut);
}

/// The IT `dx` schedule is already a fused contiguous single pass —
/// with no output permutation, both components' `dy` reads are
/// sequential block rows — so -CAT's backward input-gradient is the
/// plain IT kernel. This wrapper exists to make the fwd/dx/dw kernel
/// triple explicit at call sites.
pub fn dyad_cat_backward_dx(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    nb: usize,
) -> Vec<f32> {
    dyad_cat_backward_dx_with_threads(wl, wu, dy, dims, nb, num_threads())
}

pub fn dyad_cat_backward_dx_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    nb: usize,
    threads: usize,
) -> Vec<f32> {
    dyad_backward_dx_with_threads(wl, wu, dy, dims, Variant::ItCat, nb, threads)
}

/// xtask:hot-path — no direct heap allocation (scratch recycler only).
fn dyad_backward_dx_generic<W1: WeightRows, W2: WeightRows>(
    w1m: &W1,
    w2m: &W2,
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    threads: usize,
    dx: &mut [f32],
) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let in_perm = variant.in_perm();
    let out_perm = variant.out_perm();
    assert_eq!(dx.len(), dims.f_in() * nb);
    dx.fill(0.0);
    parallel_rows(dx, nb, threads, &|c, orow| {
        // BLOCKDIAG^T: input row c lives in block i1 = c / n_in.
        let (i1, k1) = (c / n_in, c % n_in);
        let r1 = i1 * n_in + k1;
        // BLOCKTRANS^T: with the input permutation, c = k2*n_dyad + i2.
        let (i2, k2) = if in_perm {
            (c % n_dyad, c / n_dyad)
        } else {
            (i1, k1)
        };
        let r2 = i2 * n_in + k2;
        if nb == 1 {
            let mut s = w1m.dot_row(r1, &dy[i1 * n_out..(i1 + 1) * n_out]);
            if out_perm {
                for o in 0..n_out {
                    s += w2m.at(r2, o) * dy[o * n_dyad + i2];
                }
            } else {
                s += w2m.dot_row(r2, &dy[i2 * n_out..(i2 + 1) * n_out]);
            }
            orow[0] = s;
        } else {
            for o in 0..n_out {
                let src1 = i1 * n_out + o;
                let src2 = if out_perm { o * n_dyad + i2 } else { i2 * n_out + o };
                axpy2(
                    orow,
                    w1m.at(r1, o),
                    &dy[src1 * nb..(src1 + 1) * nb],
                    w2m.at(r2, o),
                    &dy[src2 * nb..(src2 + 1) * nb],
                );
            }
        }
    });
}

/// Row-major wrapper for [`dyad_backward_dx`]: `dy (t, f_out)` ->
/// `dx (t, f_in)`, one transpose in / one transpose out, matching
/// [`dyad_linear`]'s scheme for the forward.
pub fn dyad_linear_backward_dx(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
) -> Vec<f32> {
    dyad_linear_backward_dx_with_threads(wl, wu, dy, dims, variant, t, num_threads())
}

pub fn dyad_linear_backward_dx_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    threads: usize,
) -> Vec<f32> {
    let dyc = transpose(dy, t, dims.f_out());
    let dxc = dyad_backward_dx_with_threads(wl, wu, &dyc, dims, variant, t, threads);
    transpose(&dxc, dims.f_in(), t)
}

/// Row-major [`dyad_backward_dx_prec_with_threads`].
pub fn dyad_linear_backward_dx_prec(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    prec: Precision,
) -> Vec<f32> {
    dyad_linear_backward_dx_prec_with_threads(wl, wu, dy, dims, variant, t, prec, num_threads())
}

pub fn dyad_linear_backward_dx_prec_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    prec: Precision,
    threads: usize,
) -> Vec<f32> {
    let mut dx = fresh_out(t * dims.f_in());
    dyad_linear_backward_dx_prec_into(wl, wu, dy, dims, variant, t, prec, threads, &mut dx);
    dx
}

/// [`dyad_linear_backward_dx_prec_with_threads`] into a caller-owned
/// `(t, f_in)` buffer; all transpose intermediates are recycled.
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_linear_backward_dx_prec_into(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    prec: Precision,
    threads: usize,
    dx: &mut [f32],
) {
    assert_eq!(dx.len(), t * dims.f_in());
    let mut dyc = scratch::take_f32(t * dims.f_out());
    transpose_into(dy, t, dims.f_out(), &mut dyc);
    let mut dxc = scratch::take_f32(dims.f_in() * t);
    dyad_backward_dx_prec_into(wl, wu, &dyc, dims, variant, t, prec, threads, &mut dxc);
    transpose_into(&dxc, dims.f_in(), t, dx);
    scratch::put_f32(dyc);
    scratch::put_f32(dxc);
}

/// Structured DYAD backward, weight-gradient half: accumulate the
/// block component gradients directly from row-major activations
/// `x (t, f_in)` and upstream gradients `dy (t, f_out)`:
///
/// * `dwl[i] = dy_blk_i^T @ x_blk_i` — block i of `dy` is columns
///   `[i*n_out, (i+1)*n_out)`, block i of `x` is columns
///   `[i*n_in, (i+1)*n_in)`;
/// * `dwu[i, o, k] = sum_t dy[t, pi_out(i,o)] * x[t, pi_in(i,k)]` —
///   the same entry of the full `dW` the old materialise-and-project
///   path read, computed without ever forming `dW`.
///
/// O(2 * t * total_params) work — the dense `dy^T @ x` costs n_dyad/2
/// times more. Each `dwl`/`dwu` row is owned by one thread and
/// accumulated in fixed `t` order: bitwise deterministic.
pub fn dyad_backward_dw(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
) -> (Vec<f32>, Vec<f32>) {
    dyad_backward_dw_with_threads(x, dy, dims, variant, t, num_threads())
}

pub fn dyad_backward_dw_with_threads(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dwl = fresh_out(dims.component_params());
    let mut dwu = fresh_out(dims.component_params());
    dyad_backward_dw_into(x, dy, dims, variant, t, threads, &mut dwl, &mut dwu);
    (dwl, dwu)
}

/// [`dyad_backward_dw`] into caller-owned component buffers (each
/// `component_params` long, zeroed here).
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_backward_dw_into(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    threads: usize,
    dwl: &mut [f32],
    dwu: &mut [f32],
) {
    if variant.is_cat() {
        return dyad_cat_backward_dw_into(x, dy, dims, t, threads, dwl, dwu);
    }
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    assert_eq!(x.len(), t * f_in);
    assert_eq!(dy.len(), t * f_out);
    assert_eq!(dwl.len(), dims.component_params());
    assert_eq!(dwu.len(), dims.component_params());
    let in_perm = variant.in_perm();
    let out_perm = variant.out_perm();
    dwl.fill(0.0);
    parallel_rows(dwl, n_in, threads, &|r, row| {
        let (i, o) = (r / n_out, r % n_out);
        for ti in 0..t {
            let a = dy[ti * f_out + i * n_out + o];
            if a != 0.0 {
                axpy(row, a, &x[ti * f_in + i * n_in..ti * f_in + (i + 1) * n_in]);
            }
        }
    });
    dwu.fill(0.0);
    parallel_rows(dwu, n_in, threads, &|r, row| {
        let (i, o) = (r / n_out, r % n_out);
        // pi_out(i, o) = o*n_dyad + i; pi_in(i, k) = k*n_dyad + i.
        let rp = if out_perm { o * n_dyad + i } else { i * n_out + o };
        for ti in 0..t {
            let a = dy[ti * f_out + rp];
            if a == 0.0 {
                continue;
            }
            let xt = &x[ti * f_in..(ti + 1) * f_in];
            if in_perm {
                for (k, rv) in row.iter_mut().enumerate() {
                    *rv += a * xt[k * n_dyad + i];
                }
            } else {
                axpy(row, a, &xt[i * n_in..(i + 1) * n_in]);
            }
        }
    });
}

/// The -CAT weight-gradient: gather the same block-grouped
/// concatenated panel as the forward, but row-major per token —
/// `xc[t, 2*f_in]`, block i's segment `[x block i | permuted cols
/// k*n_dyad + i]`. Because IT's `dwl[i,o,:]` and `dwu[i,o,:]` rows
/// share the *same* upstream coefficient `dy[t, i*n_out+o]`, both
/// accumulate with ONE contiguous `2*n_in` axpy per token, replacing
/// the plain path's separate axpy + strided gather loop. The fused
/// rows are split back into the two stored components at the end.
/// Elementwise identical to the plain IT `dw` on the scalar build.
pub fn dyad_cat_backward_dw(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    t: usize,
) -> (Vec<f32>, Vec<f32>) {
    dyad_cat_backward_dw_with_threads(x, dy, dims, t, num_threads())
}

pub fn dyad_cat_backward_dw_with_threads(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    t: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dwl = fresh_out(dims.component_params());
    let mut dwu = fresh_out(dims.component_params());
    dyad_cat_backward_dw_into(x, dy, dims, t, threads, &mut dwl, &mut dwu);
    (dwl, dwu)
}

/// [`dyad_cat_backward_dw`] into caller-owned component buffers; the
/// gathered panel and the fused gradient rows come from recycled
/// [`scratch`].
/// xtask:hot-path — no direct heap allocation (scratch recycler only).
pub fn dyad_cat_backward_dw_into(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    t: usize,
    threads: usize,
    dwl: &mut [f32],
    dwu: &mut [f32],
) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    assert_eq!(x.len(), t * f_in);
    assert_eq!(dy.len(), t * f_out);
    assert_eq!(dwl.len(), dims.component_params());
    assert_eq!(dwu.len(), dims.component_params());
    let two_n_in = 2 * n_in;
    let mut xc = scratch::take_f32(t * 2 * f_in);
    parallel_rows(&mut xc, 2 * f_in, threads, &|ti, row| {
        let xt = &x[ti * f_in..(ti + 1) * f_in];
        for i in 0..n_dyad {
            let seg = &mut row[i * two_n_in..(i + 1) * two_n_in];
            seg[..n_in].copy_from_slice(&xt[i * n_in..(i + 1) * n_in]);
            for k in 0..n_in {
                seg[n_in + k] = xt[k * n_dyad + i];
            }
        }
    });
    // fused gradient rows: dwc[i*n_out+o, :] = sum_t dy[t, i*n_out+o]
    //                                          * xc[t, block i]
    let mut dwc = scratch::take_f32(n_dyad * n_out * two_n_in);
    parallel_rows(&mut dwc, two_n_in, threads, &|r, row| {
        let (i, o) = (r / n_out, r % n_out);
        for ti in 0..t {
            let a = dy[ti * f_out + i * n_out + o];
            if a != 0.0 {
                let base = ti * 2 * f_in + i * two_n_in;
                axpy(row, a, &xc[base..base + two_n_in]);
            }
        }
    });
    for r in 0..n_dyad * n_out {
        let src = &dwc[r * two_n_in..(r + 1) * two_n_in];
        dwl[r * n_in..(r + 1) * n_in].copy_from_slice(&src[..n_in]);
        dwu[r * n_in..(r + 1) * n_in].copy_from_slice(&src[n_in..]);
    }
    scratch::put_f32(xc);
    scratch::put_f32(dwc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyad::layout::dyad_full;
    use crate::dyad::math::{dense_matmul, dyad_matmul, matmul};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_fast_matches_oracle() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 32)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let want = matmul(&a, &b, m, k, n);
            for threads in [1, 4] {
                let got = matmul_fast_with_threads(&a, &b, m, k, n, threads);
                for (x, y) in got.iter().zip(&want) {
                    assert!((x - y).abs() < 1e-4, "{m}x{k}x{n} t{threads}");
                }
            }
        }
    }

    #[test]
    fn matmul_bt_matches_transposed_oracle() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (9, 31, 13);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, n * k);
        let bt = transpose(&b, n, k); // (k, n)
        let want = matmul(&a, &bt, m, k, n);
        let got = matmul_bt(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(5);
        let (m, n) = (37, 53);
        let a = rand_vec(&mut rng, m * n);
        assert_eq!(transpose(&transpose(&a, m, n), n, m), a);
    }

    #[test]
    fn fused_matches_oracle_all_variants() {
        let mut rng = Rng::new(7);
        for (nd, n_in, n_out, nb) in [(4, 4, 4, 3), (2, 3, 5, 4), (8, 2, 2, 1), (1, 6, 2, 5)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            for v in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
                let want = dyad_matmul(&wl, &wu, &x, dims, v, nb, Some(&bias));
                let got = dyad_fused(&wl, &wu, &x, dims, v, nb, Some(&bias));
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{v:?} {dims:?}");
                }
            }
        }
    }

    #[test]
    fn fused_thread_count_is_bitwise_deterministic() {
        let mut rng = Rng::new(11);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let nb = 17;
        let x = rand_vec(&mut rng, dims.f_in() * nb);
        let one = dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, 1);
        for threads in [2, 3, 8] {
            let many =
                dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, threads);
            assert_eq!(one, many, "threads={threads} changed bits");
        }
    }

    #[test]
    fn dot_and_axpy2_remainders() {
        // exercise the 8-wide chunks + remainder tails at awkward lengths
        for n in [0usize, 1, 7, 8, 9, 16, 19] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 2.0 - i as f32 * 0.25).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "dot n={n}");
            let mut out = vec![1.0f32; n];
            axpy2(&mut out, 0.5, &a, -2.0, &b);
            for (i, o) in out.iter().enumerate() {
                let want = 1.0 + 0.5 * a[i] - 2.0 * b[i];
                assert!((o - want).abs() < 1e-5, "axpy2 n={n} i={i}");
            }
        }
    }

    /// Both structured backward kernels against the materialise-and-
    /// project oracle: all variants, rectangular blocks, the
    /// `n_dyad == 1` (single dense block) and `n_dyad == f_out`
    /// (1-row output blocks) edges, and `t == 1`.
    #[test]
    fn structured_backward_matches_reference() {
        use crate::dyad::math::dyad_backward;
        let mut rng = Rng::new(29);
        for (nd, n_in, n_out, t) in [
            (4, 4, 4, 3),
            (2, 3, 5, 4), // rectangular blocks
            (1, 6, 2, 5), // n_dyad == 1
            (4, 3, 1, 3), // n_dyad == f_out
            (8, 2, 2, 1), // t == 1 (serving-shaped)
        ] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let dy = rand_vec(&mut rng, t * dims.f_out());
            for v in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
                let (rwl, rwu, rdx) = dyad_backward(&wl, &wu, &x, &dy, dims, v, t);
                let (dwl, dwu) = dyad_backward_dw(&x, &dy, dims, v, t);
                let dx = dyad_linear_backward_dx(&wl, &wu, &dy, dims, v, t);
                for (name, got, want) in
                    [("dwl", &dwl, &rwl), ("dwu", &dwu, &rwu), ("dx", &dx, &rdx)]
                {
                    for (i, (a, b)) in got.iter().zip(want).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{v:?} {dims:?} t={t} {name}[{i}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn backward_kernels_thread_count_bitwise_deterministic() {
        let mut rng = Rng::new(31);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let t = 17;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let dyc = rand_vec(&mut rng, dims.f_out() * t); // column-major (f_out, t)
        let dyr = transpose(&dyc, dims.f_out(), t); // row-major (t, f_out)
        for v in [Variant::It, Variant::Ot, Variant::Dt] {
            let dx1 = dyad_backward_dx_with_threads(&wl, &wu, &dyc, dims, v, t, 1);
            let dw1 = dyad_backward_dw_with_threads(&x, &dyr, dims, v, t, 1);
            for threads in [2, 3, 8] {
                let dxn = dyad_backward_dx_with_threads(&wl, &wu, &dyc, dims, v, t, threads);
                assert_eq!(dx1, dxn, "{v:?} dx threads={threads} changed bits");
                let dwn = dyad_backward_dw_with_threads(&x, &dyr, dims, v, t, threads);
                assert_eq!(dw1, dwn, "{v:?} dw threads={threads} changed bits");
            }
        }
    }

    /// -CAT vs plain IT across the PR 2 edge grid: for `nb > 1` the
    /// two schedules issue the *same* axpy2 calls on the same values
    /// in the same order, so the outputs must be bitwise equal (simd
    /// included); `nb == 1` re-associates the BLOCKTRANS dot, so it
    /// gets a tolerance.
    #[test]
    fn cat_forward_parity_with_plain_it() {
        let mut rng = Rng::new(41);
        for (nd, n_in, n_out, nb) in [
            (4, 4, 4, 3),
            (2, 3, 5, 4), // rectangular blocks
            (1, 6, 2, 5), // n_dyad == 1
            (4, 3, 1, 3), // n_dyad == f_out
            (8, 2, 2, 1), // nb == 1 (serving-shaped)
        ] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            let it = dyad_fused(&wl, &wu, &x, dims, Variant::It, nb, Some(&bias));
            let cat = dyad_fused_cat(&wl, &wu, &x, dims, nb, Some(&bias));
            // the Variant::ItCat route and the explicit entry point
            // must be the same kernel
            let via_variant =
                dyad_fused(&wl, &wu, &x, dims, Variant::ItCat, nb, Some(&bias));
            assert_eq!(cat, via_variant, "{dims:?} nb={nb}");
            if nb > 1 {
                assert_eq!(cat, it, "{dims:?} nb={nb} must be bitwise");
            } else {
                for (a, b) in cat.iter().zip(&it) {
                    assert!((a - b).abs() < 1e-5, "{dims:?} nb={nb}");
                }
            }
        }
    }

    /// -CAT dw/dx vs plain IT across the same grid. `dx` shares IT's
    /// code path outright (bitwise, always). `dw` is elementwise
    /// identical on the scalar build; under simd the fused `2*n_in`
    /// rows vectorise at different chunk boundaries, so the bitwise
    /// assert is scalar-only and a tolerance holds everywhere.
    #[test]
    fn cat_backward_parity_with_plain_it() {
        let mut rng = Rng::new(43);
        for (nd, n_in, n_out, t) in [
            (4, 4, 4, 3),
            (2, 3, 5, 4),
            (1, 6, 2, 5),
            (4, 3, 1, 3),
            (8, 2, 2, 1),
        ] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let dyr = rand_vec(&mut rng, t * dims.f_out()); // row-major
            let dyc = rand_vec(&mut rng, dims.f_out() * t); // column-major

            let (iwl, iwu) = dyad_backward_dw(&x, &dyr, dims, Variant::It, t);
            let (cwl, cwu) = dyad_cat_backward_dw(&x, &dyr, dims, t);
            let via_variant = dyad_backward_dw(&x, &dyr, dims, Variant::ItCat, t);
            assert_eq!((cwl.clone(), cwu.clone()), via_variant, "{dims:?} t={t}");
            #[cfg(not(feature = "simd"))]
            {
                assert_eq!(cwl, iwl, "{dims:?} t={t} dwl must be bitwise (scalar)");
                assert_eq!(cwu, iwu, "{dims:?} t={t} dwu must be bitwise (scalar)");
            }
            for (name, got, want) in [("dwl", &cwl, &iwl), ("dwu", &cwu, &iwu)] {
                for (a, b) in got.iter().zip(want.iter()) {
                    assert!((a - b).abs() < 1e-5, "{dims:?} t={t} {name}");
                }
            }

            let idx = dyad_backward_dx(&wl, &wu, &dyc, dims, Variant::It, t);
            let cdx = dyad_cat_backward_dx(&wl, &wu, &dyc, dims, t);
            assert_eq!(cdx, idx, "{dims:?} t={t} dx must be bitwise");
        }
    }

    #[test]
    fn cat_kernels_thread_count_bitwise_deterministic() {
        let mut rng = Rng::new(47);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let (nb, t) = (17, 17);
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, dims.f_in() * nb);
        let xr = rand_vec(&mut rng, t * dims.f_in());
        let dyr = rand_vec(&mut rng, t * dims.f_out());
        let y1 = dyad_fused_cat_with_threads(&wl, &wu, &x, dims, nb, None, 1);
        let dw1 = dyad_cat_backward_dw_with_threads(&xr, &dyr, dims, t, 1);
        for threads in [2, 3, 8] {
            let yn = dyad_fused_cat_with_threads(&wl, &wu, &x, dims, nb, None, threads);
            assert_eq!(y1, yn, "cat fwd threads={threads} changed bits");
            let dwn = dyad_cat_backward_dw_with_threads(&xr, &dyr, dims, t, threads);
            assert_eq!(dw1, dwn, "cat dw threads={threads} changed bits");
        }
    }

    /// Quantized fwd/dx against the same kernel run on *dequantised*
    /// f32 weights: the only difference is where the rounding happens
    /// (registers vs a pre-pass), so the results agree to accumulation
    /// tolerance. Also pins that `Precision::F32` is bitwise identical
    /// to the plain entry points.
    #[test]
    fn quantized_kernels_match_dequantized_reference() {
        use crate::dyad::quant::{dequantize_rows_i8, encode_bf16, quantize_rows_i8};
        let mut rng = Rng::new(53);
        for (nd, n_in, n_out, nb) in [(4, 4, 4, 3), (2, 3, 5, 4), (8, 2, 2, 1)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            for v in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
                // F32 tag is the identity
                assert_eq!(
                    dyad_fused_prec(&wl, &wu, &x, dims, v, nb, Some(&bias), Precision::F32),
                    dyad_fused(&wl, &wu, &x, dims, v, nb, Some(&bias)),
                    "{v:?} {dims:?} F32 tag must be bitwise"
                );
                // bf16: dequantise = encode/decode roundtrip
                let dwl: Vec<f32> =
                    encode_bf16(&wl).iter().map(|&b| super::bf16_to_f32(b)).collect();
                let dwu: Vec<f32> =
                    encode_bf16(&wu).iter().map(|&b| super::bf16_to_f32(b)).collect();
                let want = dyad_fused(&dwl, &dwu, &x, dims, v, nb, Some(&bias));
                let got =
                    dyad_fused_prec(&wl, &wu, &x, dims, v, nb, Some(&bias), Precision::Bf16);
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{v:?} {dims:?} bf16 fwd");
                }
                // i8: per-block-row scales over the stored row layout
                let (ql, sl) = quantize_rows_i8(&wl, n_in);
                let (qu, su) = quantize_rows_i8(&wu, n_in);
                let dql = dequantize_rows_i8(&ql, &sl, n_in);
                let dqu = dequantize_rows_i8(&qu, &su, n_in);
                let want = dyad_fused(&dql, &dqu, &x, dims, v, nb, Some(&bias));
                let got =
                    dyad_fused_prec(&wl, &wu, &x, dims, v, nb, Some(&bias), Precision::I8);
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{v:?} {dims:?} i8 fwd");
                }
            }
        }
        // dx: quantisation happens after the block transpose, so the
        // reference here is the f32 dx with a tolerance scaled to the
        // per-weight quantisation error (bf16 2^-8, i8 1/254)
        let dims = DyadDims { n_dyad: 4, n_in: 6, n_out: 5 };
        let t = 7;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let dy = rand_vec(&mut rng, t * dims.f_out());
        for v in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
            let want = dyad_linear_backward_dx(&wl, &wu, &dy, dims, v, t);
            assert_eq!(
                dyad_linear_backward_dx_prec(&wl, &wu, &dy, dims, v, t, Precision::F32),
                want,
                "{v:?} dx F32 tag must be bitwise"
            );
            for (prec, tol) in [(Precision::Bf16, 0.05f32), (Precision::I8, 0.08f32)] {
                let got = dyad_linear_backward_dx_prec(&wl, &wu, &dy, dims, v, t, prec);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() <= tol * (1.0 + b.abs()),
                        "{v:?} {prec:?} dx: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_and_matmul_prec_match_dequantized_reference() {
        use crate::dyad::quant::{dequantize_rows_i8, encode_bf16, quantize_rows_i8};
        let mut rng = Rng::new(59);
        let (t, f_in, f_out) = (5, 19, 9);
        let x = rand_vec(&mut rng, t * f_in);
        let w = rand_vec(&mut rng, f_out * f_in);
        let bias = rand_vec(&mut rng, f_out);
        assert_eq!(
            dense_linear_prec(&x, &w, Some(&bias), t, f_in, f_out, Precision::F32),
            dense_linear(&x, &w, Some(&bias), t, f_in, f_out),
            "dense F32 tag must be bitwise"
        );
        let dwb: Vec<f32> = encode_bf16(&w).iter().map(|&b| super::bf16_to_f32(b)).collect();
        let want = dense_linear(&x, &dwb, Some(&bias), t, f_in, f_out);
        let got = dense_linear_prec(&x, &w, Some(&bias), t, f_in, f_out, Precision::Bf16);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "dense bf16");
        }
        let (q, sc) = quantize_rows_i8(&w, f_in);
        let dwq = dequantize_rows_i8(&q, &sc, f_in);
        let want = dense_linear(&x, &dwq, Some(&bias), t, f_in, f_out);
        let got = dense_linear_prec(&x, &w, Some(&bias), t, f_in, f_out, Precision::I8);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "dense i8");
        }
        // matmul_fast_prec: dy (t, f_out) @ w (f_out, f_in)
        let dy = rand_vec(&mut rng, t * f_out);
        assert_eq!(
            matmul_fast_prec_with_threads(&dy, &w, t, f_out, f_in, Precision::F32, 3),
            matmul_fast_with_threads(&dy, &w, t, f_out, f_in, 3),
            "matmul F32 tag must be bitwise"
        );
        let (q2, sc2) = quantize_rows_i8(&w, f_in);
        let dwq2 = dequantize_rows_i8(&q2, &sc2, f_in);
        let want = matmul_fast(&dy, &dwq2, t, f_out, f_in);
        let got = matmul_fast_prec_with_threads(&dy, &w, t, f_out, f_in, Precision::I8, 2);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "matmul i8");
        }
    }

    #[test]
    fn quantized_kernels_thread_count_bitwise_deterministic() {
        let mut rng = Rng::new(61);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let (nb, t) = (17, 13);
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, dims.f_in() * nb);
        let dy = rand_vec(&mut rng, t * dims.f_out());
        let (f_in, f_out) = (dims.f_in(), dims.f_out());
        let xr = rand_vec(&mut rng, t * f_in);
        let wd = rand_vec(&mut rng, f_out * f_in);
        for prec in [Precision::Bf16, Precision::I8] {
            for v in [Variant::ItCat, Variant::Dt] {
                let y1 = dyad_fused_prec_with_threads(
                    &wl, &wu, &x, dims, v, nb, None, prec, 1,
                );
                let dx1 = dyad_linear_backward_dx_prec_with_threads(
                    &wl, &wu, &dy, dims, v, t, prec, 1,
                );
                for threads in [2, 3, 8] {
                    let yn = dyad_fused_prec_with_threads(
                        &wl, &wu, &x, dims, v, nb, None, prec, threads,
                    );
                    assert_eq!(y1, yn, "{prec:?} {v:?} fwd threads={threads}");
                    let dxn = dyad_linear_backward_dx_prec_with_threads(
                        &wl, &wu, &dy, dims, v, t, prec, threads,
                    );
                    assert_eq!(dx1, dxn, "{prec:?} {v:?} dx threads={threads}");
                }
            }
            let d1 = dense_linear_prec_with_threads(&xr, &wd, None, t, f_in, f_out, prec, 1);
            let m1 = matmul_fast_prec_with_threads(&dy, &wd, t, f_out, f_in, prec, 1);
            for threads in [2, 3, 8] {
                let dn = dense_linear_prec_with_threads(
                    &xr, &wd, None, t, f_in, f_out, prec, threads,
                );
                assert_eq!(d1, dn, "{prec:?} dense threads={threads}");
                let mn =
                    matmul_fast_prec_with_threads(&dy, &wd, t, f_out, f_in, prec, threads);
                assert_eq!(m1, mn, "{prec:?} matmul threads={threads}");
            }
        }
    }

    /// Tentpole determinism contract: the resident-pool dispatch must
    /// be bitwise identical to the legacy scoped-spawn path for every
    /// kernel family, at equal thread counts {1, 2, 8}, across
    /// variants and weight precisions. Both sides run the *same*
    /// public entry points — [`pool::with_scoped_spawns`] flips the
    /// dispatch underneath.
    #[test]
    fn pool_matches_scoped_bitwise_for_every_kernel_family() {
        use crate::runtime::pool::with_scoped_spawns;
        let mut rng = Rng::new(71);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let (f_in, f_out) = (dims.f_in(), dims.f_out());
        let t = 13;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, f_in * t); // column-major (f_in, t)
        let xr = rand_vec(&mut rng, t * f_in); // row-major (t, f_in)
        let dyr = rand_vec(&mut rng, t * f_out);
        let wd = rand_vec(&mut rng, f_out * f_in);
        let bias = rand_vec(&mut rng, f_out);
        for threads in [1usize, 2, 8] {
            for prec in [Precision::F32, Precision::Bf16, Precision::I8] {
                for v in [Variant::It, Variant::ItCat, Variant::Dt] {
                    let p = dyad_fused_prec_with_threads(
                        &wl, &wu, &x, dims, v, t, Some(&bias), prec, threads,
                    );
                    let s = with_scoped_spawns(|| {
                        dyad_fused_prec_with_threads(
                            &wl, &wu, &x, dims, v, t, Some(&bias), prec, threads,
                        )
                    });
                    assert_eq!(p, s, "fused {v:?} {prec:?} threads={threads}");
                    let pdx = dyad_linear_backward_dx_prec_with_threads(
                        &wl, &wu, &dyr, dims, v, t, prec, threads,
                    );
                    let sdx = with_scoped_spawns(|| {
                        dyad_linear_backward_dx_prec_with_threads(
                            &wl, &wu, &dyr, dims, v, t, prec, threads,
                        )
                    });
                    assert_eq!(pdx, sdx, "dx {v:?} {prec:?} threads={threads}");
                }
                let pd = dense_linear_prec_with_threads(
                    &xr, &wd, Some(&bias), t, f_in, f_out, prec, threads,
                );
                let sd = with_scoped_spawns(|| {
                    dense_linear_prec_with_threads(
                        &xr, &wd, Some(&bias), t, f_in, f_out, prec, threads,
                    )
                });
                assert_eq!(pd, sd, "dense {prec:?} threads={threads}");
                let pm = matmul_fast_prec_with_threads(&dyr, &wd, t, f_out, f_in, prec, threads);
                let sm = with_scoped_spawns(|| {
                    matmul_fast_prec_with_threads(&dyr, &wd, t, f_out, f_in, prec, threads)
                });
                assert_eq!(pm, sm, "matmul {prec:?} threads={threads}");
            }
            for v in [Variant::It, Variant::ItCat, Variant::Dt] {
                let pw = dyad_backward_dw_with_threads(&xr, &dyr, dims, v, t, threads);
                let sw = with_scoped_spawns(|| {
                    dyad_backward_dw_with_threads(&xr, &dyr, dims, v, t, threads)
                });
                assert_eq!(pw, sw, "dw {v:?} threads={threads}");
            }
            let pb = matmul_bt_with_threads(&xr, &wd, t, f_in, f_out, threads);
            let sb = with_scoped_spawns(|| {
                matmul_bt_with_threads(&xr, &wd, t, f_in, f_out, threads)
            });
            assert_eq!(pb, sb, "matmul_bt threads={threads}");
        }
    }

    /// Every `_into` variant, handed a dirty (NaN-filled) buffer, must
    /// reproduce its `Vec`-returning entry point bitwise — recycled
    /// arena buffers are indistinguishable from fresh allocations.
    #[test]
    fn into_variants_match_vec_entry_points_bitwise() {
        let mut rng = Rng::new(73);
        let dims = DyadDims { n_dyad: 4, n_in: 6, n_out: 5 };
        let (f_in, f_out) = (dims.f_in(), dims.f_out());
        let t = 9;
        let threads = 3;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let xc = rand_vec(&mut rng, f_in * t);
        let xr = rand_vec(&mut rng, t * f_in);
        let dyr = rand_vec(&mut rng, t * f_out);
        let b = rand_vec(&mut rng, f_in * f_out); // (k, n) for matmul_fast
        let wd = rand_vec(&mut rng, f_out * f_in); // (f_out, f_in) weights
        let bias = rand_vec(&mut rng, f_out);

        let mut out = vec![f32::NAN; t * f_out];
        matmul_fast_into(&xr, &b, t, f_in, f_out, threads, &mut out);
        assert_eq!(out, matmul_fast_with_threads(&xr, &b, t, f_in, f_out, threads));

        let mut out = vec![f32::NAN; t * f_out];
        matmul_bt_into(&xr, &wd, t, f_in, f_out, threads, &mut out);
        assert_eq!(out, matmul_bt_with_threads(&xr, &wd, t, f_in, f_out, threads));

        let mut out = vec![f32::NAN; t * f_out];
        dense_linear_into(&xr, &wd, Some(&bias), t, f_in, f_out, threads, &mut out);
        assert_eq!(
            out,
            dense_linear_with_threads(&xr, &wd, Some(&bias), t, f_in, f_out, threads)
        );

        for prec in [Precision::F32, Precision::Bf16, Precision::I8] {
            let mut out = vec![f32::NAN; t * f_out];
            dense_linear_prec_into(&xr, &wd, Some(&bias), t, f_in, f_out, prec, threads, &mut out);
            assert_eq!(
                out,
                dense_linear_prec_with_threads(
                    &xr, &wd, Some(&bias), t, f_in, f_out, prec, threads
                ),
                "dense {prec:?}"
            );

            let mut out = vec![f32::NAN; t * f_in];
            matmul_fast_prec_into(&dyr, &wd, t, f_out, f_in, prec, threads, &mut out);
            assert_eq!(
                out,
                matmul_fast_prec_with_threads(&dyr, &wd, t, f_out, f_in, prec, threads),
                "matmul {prec:?}"
            );

            for v in [Variant::It, Variant::ItCat, Variant::Dt] {
                let mut out = vec![f32::NAN; f_out * t];
                dyad_fused_prec_into(
                    &wl, &wu, &xc, dims, v, t, Some(&bias), prec, threads, &mut out,
                );
                assert_eq!(
                    out,
                    dyad_fused_prec_with_threads(
                        &wl, &wu, &xc, dims, v, t, Some(&bias), prec, threads
                    ),
                    "fused {v:?} {prec:?}"
                );

                let mut out = vec![f32::NAN; t * f_out];
                dyad_linear_prec_into(
                    &wl, &wu, &xr, dims, v, t, Some(&bias), prec, threads, &mut out,
                );
                assert_eq!(
                    out,
                    dyad_linear_prec_with_threads(
                        &wl, &wu, &xr, dims, v, t, Some(&bias), prec, threads
                    ),
                    "linear {v:?} {prec:?}"
                );

                let mut out = vec![f32::NAN; t * f_in];
                dyad_linear_backward_dx_prec_into(
                    &wl, &wu, &dyr, dims, v, t, prec, threads, &mut out,
                );
                assert_eq!(
                    out,
                    dyad_linear_backward_dx_prec_with_threads(
                        &wl, &wu, &dyr, dims, v, t, prec, threads
                    ),
                    "dx {v:?} {prec:?}"
                );
            }
        }

        for v in [Variant::It, Variant::ItCat, Variant::Dt] {
            let mut dwl = vec![f32::NAN; dims.component_params()];
            let mut dwu = vec![f32::NAN; dims.component_params()];
            dyad_backward_dw_into(&xr, &dyr, dims, v, t, threads, &mut dwl, &mut dwu);
            assert_eq!(
                (dwl, dwu),
                dyad_backward_dw_with_threads(&xr, &dyr, dims, v, t, threads),
                "dw {v:?}"
            );
        }
    }

    /// The tentpole acceptance contract at the kernel layer: after a
    /// two-iteration warmup (pool built, scratch recyclers converged),
    /// a steady-state loop through the `_into` kernels performs zero
    /// OS thread spawns and zero heap allocations — dispatch rides the
    /// resident pool, encode/panel scratch rides the recycler.
    #[test]
    fn steady_state_into_kernels_spawn_and_allocate_nothing() {
        use crate::runtime::pool::counters;
        let mut rng = Rng::new(79);
        let dims = DyadDims { n_dyad: 4, n_in: 8, n_out: 8 };
        let (f_in, f_out) = (dims.f_in(), dims.f_out());
        let t = 16;
        let threads = 4;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let xc = rand_vec(&mut rng, f_in * t);
        let xr = rand_vec(&mut rng, t * f_in);
        let dyr = rand_vec(&mut rng, t * f_out);
        let wd = rand_vec(&mut rng, f_out * f_in);
        let bias = rand_vec(&mut rng, f_out);
        let mut y = vec![0.0f32; f_out * t];
        let mut yr = vec![0.0f32; t * f_out];
        let mut dx = vec![0.0f32; t * f_in];
        let mut dwl = vec![0.0f32; dims.component_params()];
        let mut dwu = vec![0.0f32; dims.component_params()];
        let mut dense_y = vec![0.0f32; t * f_out];
        let mut mm = vec![0.0f32; t * f_in];
        let mut warm = counters::snapshot();
        for rep in 0..8 {
            dyad_fused_prec_into(
                &wl, &wu, &xc, dims, Variant::ItCat, t, Some(&bias), Precision::I8, threads,
                &mut y,
            );
            dyad_linear_prec_into(
                &wl, &wu, &xr, dims, Variant::Dt, t, Some(&bias), Precision::Bf16, threads,
                &mut yr,
            );
            dyad_linear_backward_dx_prec_into(
                &wl, &wu, &dyr, dims, Variant::It, t, Precision::F32, threads, &mut dx,
            );
            dyad_backward_dw_into(&xr, &dyr, dims, Variant::ItCat, t, threads, &mut dwl, &mut dwu);
            dense_linear_prec_into(
                &xr, &wd, Some(&bias), t, f_in, f_out, Precision::I8, threads, &mut dense_y,
            );
            matmul_fast_prec_into(&dyr, &wd, t, f_out, f_in, Precision::Bf16, threads, &mut mm);
            if rep == 1 {
                warm = counters::snapshot();
            }
        }
        let steady = counters::snapshot().since(&warm);
        assert_eq!(steady.spawns, 0, "steady state must not spawn threads: {steady:?}");
        assert_eq!(steady.kernel_allocs, 0, "steady state must not allocate: {steady:?}");
        assert!(steady.pool_runs > 0, "work must ride the resident pool: {steady:?}");
        assert!(steady.arena_hits > 0, "scratch must come from the recycler: {steady:?}");
    }

    #[test]
    fn dyad_linear_row_major_matches_dense() {
        let mut rng = Rng::new(13);
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 4 };
        let t = 5;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let bias = rand_vec(&mut rng, dims.f_out());
        let got = dyad_linear(&wl, &wu, &x, dims, Variant::It, t, Some(&bias));
        // reference: materialise W, y = x @ W^T + b, row-major
        let full = dyad_full(&wl, &wu, dims, Variant::It);
        let xc = transpose(&x, t, dims.f_in());
        let want_c = dense_matmul(&full, &xc, dims.f_out(), dims.f_in(), t, Some(&bias));
        let want = transpose(&want_c, dims.f_out(), t);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

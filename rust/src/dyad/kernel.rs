//! Fast native DYAD + dense kernels: cache-blocked, multi-threaded.
//!
//! This is the hot path of the native CPU backend. Unlike the oracles
//! in [`super::math`] (kept simple for property tests), these kernels:
//!
//! * split work across row panels with `std::thread::scope`, one panel
//!   per thread, so no synchronisation is needed inside a call;
//! * block the dense matmul over the inner dimension so the B panel
//!   stays cache-resident while a row panel streams through it;
//! * run the fused DYAD forward (paper Eqs 3-10) *row-wise*: each
//!   output row accumulates its BLOCKDIAG and BLOCKTRANS contributions
//!   in one pass ([`axpy2`]) — permuted rows are written in place,
//!   with no per-block `x2` gather allocation and no temporary `y_i`
//!   buffer;
//! * run the DYAD *backward* the same way: [`dyad_backward_dx`] is the
//!   mirror of the forward schedule over `W^T` (input rows own their
//!   accumulation) and [`dyad_backward_dw`] accumulates each `dwl`/
//!   `dwu` block row directly from the activation/gradient streams —
//!   no `(f_out, f_in)` materialisation anywhere in training.
//!
//! Every output row is produced by exactly one thread in a fixed
//! sequential accumulation order, so results are bitwise identical for
//! any thread count (asserted by the determinism property tests).

use std::sync::OnceLock;

use super::layout::{DyadDims, Variant};

/// Worker count: `DYAD_NUM_THREADS` env override, else the machine's
/// available parallelism, else 1.
///
/// Resolved once per process and cached in a [`OnceLock`] — kernels
/// call this on every dispatch, and re-reading the environment is a
/// syscall in the hot path. Tests that need a specific count use the
/// `*_with_threads` escape hatches instead of mutating the env.
pub fn num_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("DYAD_NUM_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// `out[j] += a * x[j]` over one row, 8-wide unrolled so the
/// autovectoriser emits full-width lanes.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len(), "axpy: length mismatch");
    let n = out.len().min(x.len());
    let mut oc = out[..n].chunks_exact_mut(8);
    let mut xc = x[..n].chunks_exact(8);
    for (o8, x8) in (&mut oc).zip(&mut xc) {
        for i in 0..8 {
            o8[i] += a * x8[i];
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * v;
    }
}

/// Fused dual-source update `out[j] += a * x[j] + b * z[j]`: one pass
/// over the output row for both DYAD components, so the store stream
/// (and the loop overhead) is paid once instead of twice.
#[inline]
pub fn axpy2(out: &mut [f32], a: f32, x: &[f32], b: f32, z: &[f32]) {
    debug_assert_eq!(out.len(), x.len(), "axpy2: x length mismatch");
    debug_assert_eq!(out.len(), z.len(), "axpy2: z length mismatch");
    let n = out.len().min(x.len()).min(z.len());
    let mut oc = out[..n].chunks_exact_mut(8);
    let mut xc = x[..n].chunks_exact(8);
    let mut zc = z[..n].chunks_exact(8);
    for ((o8, x8), z8) in (&mut oc).zip(&mut xc).zip(&mut zc) {
        for i in 0..8 {
            o8[i] += a * x8[i] + b * z8[i];
        }
    }
    for ((o, &xv), &zv) in oc
        .into_remainder()
        .iter_mut()
        .zip(xc.remainder())
        .zip(zc.remainder())
    {
        *o += a * xv + b * zv;
    }
}

/// Dot product with 8 independent accumulators (full-width ILP on long
/// rows). The operands must be the same length — a mismatch is a shape
/// bug upstream and fails loudly in debug builds instead of silently
/// truncating to the shorter slice.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(
        a.len(),
        b.len(),
        "dot: length mismatch {} vs {}",
        a.len(),
        b.len()
    );
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; 8];
    let mut ac = a[..n].chunks_exact(8);
    let mut bc = b[..n].chunks_exact(8);
    for (a8, b8) in (&mut ac).zip(&mut bc) {
        for i in 0..8 {
            acc[i] += a8[i] * b8[i];
        }
    }
    let mut s =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        s += x * y;
    }
    s
}

/// Run `f(row_index, row_slice)` for every `row_len`-sized row of
/// `out`, split across `threads` row panels. Rows are disjoint, so the
/// closure runs without any locking; each row sees a fixed sequential
/// execution, keeping results independent of the thread count.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, threads: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_len;
    let threads = threads.clamp(1, n_rows.max(1));
    if threads <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row);
        }
        return;
    }
    let rows_per = n_rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * row_len).enumerate() {
            let start = t * rows_per;
            s.spawn(move || {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    f(start + i, row);
                }
            });
        }
    });
}

/// Row-major `(m, k) x (k, n) -> (m, n)`, parallel over row panels and
/// blocked over `k` so each B panel is reused across a whole row panel.
pub fn matmul_fast(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_fast_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_fast_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let threads = threads.clamp(1, m);
    // B panel of KB rows: KB * n * 4 bytes; 64 rows of a 4096-wide B is
    // 1 MB — L2-resident on anything we target.
    const KB: usize = 64;
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let i0 = t * rows_per;
            s.spawn(move || {
                let rows = chunk.len() / n;
                let mut p0 = 0;
                while p0 < k {
                    let p1 = (p0 + KB).min(k);
                    for i in 0..rows {
                        let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                        let orow = &mut chunk[i * n..(i + 1) * n];
                        for (p, &av) in arow.iter().enumerate().take(p1).skip(p0) {
                            if av != 0.0 {
                                axpy(orow, av, &b[p * n..(p + 1) * n]);
                            }
                        }
                    }
                    p0 = p1;
                }
            });
        }
    });
    out
}

/// `a (m, k) @ b^T` where `b` is `(n, k)` row-major — the natural form
/// for `y = x @ W^T` linears. Both operands stream contiguously.
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_bt_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_bt_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    parallel_rows(&mut out, n, threads, &|i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * k..(j + 1) * k]);
        }
    });
    out
}

/// Transpose a row-major `(m, n)` matrix into `(n, m)`.
pub fn transpose(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    transpose_into(a, m, n, &mut out);
    out
}

/// Transpose a row-major `(m, n)` matrix into a caller-owned `(n, m)`
/// buffer (the backward pass transposes weight blocks in place into
/// one scratch allocation instead of one `Vec` per block).
pub fn transpose_into(a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), m * n);
    // simple tiled transpose; tiles keep both sides cache-friendly
    const T: usize = 32;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + T).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + T).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    out[j * m + i] = a[i * n + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Dense linear on row-major activations: `x (t, f_in) @ w^T + b`
/// with `w (f_out, f_in)` — returns `(t, f_out)`.
pub fn dense_linear(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
) -> Vec<f32> {
    dense_linear_with_threads(x, w, bias, t, f_in, f_out, num_threads())
}

#[allow(clippy::too_many_arguments)]
pub fn dense_linear_with_threads(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
    threads: usize,
) -> Vec<f32> {
    let mut y = matmul_bt_with_threads(x, w, t, f_in, f_out, threads);
    if let Some(b) = bias {
        for row in y.chunks_mut(f_out) {
            for (o, &bv) in row.iter_mut().zip(b) {
                *o += bv;
            }
        }
    }
    y
}

/// Fused DYAD forward (paper Eqs 3-10) on column-major activations:
/// `x (f_in, nb)` -> `y (f_out, nb)`, `y = (W1 + W2) x (+ bias)`.
///
/// Row-wise schedule: output row `r` receives its BLOCKDIAG
/// contribution from block `r / n_out` and its BLOCKTRANS contribution
/// from the block the output permutation maps it to — so permuted rows
/// are written in place and no `x2` gather or `y_i` temporary exists.
/// Matches `dyad::math::dyad_matmul` (the oracle) bit-for-bit in
/// structure, to float-accumulation-order tolerance in value.
pub fn dyad_fused(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_fused_with_threads(wl, wu, x, dims, variant, nb, bias, num_threads())
}

#[allow(clippy::too_many_arguments)]
pub fn dyad_fused_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(wl.len(), dims.component_params());
    assert_eq!(wu.len(), dims.component_params());
    assert_eq!(x.len(), dims.f_in() * nb);
    if let Some(b) = bias {
        assert_eq!(b.len(), dims.f_out());
    }
    let in_perm = matches!(variant, Variant::It | Variant::Dt);
    let out_perm = matches!(variant, Variant::Ot | Variant::Dt);
    let mut y = vec![0.0f32; dims.f_out() * nb];
    parallel_rows(&mut y, nb, threads, &|r, orow| {
        if let Some(b) = bias {
            orow.fill(b[r]);
        }
        // BLOCKDIAG: row r lives in block i1 = r / n_out. BLOCKTRANS:
        // with the output permutation, row r = o2*n_dyad + i2 (the
        // Eq-9 stride swap); without it, same indexing as BLOCKDIAG.
        // Both components contribute exactly n_in terms per output
        // row, so the two passes fuse into one axpy2 sweep.
        let (i1, o1) = (r / n_out, r % n_out);
        let (i2, o2) = if out_perm {
            (r % n_dyad, r / n_dyad)
        } else {
            (i1, o1)
        };
        let w1 = &wl[(i1 * n_out + o1) * n_in..(i1 * n_out + o1 + 1) * n_in];
        let w2 = &wu[(i2 * n_out + o2) * n_in..(i2 * n_out + o2 + 1) * n_in];
        let base = i1 * n_in;
        if nb == 1 {
            let mut s = dot(w1, &x[base..base + n_in]);
            if in_perm {
                for (k, &wv) in w2.iter().enumerate() {
                    s += wv * x[k * n_dyad + i2];
                }
            } else {
                s += dot(w2, &x[i2 * n_in..(i2 + 1) * n_in]);
            }
            orow[0] += s;
        } else {
            for k in 0..n_in {
                let src1 = base + k;
                let src2 = if in_perm { k * n_dyad + i2 } else { i2 * n_in + k };
                axpy2(
                    orow,
                    w1[k],
                    &x[src1 * nb..(src1 + 1) * nb],
                    w2[k],
                    &x[src2 * nb..(src2 + 1) * nb],
                );
            }
        }
    });
    y
}

/// DYAD linear on row-major activations (`x (t, f_in)` -> `(t, f_out)`),
/// transposing in and out around the column-major fused kernel — the
/// same one-transpose-in / one-transpose-out scheme the L2 model uses.
#[allow(clippy::too_many_arguments)]
pub fn dyad_linear(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_linear_with_threads(wl, wu, x, dims, variant, t, bias, num_threads())
}

#[allow(clippy::too_many_arguments)]
pub fn dyad_linear_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let xc = transpose(x, t, dims.f_in());
    let yc = dyad_fused_with_threads(wl, wu, &xc, dims, variant, t, bias, threads);
    transpose(&yc, dims.f_out(), t)
}

/// Transpose each `(n_out, n_in)` block of a component tensor into
/// `(n_in, n_out)`. The backward `dx` pass streams weights along the
/// output-feature axis, which is stride-`n_in` in the stored layout —
/// one O(component_params) block transpose (2/n_dyad of dense, reused
/// across every activation column and input row) turns that into a
/// contiguous read. The *activations* are never gathered or copied.
fn transpose_blocks(w: &[f32], dims: DyadDims) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(w.len(), dims.component_params());
    let mut out = vec![0.0f32; w.len()];
    let blk = n_out * n_in;
    for i in 0..n_dyad {
        let src = &w[i * blk..(i + 1) * blk];
        transpose_into(src, n_out, n_in, &mut out[i * blk..(i + 1) * blk]);
    }
    out
}

/// Structured DYAD backward, input-gradient half (paper training path):
/// `dx = W^T dy = (W1 + W2)^T dy` on column-major gradients
/// `dy (f_out, nb)` -> `dx (f_in, nb)`, without materialising `W`.
///
/// Mirror of [`dyad_fused`]: each *input* row owns its accumulation.
/// Input row c takes its BLOCKDIAG^T terms from block `c / n_in` and
/// its BLOCKTRANS^T terms from the block the *input* permutation maps
/// it to (`c = k2*n_dyad + i2`, the same Eq-9 stride swap the forward
/// applies on the output side) — so permuted rows are read/written in
/// place, with no gather buffers and no `dyad_full` call. Both
/// components contribute n_out terms per row; the sweeps fuse via
/// [`axpy2`]. Bitwise deterministic across thread counts.
pub fn dyad_backward_dx(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
) -> Vec<f32> {
    dyad_backward_dx_with_threads(wl, wu, dy, dims, variant, nb, num_threads())
}

pub fn dyad_backward_dx_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    threads: usize,
) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(wl.len(), dims.component_params());
    assert_eq!(wu.len(), dims.component_params());
    assert_eq!(dy.len(), dims.f_out() * nb);
    let in_perm = matches!(variant, Variant::It | Variant::Dt);
    let out_perm = matches!(variant, Variant::Ot | Variant::Dt);
    let wlt = transpose_blocks(wl, dims);
    let wut = transpose_blocks(wu, dims);
    let mut dx = vec![0.0f32; dims.f_in() * nb];
    parallel_rows(&mut dx, nb, threads, &|c, orow| {
        // BLOCKDIAG^T: input row c lives in block i1 = c / n_in.
        let (i1, k1) = (c / n_in, c % n_in);
        let w1 = &wlt[(i1 * n_in + k1) * n_out..(i1 * n_in + k1 + 1) * n_out];
        // BLOCKTRANS^T: with the input permutation, c = k2*n_dyad + i2.
        let (i2, k2) = if in_perm {
            (c % n_dyad, c / n_dyad)
        } else {
            (i1, k1)
        };
        let w2 = &wut[(i2 * n_in + k2) * n_out..(i2 * n_in + k2 + 1) * n_out];
        if nb == 1 {
            let mut s = dot(w1, &dy[i1 * n_out..(i1 + 1) * n_out]);
            if out_perm {
                for (o, &wv) in w2.iter().enumerate() {
                    s += wv * dy[o * n_dyad + i2];
                }
            } else {
                s += dot(w2, &dy[i2 * n_out..(i2 + 1) * n_out]);
            }
            orow[0] = s;
        } else {
            for o in 0..n_out {
                let src1 = i1 * n_out + o;
                let src2 = if out_perm { o * n_dyad + i2 } else { i2 * n_out + o };
                axpy2(
                    orow,
                    w1[o],
                    &dy[src1 * nb..(src1 + 1) * nb],
                    w2[o],
                    &dy[src2 * nb..(src2 + 1) * nb],
                );
            }
        }
    });
    dx
}

/// Row-major wrapper for [`dyad_backward_dx`]: `dy (t, f_out)` ->
/// `dx (t, f_in)`, one transpose in / one transpose out, matching
/// [`dyad_linear`]'s scheme for the forward.
pub fn dyad_linear_backward_dx(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
) -> Vec<f32> {
    dyad_linear_backward_dx_with_threads(wl, wu, dy, dims, variant, t, num_threads())
}

#[allow(clippy::too_many_arguments)]
pub fn dyad_linear_backward_dx_with_threads(
    wl: &[f32],
    wu: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    threads: usize,
) -> Vec<f32> {
    let dyc = transpose(dy, t, dims.f_out());
    let dxc = dyad_backward_dx_with_threads(wl, wu, &dyc, dims, variant, t, threads);
    transpose(&dxc, dims.f_in(), t)
}

/// Structured DYAD backward, weight-gradient half: accumulate the
/// block component gradients directly from row-major activations
/// `x (t, f_in)` and upstream gradients `dy (t, f_out)`:
///
/// * `dwl[i] = dy_blk_i^T @ x_blk_i` — block i of `dy` is columns
///   `[i*n_out, (i+1)*n_out)`, block i of `x` is columns
///   `[i*n_in, (i+1)*n_in)`;
/// * `dwu[i, o, k] = sum_t dy[t, pi_out(i,o)] * x[t, pi_in(i,k)]` —
///   the same entry of the full `dW` the old materialise-and-project
///   path read, computed without ever forming `dW`.
///
/// O(2 * t * total_params) work — the dense `dy^T @ x` costs n_dyad/2
/// times more. Each `dwl`/`dwu` row is owned by one thread and
/// accumulated in fixed `t` order: bitwise deterministic.
pub fn dyad_backward_dw(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
) -> (Vec<f32>, Vec<f32>) {
    dyad_backward_dw_with_threads(x, dy, dims, variant, t, num_threads())
}

pub fn dyad_backward_dw_with_threads(
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    assert_eq!(x.len(), t * f_in);
    assert_eq!(dy.len(), t * f_out);
    let in_perm = matches!(variant, Variant::It | Variant::Dt);
    let out_perm = matches!(variant, Variant::Ot | Variant::Dt);
    let mut dwl = vec![0.0f32; dims.component_params()];
    parallel_rows(&mut dwl, n_in, threads, &|r, row| {
        let (i, o) = (r / n_out, r % n_out);
        for ti in 0..t {
            let a = dy[ti * f_out + i * n_out + o];
            if a != 0.0 {
                axpy(row, a, &x[ti * f_in + i * n_in..ti * f_in + (i + 1) * n_in]);
            }
        }
    });
    let mut dwu = vec![0.0f32; dims.component_params()];
    parallel_rows(&mut dwu, n_in, threads, &|r, row| {
        let (i, o) = (r / n_out, r % n_out);
        // pi_out(i, o) = o*n_dyad + i; pi_in(i, k) = k*n_dyad + i.
        let rp = if out_perm { o * n_dyad + i } else { i * n_out + o };
        for ti in 0..t {
            let a = dy[ti * f_out + rp];
            if a == 0.0 {
                continue;
            }
            let xt = &x[ti * f_in..(ti + 1) * f_in];
            if in_perm {
                for (k, rv) in row.iter_mut().enumerate() {
                    *rv += a * xt[k * n_dyad + i];
                }
            } else {
                axpy(row, a, &xt[i * n_in..(i + 1) * n_in]);
            }
        }
    });
    (dwl, dwu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyad::layout::dyad_full;
    use crate::dyad::math::{dense_matmul, dyad_matmul, matmul};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_fast_matches_oracle() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 32)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let want = matmul(&a, &b, m, k, n);
            for threads in [1, 4] {
                let got = matmul_fast_with_threads(&a, &b, m, k, n, threads);
                for (x, y) in got.iter().zip(&want) {
                    assert!((x - y).abs() < 1e-4, "{m}x{k}x{n} t{threads}");
                }
            }
        }
    }

    #[test]
    fn matmul_bt_matches_transposed_oracle() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (9, 31, 13);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, n * k);
        let bt = transpose(&b, n, k); // (k, n)
        let want = matmul(&a, &bt, m, k, n);
        let got = matmul_bt(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(5);
        let (m, n) = (37, 53);
        let a = rand_vec(&mut rng, m * n);
        assert_eq!(transpose(&transpose(&a, m, n), n, m), a);
    }

    #[test]
    fn fused_matches_oracle_all_variants() {
        let mut rng = Rng::new(7);
        for (nd, n_in, n_out, nb) in [(4, 4, 4, 3), (2, 3, 5, 4), (8, 2, 2, 1), (1, 6, 2, 5)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            for v in [Variant::It, Variant::Ot, Variant::Dt] {
                let want = dyad_matmul(&wl, &wu, &x, dims, v, nb, Some(&bias));
                let got = dyad_fused(&wl, &wu, &x, dims, v, nb, Some(&bias));
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{v:?} {dims:?}");
                }
            }
        }
    }

    #[test]
    fn fused_thread_count_is_bitwise_deterministic() {
        let mut rng = Rng::new(11);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let nb = 17;
        let x = rand_vec(&mut rng, dims.f_in() * nb);
        let one = dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, 1);
        for threads in [2, 3, 8] {
            let many =
                dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, threads);
            assert_eq!(one, many, "threads={threads} changed bits");
        }
    }

    #[test]
    fn dot_and_axpy2_remainders() {
        // exercise the 8-wide chunks + remainder tails at awkward lengths
        for n in [0usize, 1, 7, 8, 9, 16, 19] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 2.0 - i as f32 * 0.25).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "dot n={n}");
            let mut out = vec![1.0f32; n];
            axpy2(&mut out, 0.5, &a, -2.0, &b);
            for (i, o) in out.iter().enumerate() {
                let want = 1.0 + 0.5 * a[i] - 2.0 * b[i];
                assert!((o - want).abs() < 1e-5, "axpy2 n={n} i={i}");
            }
        }
    }

    /// Both structured backward kernels against the materialise-and-
    /// project oracle: all variants, rectangular blocks, the
    /// `n_dyad == 1` (single dense block) and `n_dyad == f_out`
    /// (1-row output blocks) edges, and `t == 1`.
    #[test]
    fn structured_backward_matches_reference() {
        use crate::dyad::math::dyad_backward;
        let mut rng = Rng::new(29);
        for (nd, n_in, n_out, t) in [
            (4, 4, 4, 3),
            (2, 3, 5, 4), // rectangular blocks
            (1, 6, 2, 5), // n_dyad == 1
            (4, 3, 1, 3), // n_dyad == f_out
            (8, 2, 2, 1), // t == 1 (serving-shaped)
        ] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let dy = rand_vec(&mut rng, t * dims.f_out());
            for v in [Variant::It, Variant::Ot, Variant::Dt] {
                let (rwl, rwu, rdx) = dyad_backward(&wl, &wu, &x, &dy, dims, v, t);
                let (dwl, dwu) = dyad_backward_dw(&x, &dy, dims, v, t);
                let dx = dyad_linear_backward_dx(&wl, &wu, &dy, dims, v, t);
                for (name, got, want) in
                    [("dwl", &dwl, &rwl), ("dwu", &dwu, &rwu), ("dx", &dx, &rdx)]
                {
                    for (i, (a, b)) in got.iter().zip(want).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{v:?} {dims:?} t={t} {name}[{i}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn backward_kernels_thread_count_bitwise_deterministic() {
        let mut rng = Rng::new(31);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let t = 17;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let dyc = rand_vec(&mut rng, dims.f_out() * t); // column-major (f_out, t)
        let dyr = transpose(&dyc, dims.f_out(), t); // row-major (t, f_out)
        for v in [Variant::It, Variant::Ot, Variant::Dt] {
            let dx1 = dyad_backward_dx_with_threads(&wl, &wu, &dyc, dims, v, t, 1);
            let dw1 = dyad_backward_dw_with_threads(&x, &dyr, dims, v, t, 1);
            for threads in [2, 3, 8] {
                let dxn = dyad_backward_dx_with_threads(&wl, &wu, &dyc, dims, v, t, threads);
                assert_eq!(dx1, dxn, "{v:?} dx threads={threads} changed bits");
                let dwn = dyad_backward_dw_with_threads(&x, &dyr, dims, v, t, threads);
                assert_eq!(dw1, dwn, "{v:?} dw threads={threads} changed bits");
            }
        }
    }

    #[test]
    fn dyad_linear_row_major_matches_dense() {
        let mut rng = Rng::new(13);
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 4 };
        let t = 5;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let bias = rand_vec(&mut rng, dims.f_out());
        let got = dyad_linear(&wl, &wu, &x, dims, Variant::It, t, Some(&bias));
        // reference: materialise W, y = x @ W^T + b, row-major
        let full = dyad_full(&wl, &wu, dims, Variant::It);
        let xc = transpose(&x, t, dims.f_in());
        let want_c = dense_matmul(&full, &xc, dims.f_out(), dims.f_in(), t, Some(&bias));
        let want = transpose(&want_c, dims.f_out(), t);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

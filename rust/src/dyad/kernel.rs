//! Fast native DYAD + dense kernels: cache-blocked, multi-threaded.
//!
//! This is the hot path of the native CPU backend. Unlike the oracles
//! in [`super::math`] (kept simple for property tests), these kernels:
//!
//! * split work across row panels with `std::thread::scope`, one panel
//!   per thread, so no synchronisation is needed inside a call;
//! * block the dense matmul over the inner dimension so the B panel
//!   stays cache-resident while a row panel streams through it;
//! * run the fused DYAD forward (paper Eqs 3-10) *row-wise*: each
//!   output row accumulates its BLOCKDIAG and BLOCKTRANS contributions
//!   directly — permuted rows are written in place, with no per-block
//!   `x2` gather allocation and no temporary `y_i` buffer.
//!
//! Every output row is produced by exactly one thread in a fixed
//! sequential accumulation order, so results are bitwise identical for
//! any thread count (asserted by the determinism property test).

use super::layout::{DyadDims, Variant};

/// Worker count: `DYAD_NUM_THREADS` env override, else the machine's
/// available parallelism, else 1.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("DYAD_NUM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// `out[j] += a * x[j]` over one row.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

/// Dot product with 4-way accumulators (helps ILP on long rows).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Run `f(row_index, row_slice)` for every `row_len`-sized row of
/// `out`, split across `threads` row panels. Rows are disjoint, so the
/// closure runs without any locking; each row sees a fixed sequential
/// execution, keeping results independent of the thread count.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, threads: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_len;
    let threads = threads.clamp(1, n_rows.max(1));
    if threads <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row);
        }
        return;
    }
    let rows_per = n_rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * row_len).enumerate() {
            let start = t * rows_per;
            s.spawn(move || {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    f(start + i, row);
                }
            });
        }
    });
}

/// Row-major `(m, k) x (k, n) -> (m, n)`, parallel over row panels and
/// blocked over `k` so each B panel is reused across a whole row panel.
pub fn matmul_fast(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_fast_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_fast_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let threads = threads.clamp(1, m);
    // B panel of KB rows: KB * n * 4 bytes; 64 rows of a 4096-wide B is
    // 1 MB — L2-resident on anything we target.
    const KB: usize = 64;
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let i0 = t * rows_per;
            s.spawn(move || {
                let rows = chunk.len() / n;
                let mut p0 = 0;
                while p0 < k {
                    let p1 = (p0 + KB).min(k);
                    for i in 0..rows {
                        let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
                        let orow = &mut chunk[i * n..(i + 1) * n];
                        for (p, &av) in arow.iter().enumerate().take(p1).skip(p0) {
                            if av != 0.0 {
                                axpy(orow, av, &b[p * n..(p + 1) * n]);
                            }
                        }
                    }
                    p0 = p1;
                }
            });
        }
    });
    out
}

/// `a (m, k) @ b^T` where `b` is `(n, k)` row-major — the natural form
/// for `y = x @ W^T` linears. Both operands stream contiguously.
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_bt_with_threads(a, b, m, k, n, num_threads())
}

pub fn matmul_bt_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    parallel_rows(&mut out, n, threads, &|i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * k..(j + 1) * k]);
        }
    });
    out
}

/// Transpose a row-major `(m, n)` matrix into `(n, m)`.
pub fn transpose(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    let mut out = vec![0.0f32; m * n];
    // simple tiled transpose; tiles keep both sides cache-friendly
    const T: usize = 32;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + T).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + T).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    out[j * m + i] = a[i * n + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
    out
}

/// Dense linear on row-major activations: `x (t, f_in) @ w^T + b`
/// with `w (f_out, f_in)` — returns `(t, f_out)`.
pub fn dense_linear(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    t: usize,
    f_in: usize,
    f_out: usize,
) -> Vec<f32> {
    let mut y = matmul_bt(x, w, t, f_in, f_out);
    if let Some(b) = bias {
        for row in y.chunks_mut(f_out) {
            for (o, &bv) in row.iter_mut().zip(b) {
                *o += bv;
            }
        }
    }
    y
}

/// Fused DYAD forward (paper Eqs 3-10) on column-major activations:
/// `x (f_in, nb)` -> `y (f_out, nb)`, `y = (W1 + W2) x (+ bias)`.
///
/// Row-wise schedule: output row `r` receives its BLOCKDIAG
/// contribution from block `r / n_out` and its BLOCKTRANS contribution
/// from the block the output permutation maps it to — so permuted rows
/// are written in place and no `x2` gather or `y_i` temporary exists.
/// Matches `dyad::math::dyad_matmul` (the oracle) bit-for-bit in
/// structure, to float-accumulation-order tolerance in value.
pub fn dyad_fused(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dyad_fused_with_threads(wl, wu, x, dims, variant, nb, bias, num_threads())
}

#[allow(clippy::too_many_arguments)]
pub fn dyad_fused_with_threads(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(wl.len(), dims.component_params());
    assert_eq!(wu.len(), dims.component_params());
    assert_eq!(x.len(), dims.f_in() * nb);
    if let Some(b) = bias {
        assert_eq!(b.len(), dims.f_out());
    }
    let in_perm = matches!(variant, Variant::It | Variant::Dt);
    let out_perm = matches!(variant, Variant::Ot | Variant::Dt);
    let mut y = vec![0.0f32; dims.f_out() * nb];
    parallel_rows(&mut y, nb, threads, &|r, orow| {
        if let Some(b) = bias {
            orow.fill(b[r]);
        }
        // BLOCKDIAG: row r lives in block i1 = r / n_out.
        let (i1, o1) = (r / n_out, r % n_out);
        let wrow = &wl[(i1 * n_out + o1) * n_in..(i1 * n_out + o1 + 1) * n_in];
        let base = i1 * n_in;
        if nb == 1 {
            orow[0] += dot(wrow, &x[base..base + n_in]);
        } else {
            for (k, &wv) in wrow.iter().enumerate() {
                if wv != 0.0 {
                    axpy(orow, wv, &x[(base + k) * nb..(base + k + 1) * nb]);
                }
            }
        }
        // BLOCKTRANS: with the output permutation, row r = o2*n_dyad + i2
        // (the Eq-9 stride swap); without it, same indexing as BLOCKDIAG.
        let (i2, o2) = if out_perm {
            (r % n_dyad, r / n_dyad)
        } else {
            (r / n_out, r % n_out)
        };
        let wrow = &wu[(i2 * n_out + o2) * n_in..(i2 * n_out + o2 + 1) * n_in];
        for (k, &wv) in wrow.iter().enumerate() {
            if wv == 0.0 {
                continue;
            }
            let src = if in_perm { k * n_dyad + i2 } else { i2 * n_in + k };
            if nb == 1 {
                orow[0] += wv * x[src];
            } else {
                axpy(orow, wv, &x[src * nb..(src + 1) * nb]);
            }
        }
    });
    y
}

/// DYAD linear on row-major activations (`x (t, f_in)` -> `(t, f_out)`),
/// transposing in and out around the column-major fused kernel — the
/// same one-transpose-in / one-transpose-out scheme the L2 model uses.
#[allow(clippy::too_many_arguments)]
pub fn dyad_linear(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let xc = transpose(x, t, dims.f_in());
    let yc = dyad_fused(wl, wu, &xc, dims, variant, t, bias);
    transpose(&yc, dims.f_out(), t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyad::layout::dyad_full;
    use crate::dyad::math::{dense_matmul, dyad_matmul, matmul};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_fast_matches_oracle() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 32)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let want = matmul(&a, &b, m, k, n);
            for threads in [1, 4] {
                let got = matmul_fast_with_threads(&a, &b, m, k, n, threads);
                for (x, y) in got.iter().zip(&want) {
                    assert!((x - y).abs() < 1e-4, "{m}x{k}x{n} t{threads}");
                }
            }
        }
    }

    #[test]
    fn matmul_bt_matches_transposed_oracle() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (9, 31, 13);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, n * k);
        let bt = transpose(&b, n, k); // (k, n)
        let want = matmul(&a, &bt, m, k, n);
        let got = matmul_bt(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(5);
        let (m, n) = (37, 53);
        let a = rand_vec(&mut rng, m * n);
        assert_eq!(transpose(&transpose(&a, m, n), n, m), a);
    }

    #[test]
    fn fused_matches_oracle_all_variants() {
        let mut rng = Rng::new(7);
        for (nd, n_in, n_out, nb) in [(4, 4, 4, 3), (2, 3, 5, 4), (8, 2, 2, 1), (1, 6, 2, 5)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            for v in [Variant::It, Variant::Ot, Variant::Dt] {
                let want = dyad_matmul(&wl, &wu, &x, dims, v, nb, Some(&bias));
                let got = dyad_fused(&wl, &wu, &x, dims, v, nb, Some(&bias));
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "{v:?} {dims:?}");
                }
            }
        }
    }

    #[test]
    fn fused_thread_count_is_bitwise_deterministic() {
        let mut rng = Rng::new(11);
        let dims = DyadDims { n_dyad: 4, n_in: 12, n_out: 20 };
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let nb = 17;
        let x = rand_vec(&mut rng, dims.f_in() * nb);
        let one = dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, 1);
        for threads in [2, 3, 8] {
            let many =
                dyad_fused_with_threads(&wl, &wu, &x, dims, Variant::Dt, nb, None, threads);
            assert_eq!(one, many, "threads={threads} changed bits");
        }
    }

    #[test]
    fn dyad_linear_row_major_matches_dense() {
        let mut rng = Rng::new(13);
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 4 };
        let t = 5;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let bias = rand_vec(&mut rng, dims.f_out());
        let got = dyad_linear(&wl, &wu, &x, dims, Variant::It, t, Some(&bias));
        // reference: materialise W, y = x @ W^T + b, row-major
        let full = dyad_full(&wl, &wu, dims, Variant::It);
        let xc = transpose(&x, t, dims.f_in());
        let want_c = dense_matmul(&full, &xc, dims.f_out(), dims.f_in(), t, Some(&bias));
        let want = transpose(&want_c, dims.f_out(), t);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

//! Pure-rust DYAD mathematics.
//!
//! The coordinator-side ground truth for the paper's layer family:
//! permutation bookkeeping, materialisation of the near-sparse weight
//! matrix, the efficient block-diagonal schedule, parameter accounting
//! and the Eq 17/18 connectivity analysis. Used by property tests, the
//! memory tables (T11/F8) and `repro inspect`.

pub mod connectivity;
pub mod kernel;
pub mod layout;
pub mod math;
pub mod quant;

pub use connectivity::{connection_counts, connectivity_ratio};
pub use kernel::{
    dense_linear, dense_linear_prec, dyad_backward_dw, dyad_backward_dx, dyad_cat_backward_dw,
    dyad_cat_backward_dx, dyad_fused, dyad_fused_cat, dyad_fused_prec, dyad_linear,
    dyad_linear_backward_dx, dyad_linear_backward_dx_prec, dyad_linear_prec, matmul_bt,
    matmul_fast, transpose,
};
pub use layout::{blockdiag_full, blocktrans_full, dyad_full, perm_vector, DyadDims, Variant};
pub use math::{dense_matmul, dyad_backward, dyad_matmul, matmul, project_dyad_grads};
pub use quant::{bf16_from_f32, bf16_to_f32, dequantize_rows_i8, quantize_rows_i8};

//! CPU reference matmuls: dense and the efficient DYAD schedule.
//!
//! These are *oracles*, not the hot path (PJRT executables are). The
//! efficient form is the paper's Eqs 3-10 executed directly on host
//! slices, so property tests can assert
//! `dyad_matmul == dense_matmul(dyad_full(...))` for every variant.

use super::layout::{dyad_full, perm_vector, DyadDims, Variant};

/// Row-major (m, k) x (k, n) -> (m, n).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_acc(a, b, m, k, n, &mut out);
    out
}

/// Row-major (m, k) x (k, n) accumulated into `out (m, n)` — lets the
/// DYAD schedule add block products straight into the output.
pub fn matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Dense layer: Y = W X (+ b per column), column-major activations
/// X: (f_in, nb) stored row-major as f_in rows.
pub fn dense_matmul(
    w: &[f32],
    x: &[f32],
    f_out: usize,
    f_in: usize,
    nb: usize,
    b: Option<&[f32]>,
) -> Vec<f32> {
    let mut y = matmul(w, x, f_out, f_in, nb);
    if let Some(bias) = b {
        for r in 0..f_out {
            for c in 0..nb {
                y[r * nb + c] += bias[r];
            }
        }
    }
    y
}

/// Efficient DYAD forward (paper Eqs 3-10): per-block matmuls plus the
/// stride-swap permutation — O(n_dyad) fewer FLOPs than dense.
pub fn dyad_matmul(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dims: DyadDims,
    variant: Variant,
    nb: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let f_out = dims.f_out();
    assert_eq!(x.len(), dims.f_in() * nb);
    let mut y = vec![0.0f32; f_out * nb];

    // BLOCKDIAG: y[i*n_out + o] += wl[i] @ x[i*n_in + k], accumulated
    // directly into the output block (no per-block y_i temporary).
    for i in 0..n_dyad {
        let w_i = &wl[i * n_out * n_in..(i + 1) * n_out * n_in];
        let x_i = &x[i * n_in * nb..(i + 1) * n_in * nb];
        matmul_acc(w_i, x_i, n_out, n_in, nb, &mut y[i * n_out * nb..(i + 1) * n_out * nb]);
    }

    // BLOCKTRANS: gather the strided input view (IT/DT), per-block
    // matmul, scatter to strided output rows (OT/DT). One x2/z scratch
    // pair is reused across all blocks.
    let in_perm = variant.in_perm();
    let out_perm = variant.out_perm();
    let pi_in = perm_vector(n_in, n_dyad); // x2 row m reads x row pi_in[m]
    let pi_out = perm_vector(n_out, n_dyad);
    let mut x2 = vec![0.0f32; n_in * nb];
    let mut z = vec![0.0f32; n_out * nb];
    for i in 0..n_dyad {
        let w_i = &wu[i * n_out * n_in..(i + 1) * n_out * n_in];
        // assemble x2 block i: rows (i*n_in .. ) of the permuted view
        for k in 0..n_in {
            let src_row = if in_perm { pi_in[i * n_in + k] } else { i * n_in + k };
            x2[k * nb..(k + 1) * nb]
                .copy_from_slice(&x[src_row * nb..(src_row + 1) * nb]);
        }
        z.fill(0.0);
        matmul_acc(w_i, &x2, n_out, n_in, nb, &mut z);
        for o in 0..n_out {
            let dst_row = if out_perm { pi_out[i * n_out + o] } else { i * n_out + o };
            y[dst_row * nb..(dst_row + 1) * nb]
                .iter_mut()
                .zip(&z[o * nb..(o + 1) * nb])
                .for_each(|(a, b)| *a += b);
        }
    }

    if let Some(b) = bias {
        for r in 0..f_out {
            for c in 0..nb {
                y[r * nb + c] += b[r];
            }
        }
    }
    y
}

/// Read the block-structured component gradients out of a full
/// `(f_out, f_in)` `dW`: each `wl`/`wu` entry reads the cell its
/// layout places it in (permutations included). Exact for both
/// components, including where their supports overlap, because
/// `W = W1 + W2` is linear in each stored entry.
pub fn project_dyad_grads(dw: &[f32], dims: DyadDims, variant: Variant) -> (Vec<f32>, Vec<f32>) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let f_in = dims.f_in();
    assert_eq!(dw.len(), dims.f_out() * f_in);
    let in_perm = variant.in_perm();
    let out_perm = variant.out_perm();
    let pi_in = perm_vector(n_in, n_dyad);
    let pi_out = perm_vector(n_out, n_dyad);
    let mut dwl = vec![0.0f32; dims.component_params()];
    let mut dwu = vec![0.0f32; dims.component_params()];
    for i in 0..n_dyad {
        for o in 0..n_out {
            for k in 0..n_in {
                let idx = (i * n_out + o) * n_in + k;
                dwl[idx] = dw[(i * n_out + o) * f_in + (i * n_in + k)];
                let r = if out_perm { pi_out[i * n_out + o] } else { i * n_out + o };
                let c = if in_perm { pi_in[i * n_in + k] } else { i * n_in + k };
                dwu[idx] = dw[r * f_in + c];
            }
        }
    }
    (dwl, dwu)
}

/// Reference DYAD backward for `y = x @ W^T` on row-major activations
/// `x (t, f_in)` with upstream `dy (t, f_out)`: materialise `W`, run
/// the dense gradient matmuls, project `dW` onto the block structure.
///
/// This is the *oracle* — exactly the O(dense) path the runtime used
/// before the structured backward existed — kept so property tests can
/// assert `dyad_backward_dw/dx == materialise-and-project` for every
/// variant and shape. Returns `(dwl, dwu, dx)`.
pub fn dyad_backward(
    wl: &[f32],
    wu: &[f32],
    x: &[f32],
    dy: &[f32],
    dims: DyadDims,
    variant: Variant,
    t: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    assert_eq!(x.len(), t * f_in);
    assert_eq!(dy.len(), t * f_out);
    let full = dyad_full(wl, wu, dims, variant);
    // dW = dy^T @ x  (f_out, f_in)
    let mut dw = vec![0.0f32; f_out * f_in];
    for ti in 0..t {
        for r in 0..f_out {
            let a = dy[ti * f_out + r];
            if a == 0.0 {
                continue;
            }
            for c in 0..f_in {
                dw[r * f_in + c] += a * x[ti * f_in + c];
            }
        }
    }
    // dx = dy @ W  (t, f_in)
    let mut dx = vec![0.0f32; t * f_in];
    for ti in 0..t {
        for r in 0..f_out {
            let a = dy[ti * f_out + r];
            if a == 0.0 {
                continue;
            }
            for c in 0..f_in {
                dx[ti * f_in + c] += a * full[r * f_in + c];
            }
        }
    }
    let (dwl, dwu) = project_dyad_grads(&dw, dims, variant);
    (dwl, dwu, dx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_identity() {
        let i2 = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![3.0, 4.0, 5.0, 6.0];
        assert_eq!(matmul(&i2, &b, 2, 2, 2), b);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    /// Projecting each component's own materialisation recovers the
    /// stored entries exactly — the permutation bookkeeping in
    /// `project_dyad_grads` inverts the layout placement.
    #[test]
    fn projection_inverts_materialisation() {
        let mut rng = Rng::new(19);
        for (nd, n_in, n_out) in [(4, 3, 5), (1, 4, 2), (6, 2, 1)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let w3 = rand_vec(&mut rng, dims.component_params());
            for v in [Variant::It, Variant::Ot, Variant::Dt] {
                let bd = crate::dyad::layout::blockdiag_full(&w3, dims);
                let (dwl, _) = project_dyad_grads(&bd, dims, v);
                assert_eq!(dwl, w3, "{v:?} blockdiag");
                let bt = crate::dyad::layout::blocktrans_full(&w3, dims, v);
                let (_, dwu) = project_dyad_grads(&bt, dims, v);
                assert_eq!(dwu, w3, "{v:?} blocktrans");
            }
        }
    }

    #[test]
    fn dyad_matches_materialised_all_variants() {
        let mut rng = Rng::new(7);
        for (nd, n_in, n_out, nb) in [(4, 4, 4, 3), (2, 3, 5, 4), (8, 2, 2, 1)] {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let x = rand_vec(&mut rng, dims.f_in() * nb);
            let bias = rand_vec(&mut rng, dims.f_out());
            for v in [Variant::It, Variant::Ot, Variant::Dt] {
                let full = dyad_full(&wl, &wu, dims, v);
                let want =
                    dense_matmul(&full, &x, dims.f_out(), dims.f_in(), nb, Some(&bias));
                let got = dyad_matmul(&wl, &wu, &x, dims, v, nb, Some(&bias));
                for (a, b) in want.iter().zip(&got) {
                    assert!((a - b).abs() < 1e-4, "{v:?}: {a} vs {b}");
                }
            }
        }
    }
}

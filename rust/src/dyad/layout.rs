//! DYAD weight layout: 3-D block tensors, permutations, materialisation.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly — the rust and
//! python oracles are cross-checked through the AOT'd pallas artifact
//! in the integration tests.

use anyhow::{bail, Result};

/// Which component-2 permutation the layer uses (paper §2.2/§2.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Input Transpose: columns of BLOCKTRANS permuted.
    It,
    /// IT with the §3.4.3 -CAT execution schedule: algebraically
    /// identical to `It` (same weights, same output), but the kernel
    /// gathers the permuted input once into a block-grouped
    /// concatenated panel so both components stream contiguously.
    ItCat,
    /// Output Transpose: rows permuted.
    Ot,
    /// Double Transpose: both.
    Dt,
}

impl Variant {
    pub fn from_str(s: &str) -> Result<Variant> {
        Ok(match s {
            "it" => Variant::It,
            "it_cat" => Variant::ItCat,
            "ot" => Variant::Ot,
            "dt" => Variant::Dt,
            _ => bail!("unknown dyad variant {s:?}"),
        })
    }

    /// BLOCKTRANS reads a permuted view of the input (columns
    /// permuted): It / ItCat / Dt.
    pub fn in_perm(&self) -> bool {
        matches!(self, Variant::It | Variant::ItCat | Variant::Dt)
    }

    /// BLOCKTRANS writes a permuted view of the output (rows
    /// permuted): Ot / Dt.
    pub fn out_perm(&self) -> bool {
        matches!(self, Variant::Ot | Variant::Dt)
    }

    /// Uses the -CAT concatenated single-pass kernel schedule.
    pub fn is_cat(&self) -> bool {
        matches!(self, Variant::ItCat)
    }
}

/// Dimensions of a DYAD layer: f_in = n_dyad*n_in, f_out = n_dyad*n_out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DyadDims {
    pub n_dyad: usize,
    pub n_in: usize,
    pub n_out: usize,
}

impl DyadDims {
    pub fn new(n_dyad: usize, f_in: usize, f_out: usize) -> Result<DyadDims> {
        if n_dyad == 0 || f_in % n_dyad != 0 || f_out % n_dyad != 0 {
            bail!("f_in={f_in}, f_out={f_out} not divisible by n_dyad={n_dyad}");
        }
        Ok(DyadDims { n_dyad, n_in: f_in / n_dyad, n_out: f_out / n_dyad })
    }

    pub fn f_in(&self) -> usize {
        self.n_dyad * self.n_in
    }

    pub fn f_out(&self) -> usize {
        self.n_dyad * self.n_out
    }

    /// Weight elements stored by one component's 3-D tensor.
    pub fn component_params(&self) -> usize {
        self.n_dyad * self.n_out * self.n_in
    }

    /// Total DYAD weight params (2 components) vs dense f_out*f_in:
    /// a 2/n_dyad fraction (paper §2.2.1).
    pub fn total_params(&self) -> usize {
        2 * self.component_params()
    }

    /// FLOPs (mul-adds) for one forward matmul with n_batch columns.
    pub fn flops(&self, n_batch: usize) -> usize {
        2 * self.total_params() * n_batch
    }

    pub fn dense_flops(&self, n_batch: usize) -> usize {
        2 * self.f_in() * self.f_out() * n_batch
    }
}

/// Permutation pi over a dimension of size n_block*n_dyad: slot
/// m = i*n_block + k reads original index k*n_dyad + i (the paper's
/// Eq-9 stride-swap view). Identical to ref.py's `perm_vector`.
pub fn perm_vector(n_block: usize, n_dyad: usize) -> Vec<usize> {
    (0..n_block * n_dyad)
        .map(|m| {
            let (i, k) = (m / n_block, m % n_block);
            k * n_dyad + i
        })
        .collect()
}

/// Invert a permutation vector.
pub fn invert_perm(pi: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; pi.len()];
    for (m, &j) in pi.iter().enumerate() {
        inv[j] = m;
    }
    inv
}

/// Materialise the block-diagonal component: blocks w3[(i, o, k)] laid
/// on the diagonal of an (f_out, f_in) row-major matrix.
pub fn blockdiag_full(w3: &[f32], dims: DyadDims) -> Vec<f32> {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    assert_eq!(w3.len(), dims.component_params());
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    let mut full = vec![0.0f32; f_out * f_in];
    for i in 0..n_dyad {
        for o in 0..n_out {
            for k in 0..n_in {
                let r = i * n_out + o;
                let c = i * n_in + k;
                full[r * f_in + c] = w3[(i * n_out + o) * n_in + k];
            }
        }
    }
    full
}

/// Materialise the BLOCKTRANS component for the given variant
/// (BLOCKDIAG with rows/cols permuted; see ref.py for the algebra).
pub fn blocktrans_full(w3: &[f32], dims: DyadDims, variant: Variant) -> Vec<f32> {
    let bd = blockdiag_full(w3, dims);
    let (f_in, f_out) = (dims.f_in(), dims.f_out());
    match variant {
        Variant::It | Variant::ItCat => {
            // W2[:, pi[m]] = BD[:, m]
            let pi = perm_vector(dims.n_in, dims.n_dyad);
            let mut out = vec![0.0f32; f_out * f_in];
            for r in 0..f_out {
                for m in 0..f_in {
                    out[r * f_in + pi[m]] = bd[r * f_in + m];
                }
            }
            out
        }
        Variant::Ot => {
            // W2[pi[m], :] = BD[m, :]
            let pi = perm_vector(dims.n_out, dims.n_dyad);
            let mut out = vec![0.0f32; f_out * f_in];
            for m in 0..f_out {
                out[pi[m] * f_in..(pi[m] + 1) * f_in]
                    .copy_from_slice(&bd[m * f_in..(m + 1) * f_in]);
            }
            out
        }
        Variant::Dt => {
            let pi_c = perm_vector(dims.n_in, dims.n_dyad);
            let pi_r = perm_vector(dims.n_out, dims.n_dyad);
            let mut out = vec![0.0f32; f_out * f_in];
            for m in 0..f_out {
                for c in 0..f_in {
                    out[pi_r[m] * f_in + pi_c[c]] = bd[m * f_in + c];
                }
            }
            out
        }
    }
}

/// Materialise the full DYAD matrix W = W1 + W2 (paper Eq 1).
pub fn dyad_full(wl: &[f32], wu: &[f32], dims: DyadDims, variant: Variant) -> Vec<f32> {
    let w1 = blockdiag_full(wl, dims);
    let w2 = blocktrans_full(wu, dims, variant);
    w1.iter().zip(&w2).map(|(a, b)| a + b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_validation() {
        assert!(DyadDims::new(4, 16, 8).is_ok());
        assert!(DyadDims::new(3, 16, 8).is_err());
        assert!(DyadDims::new(0, 16, 8).is_err());
        let d = DyadDims::new(4, 768, 3072).unwrap();
        assert_eq!(d.n_in, 192);
        assert_eq!(d.n_out, 768);
        // total_params * n_dyad == 2 * dense params (paper §2.2.1)
        assert_eq!(d.total_params() * 4, 2 * 768 * 3072);
    }

    #[test]
    fn perm_is_permutation_and_involution_with_inverse() {
        for (nb, nd) in [(4, 4), (3, 5), (8, 2), (1, 6)] {
            let pi = perm_vector(nb, nd);
            let mut sorted = pi.clone();
            sorted.sort();
            assert_eq!(sorted, (0..nb * nd).collect::<Vec<_>>());
            let inv = invert_perm(&pi);
            for m in 0..pi.len() {
                assert_eq!(inv[pi[m]], m);
            }
            // the inverse is the mirrored stride-swap
            assert_eq!(inv, perm_vector(nd, nb));
        }
    }

    #[test]
    fn blockdiag_places_blocks() {
        let dims = DyadDims { n_dyad: 2, n_in: 2, n_out: 1 };
        // blocks: [[1,2]], [[3,4]]
        let w3 = vec![1.0, 2.0, 3.0, 4.0];
        let full = blockdiag_full(&w3, dims);
        // (f_out=2, f_in=4) row-major
        assert_eq!(full, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn blocktrans_permutes_not_destroys() {
        let dims = DyadDims { n_dyad: 4, n_in: 4, n_out: 4 };
        let w3: Vec<f32> = (0..dims.component_params()).map(|x| x as f32 + 1.0).collect();
        let bd = blockdiag_full(&w3, dims);
        for v in [Variant::It, Variant::Ot, Variant::Dt] {
            let bt = blocktrans_full(&w3, dims, v);
            let mut a = bd.clone();
            let mut b = bt.clone();
            a.sort_by(|x, y| x.partial_cmp(y).unwrap());
            b.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(a, b, "{v:?}");
            assert_ne!(bd, bt, "{v:?} must move entries");
        }
    }

    #[test]
    fn it_cat_is_it_algebra() {
        // -CAT is an execution schedule, not a new matrix: it must
        // materialise to exactly the IT operator.
        let dims = DyadDims { n_dyad: 3, n_in: 2, n_out: 4 };
        let w3: Vec<f32> = (0..dims.component_params()).map(|x| x as f32 + 0.5).collect();
        assert_eq!(
            blocktrans_full(&w3, dims, Variant::ItCat),
            blocktrans_full(&w3, dims, Variant::It)
        );
        assert_eq!(Variant::from_str("it_cat").unwrap(), Variant::ItCat);
        assert_eq!(Variant::from_str("it").unwrap(), Variant::It);
        assert!(Variant::ItCat.in_perm() && !Variant::ItCat.out_perm());
        assert!(Variant::ItCat.is_cat() && !Variant::It.is_cat());
        assert!(Variant::Dt.in_perm() && Variant::Dt.out_perm());
        assert!(Variant::Ot.out_perm() && !Variant::Ot.in_perm());
    }

    #[test]
    fn dt_composes_it_and_ot() {
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 2 };
        let w3: Vec<f32> = (0..dims.component_params()).map(|x| x as f32).collect();
        let it = blocktrans_full(&w3, dims, Variant::It);
        let pi_r = perm_vector(dims.n_out, dims.n_dyad);
        let f_in = dims.f_in();
        let mut want = vec![0.0; it.len()];
        for m in 0..dims.f_out() {
            want[pi_r[m] * f_in..(pi_r[m] + 1) * f_in]
                .copy_from_slice(&it[m * f_in..(m + 1) * f_in]);
        }
        assert_eq!(want, blocktrans_full(&w3, dims, Variant::Dt));
    }
}

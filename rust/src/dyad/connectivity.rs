//! Eq 17/18: connectivity analysis of stacked DYAD layers.
//!
//! The paper's representational-power sketch (Appendix §5.4): for two
//! square DYAD layers applied in sequence, count the 2-hop connections
//! between input dim `i` and output dim `j`. Within-block pairs get
//! O(n_in) paths; cross-block pairs only O(n_in/n_dyad) (through
//! BLOCKTRANS). We compute the counts *exactly* on the materialised
//! support and check the paper's asymptotics in tests / `repro inspect`.

use super::layout::{dyad_full, DyadDims, Variant};

/// Exact 2-hop path counts through two stacked square DYAD layers.
/// Returns (within_block_avg, cross_block_avg): average number of
/// middle dimensions connecting (i, j) pairs in the same / different
/// BLOCKDIAG block.
pub fn connection_counts(dims: DyadDims, variant: Variant) -> (f64, f64) {
    assert_eq!(dims.n_in, dims.n_out, "analysis assumes square layers");
    let n = dims.f_in();
    // support matrices: 1.0 where a weight exists
    let ones = vec![1.0f32; dims.component_params()];
    let w = dyad_full(&ones, &ones, dims, variant);
    // paths(i -> j) = sum_k support2[j, k] * support1[k, i]; with both
    // layers sharing structure, count = (S @ S)[j, i] on 0/1 support.
    let s: Vec<f32> = w.iter().map(|&x| if x != 0.0 { 1.0 } else { 0.0 }).collect();
    let mut within = (0.0, 0u64);
    let mut cross = (0.0, 0u64);
    for j in 0..n {
        for i in 0..n {
            let mut paths = 0.0f64;
            for k in 0..n {
                paths += (s[j * n + k] * s[k * n + i]) as f64;
            }
            if i / dims.n_in == j / dims.n_in {
                within.0 += paths;
                within.1 += 1;
            } else {
                cross.0 += paths;
                cross.1 += 1;
            }
        }
    }
    (
        within.0 / within.1.max(1) as f64,
        cross.0 / cross.1.max(1) as f64,
    )
}

/// Eq 18: ratio of dense connections to DYAD connections, (within, cross).
/// Dense 2-layer stacks give n = n_in*n_dyad paths for every pair.
pub fn connectivity_ratio(dims: DyadDims, variant: Variant) -> (f64, f64) {
    let dense = dims.f_in() as f64;
    let (within, cross) = connection_counts(dims, variant);
    (dense / within, dense / cross.max(1e-9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_block_scales_like_n_in() {
        // Eq 17 first case: O(n_in) paths within a block.
        let dims = DyadDims { n_dyad: 4, n_in: 8, n_out: 8 };
        let (within, cross) = connection_counts(dims, Variant::It);
        assert!(within >= dims.n_in as f64, "within={within}");
        assert!(within < 4.0 * dims.n_in as f64);
        assert!(cross > 0.0, "BLOCKTRANS must create cross-block paths");
        assert!(within > cross, "within-block must dominate");
    }

    #[test]
    fn ratios_match_paper_asymptotics() {
        // Eq 18: dense/dyad ratio O(n_dyad) within, O(n_dyad^2) across.
        for nd in [2usize, 4, 8] {
            let dims = DyadDims { n_dyad: nd, n_in: 16, n_out: 16 };
            let (rw, rc) = connectivity_ratio(dims, Variant::It);
            // within: between nd/4 and 4*nd; cross: between nd^2/8 and 8*nd^2
            assert!(rw > nd as f64 / 4.0 && rw < 4.0 * nd as f64, "nd={nd} rw={rw}");
            assert!(
                rc > (nd * nd) as f64 / 8.0 && rc < 8.0 * (nd * nd) as f64,
                "nd={nd} rc={rc}"
            );
        }
    }

    #[test]
    fn sparser_dyad_loses_cross_connectivity_faster() {
        let d4 = DyadDims { n_dyad: 4, n_in: 8, n_out: 8 };
        let d8 = DyadDims { n_dyad: 8, n_in: 8, n_out: 8 };
        let (_, c4) = connection_counts(d4, Variant::It);
        let (_, c8) = connection_counts(d8, Variant::It);
        assert!(c8 < c4, "raising n_dyad must cut cross-block paths");
    }
}

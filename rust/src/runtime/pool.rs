//! Persistent worker pool: resident threads for every native kernel.
//!
//! Before this module, every parallel kernel call in
//! [`crate::dyad::kernel`] spawned and joined fresh OS threads via
//! `std::thread::scope`, so one transformer train step paid hundreds
//! of spawn/join cycles. A [`ThreadPool`] keeps its workers resident:
//! a job is published once (an erased closure pointer + task count),
//! workers wake by a spin-then-park epoch protocol, run their task,
//! and check in; the caller runs task 0 itself and returns when every
//! worker has checked in. After warmup the steady-state hot path
//! performs **zero thread spawns** (asserted by [`counters`]).
//!
//! ## Determinism contract
//!
//! The pool schedules **statically**: task `t` of a `run(n_tasks, f)`
//! always executes on the same logical lane (caller = lane 0, worker
//! `i` = lane `i+1`), and [`ThreadPool::run_chunks`] hands task `t`
//! exactly the `t`-th `chunks_mut(chunk_len)` chunk of the output
//! slice. Kernels built on it therefore produce **bitwise identical**
//! results to the scoped-spawn path at equal thread count — there is
//! no work stealing and no dynamic splitting anywhere. The scoped
//! reference path is kept behind [`with_scoped_spawns`] so tests and
//! `benches/pool_overhead.rs` can measure/verify pool-vs-scoped on
//! the *same* public kernel entry points.
//!
//! ## Lifecycle and sizing
//!
//! Pools are cached **per OS thread** in a size-keyed registry
//! ([`sized`]); [`global`] resolves [`crate::dyad::kernel::num_threads`]
//! (the `DYAD_NUM_THREADS` OnceLock default). Per-thread caching is
//! what gives each serve worker its own pool with zero plumbing: a
//! fleet of N workers sized `num_threads()/N` holds N independent
//! pools and never oversubscribes the machine, while two workers
//! never contend on one pool's job slot. Explicit
//! [`ThreadPool::new(n)`] always bypasses the `num_threads()` cache —
//! the env default is a default, not a ceiling. Dropping a pool joins
//! its workers; thread-exit drops the registry.
//!
//! A task that calls back into the pool (nested parallel section)
//! runs the inner job inline on its own lane — same chunk
//! assignment, still bitwise identical, no deadlock, no
//! oversubscription. A panic inside any task is caught, the job
//! still completes on the other lanes, and the panic is resumed on
//! the caller — a poisoned task surfaces as an error, never a hang.
//!
//! ## Model checking (`--cfg loom`)
//!
//! Every synchronisation primitive in this module is drawn from the
//! [`shim`] module: `std` types in normal builds, `loom` doubles when
//! built with `RUSTFLAGS="--cfg loom"`. `tests/loom_pool.rs`
//! exhaustively explores the epoch-publication protocol under loom —
//! job-write/epoch-bump happens-before, park/unpark wakeup, panic
//! check-in, nested inlining — and a mutation harness (CI `loom` job)
//! rebuilds with `--cfg dyad_loom_epoch_relaxed` /
//! `--cfg dyad_loom_done_relaxed` to prove the suite *fails* when the
//! [`epoch_publish`] / [`done_check_in`] orderings are weakened. The
//! [`ThreadPool::run_chunks`] disjointness contract is additionally
//! enforced at runtime in debug builds by
//! [`debug_validate_chunk_cover`] and under Miri by
//! `tests/miri_subset.rs`.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::rc::Rc;

use shim::cell::UnsafeCell;
use shim::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use shim::sync::{Arc, Condvar, Mutex};
use shim::thread::JoinHandle;

/// Synchronisation-primitive indirection: `std` types in normal
/// builds, `loom`-instrumented doubles under `--cfg loom` so the
/// model checker can exhaustively explore the epoch protocol. The
/// `std` side mirrors loom's closure-scoped `UnsafeCell` API so both
/// builds share one source of truth for every access to `job`.
pub(crate) mod shim {
    pub(crate) mod sync {
        #[cfg(not(loom))]
        pub(crate) use std::sync::{Arc, Condvar, Mutex};

        #[cfg(loom)]
        pub(crate) use loom::sync::{Arc, Condvar, Mutex};

        pub(crate) mod atomic {
            #[cfg(not(loom))]
            pub(crate) use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

            #[cfg(loom)]
            pub(crate) use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
        }
    }

    pub(crate) mod cell {
        /// API-compatible subset of `loom::cell::UnsafeCell`: all
        /// reads/writes go through closures, which is what lets the
        /// loom build track every access for race detection.
        #[cfg(not(loom))]
        #[derive(Debug)]
        pub(crate) struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

        #[cfg(not(loom))]
        impl<T> UnsafeCell<T> {
            pub(crate) fn new(data: T) -> UnsafeCell<T> {
                UnsafeCell(std::cell::UnsafeCell::new(data))
            }

            /// Closure-scoped shared access to the wrapped value.
            pub(crate) fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
                f(self.0.get())
            }

            /// Closure-scoped exclusive access to the wrapped value.
            pub(crate) fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
                f(self.0.get())
            }
        }

        #[cfg(loom)]
        pub(crate) use loom::cell::UnsafeCell;
    }

    pub(crate) mod thread {
        #[cfg(not(loom))]
        pub(crate) use std::thread::{yield_now, JoinHandle};

        #[cfg(loom)]
        pub(crate) use loom::thread::{yield_now, JoinHandle};

        /// Spawn a named resident worker thread. The loom double
        /// drops the name — loom's `spawn` has no builder — which is
        /// fine: thread names are a debugging nicety only.
        #[cfg(not(loom))]
        pub(crate) fn spawn_worker<F>(idx: usize, f: F) -> JoinHandle<()>
        where
            F: FnOnce() + Send + 'static,
        {
            std::thread::Builder::new()
                .name(format!("dyad-pool-{idx}"))
                .spawn(f)
                .expect("spawn pool worker")
        }

        #[cfg(loom)]
        pub(crate) fn spawn_worker<F>(_idx: usize, f: F) -> JoinHandle<()>
        where
            F: FnOnce() + Send + 'static,
        {
            loom::thread::spawn(f)
        }

        /// One bounded-spin iteration: a CPU pause hint on real
        /// hardware, a scheduler yield under loom (pause hints are
        /// invisible to the model checker and would livelock it).
        #[cfg(not(loom))]
        pub(crate) fn spin_hint() {
            std::hint::spin_loop();
        }

        #[cfg(loom)]
        pub(crate) fn spin_hint() {
            loom::thread::yield_now();
        }
    }
}

/// Bounded busy-wait before a worker parks on the condvar (and before
/// the caller yields while waiting for check-ins). Kernels are
/// micro/millisecond scale, so the common case hits the spin window.
/// Under loom the window shrinks to keep the schedule space
/// explorable (each spin is a yield = a preemption point); under Miri
/// it shrinks so interpreted spins reach the park path quickly.
const SPIN_LIMIT: u32 = if cfg!(loom) {
    2
} else if cfg!(miri) {
    64
} else {
    1 << 14
};

type PanicPayload = Box<dyn std::any::Any + Send>;

/// One published job: an erased `&F` plus the monomorphic trampoline
/// that re-types it. Valid only between epoch publication and the
/// last `done` check-in of that epoch, which `run` brackets. `Copy`
/// so workers can lift it out of the [`UnsafeCell`] access closure.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n_tasks: usize,
}

struct Shared {
    /// Written by the caller before the epoch bump (Release) that
    /// publishes it; read by workers after their Acquire epoch load.
    job: UnsafeCell<Job>,
    epoch: AtomicU64,
    /// Workers that finished the current epoch (idle lanes check in
    /// too, so the caller's wait is a single counter compare).
    done: AtomicUsize,
    shutdown: AtomicBool,
    /// First panic payload caught in a worker task this epoch.
    panicked: Mutex<Option<PanicPayload>>,
    /// Park/wake for idle workers; pairs with `epoch`/`shutdown`.
    lock: Mutex<()>,
    cv: Condvar,
}

// SAFETY: `job` is only written by the caller while every worker is
// waiting for the next epoch, and only read by workers between the
// epoch bump and their `done` check-in; `run` does not return (and so
// cannot re-write `job`) until all check-ins arrive. This hand-off
// discipline is model-checked exhaustively by `tests/loom_pool.rs`.
unsafe impl Sync for Shared {}

/// Publish a new epoch, waking workers onto the freshly written job.
/// Release ordering pairs with the workers' Acquire epoch load in
/// [`worker_loop`]: that edge is what makes the `job` write
/// happen-before every task read.
///
/// Mutation harness: under `--cfg loom --cfg dyad_loom_epoch_relaxed`
/// this deliberately degrades to a Relaxed publish, which lets a
/// spinning worker observe the new epoch with no happens-before edge
/// to the job write. The loom suite MUST fail on that build — CI's
/// `loom` job asserts it does.
fn epoch_publish(epoch: &AtomicU64) {
    #[cfg(all(loom, dyad_loom_epoch_relaxed))]
    epoch.fetch_add(1, Ordering::Relaxed);
    #[cfg(not(all(loom, dyad_loom_epoch_relaxed)))]
    epoch.fetch_add(1, Ordering::Release);
}

/// A worker's end-of-epoch check-in. Release (within the AcqRel RMW)
/// pairs with the caller's Acquire `done` load in [`ThreadPool::run`]:
/// it is what makes every task-side write (including the worker's
/// last read of `job`) happen-before `run` returning — and therefore
/// before the *next* `run` overwrites the job slot.
///
/// Mutation harness: under `--cfg loom --cfg dyad_loom_done_relaxed`
/// this degrades to a Relaxed check-in, so back-to-back `run` calls
/// race the next job write against the previous epoch's job read. The
/// loom suite MUST fail on that build.
fn done_check_in(done: &AtomicUsize) {
    #[cfg(all(loom, dyad_loom_done_relaxed))]
    done.fetch_add(1, Ordering::Relaxed);
    #[cfg(not(all(loom, dyad_loom_done_relaxed)))]
    done.fetch_add(1, Ordering::AcqRel);
}

#[cfg(not(loom))]
thread_local! {
    static POOLS: RefCell<HashMap<usize, Rc<ThreadPool>>> = RefCell::new(HashMap::new());
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
    static FORCE_SCOPED: Cell<bool> = const { Cell::new(false) };
}

#[cfg(loom)]
loom::thread_local! {
    static POOLS: RefCell<HashMap<usize, Rc<ThreadPool>>> = RefCell::new(HashMap::new());
    static IN_TASK: Cell<bool> = Cell::new(false);
    static FORCE_SCOPED: Cell<bool> = Cell::new(false);
}

fn in_task_get() -> bool {
    IN_TASK.with(Cell::get)
}

fn in_task_set(v: bool) {
    IN_TASK.with(|c| c.set(v));
}

/// A persistent worker pool of `threads` logical lanes: `threads - 1`
/// resident OS threads plus the calling thread (lane 0).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool with exactly `threads` lanes (min 1). This always
    /// honours the explicit count — it does **not** consult the
    /// `num_threads()` OnceLock cache, so callers (serve workers,
    /// tests, benches) can size pools freely within one process.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            job: UnsafeCell::new(Job { data: std::ptr::null(), call: noop_call, n_tasks: 0 }),
            epoch: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            panicked: Mutex::new(None),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            counters::note_spawn(1);
            let worker = shim::thread::spawn_worker(i, move || worker_loop(&sh, i));
            workers.push(worker);
        }
        ThreadPool { shared, workers, threads }
    }

    /// Logical lane count (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `n_tasks` tasks (`f(0)..f(n_tasks-1)`) across the lanes:
    /// the caller executes task 0, worker `i` executes task `i + 1`.
    /// Blocks until every lane has checked in. `n_tasks` must not
    /// exceed [`ThreadPool::threads`]; kernels guarantee this because
    /// `div_ceil` panel splits produce at most `threads` chunks.
    ///
    /// Panics in any task are caught, the epoch still completes on
    /// every lane, and the first payload is resumed on the caller.
    ///
    /// xtask:hot-path — dispatch itself must not allocate.
    pub fn run<F>(&self, n_tasks: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        // Serial lanes, nested parallel sections and 1-task jobs run
        // inline in task order — same chunk ownership, no dispatch.
        if n_tasks == 1 || self.workers.is_empty() || in_task_get() {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        debug_assert!(
            n_tasks <= self.threads,
            "run: {n_tasks} tasks exceed {} pool lanes",
            self.threads
        );
        counters::note_pool_run();
        let shared = &*self.shared;
        shared.done.store(0, Ordering::Relaxed);
        // SAFETY: all workers from the previous epoch have checked in
        // (the previous `run` blocked on it, and `done_check_in`'s
        // Release side published their last `job` read), so no lane
        // reads `job` while we overwrite it; `epoch_publish` below is
        // what makes this write visible before any task runs.
        shared.job.with_mut(|j| unsafe {
            *j = Job { data: f as *const F as *const (), call: call_typed::<F>, n_tasks };
        });
        {
            // Bump under the park lock so a worker that just decided
            // to wait cannot miss the notify.
            let _g = shared.lock.lock().unwrap_or_else(|p| p.into_inner());
            epoch_publish(&shared.epoch);
            shared.cv.notify_all();
        }
        // Caller is lane 0. Mark in-task so nested pool use inlines.
        in_task_set(true);
        let caller = panic::catch_unwind(AssertUnwindSafe(|| f(0)));
        in_task_set(false);
        let n_workers = self.workers.len();
        let mut spins = 0u32;
        while shared.done.load(Ordering::Acquire) < n_workers {
            spins = spins.wrapping_add(1);
            if spins < SPIN_LIMIT {
                shim::thread::spin_hint();
            } else {
                shim::thread::yield_now();
            }
        }
        let worker_panic =
            shared.panicked.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Err(p) = caller {
            panic::resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            panic::resume_unwind(p);
        }
    }

    /// The bitwise-exact panel primitive: hand task `t` the `t`-th
    /// `chunks_mut(chunk_len)` chunk of `out`, one task per chunk —
    /// byte-for-byte the iteration the scoped-spawn kernels ran, with
    /// resident lanes instead of fresh threads.
    ///
    /// ## Contract (soundness of the `SendPtr` handout)
    ///
    /// Task `t` receives exactly the half-open range
    /// `[t * chunk_len, min((t + 1) * chunk_len, len))` of `out`, and
    /// the task count is `len.div_ceil(chunk_len)` — so the ranges
    /// are non-empty, **pairwise disjoint**, and **tile `[0, len)`
    /// exactly**, and no `&mut` chunk outlives the call (`run` blocks
    /// until every lane checks in). Debug builds re-verify the
    /// partition on every call via [`debug_validate_chunk_cover`];
    /// `tests/miri_subset.rs` checks the handout under Miri's
    /// strict-provenance aliasing rules.
    ///
    /// xtask:hot-path — dispatch itself must not allocate.
    pub fn run_chunks<F>(&self, out: &mut [f32], chunk_len: usize, f: &F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if out.is_empty() || chunk_len == 0 {
            return;
        }
        let len = out.len();
        let n_tasks = len.div_ceil(chunk_len);
        debug_validate_chunk_cover(len, chunk_len, n_tasks);
        let base = SendPtr(out.as_mut_ptr());
        self.run(n_tasks, &move |t| {
            let start = t * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: task `t` takes the `t`-th `chunks_mut`-style
            // range of `out`; the ranges are pairwise disjoint and
            // tile `[0, len)` (debug-checked above), and `run` blocks
            // until every task finishes, so no chunk outlives the
            // caller's `&mut [f32]`.
            let chunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(t, chunk);
        });
    }
}

/// Debug-build dynamic checker for the [`ThreadPool::run_chunks`]
/// contract: task ranges `[t * chunk_len, min((t + 1) * chunk_len,
/// len))` must be non-empty, pairwise disjoint (they ascend and abut)
/// and tile `[0, len)` exactly — the properties the `SendPtr` handout
/// relies on for soundness. Allocation-free so it can sit on the hot
/// path of debug builds; compiled out of release builds.
fn debug_validate_chunk_cover(len: usize, chunk_len: usize, n_tasks: usize) {
    if !cfg!(debug_assertions) {
        return;
    }
    assert_eq!(
        n_tasks,
        len.div_ceil(chunk_len),
        "run_chunks: task count drifted from the chunk partition"
    );
    let mut prev_end = 0usize;
    for t in 0..n_tasks {
        let start = t * chunk_len;
        let end = (start + chunk_len).min(len);
        assert!(start < end, "run_chunks: empty range for task {t}");
        assert_eq!(start, prev_end, "run_chunks: task {t} overlaps or gaps");
        prev_end = end;
    }
    assert_eq!(prev_end, len, "run_chunks: ranges do not cover the output");
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let _g = self.shared.lock.lock().unwrap_or_else(|p| p.into_inner());
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

struct SendPtr(*mut f32);

// SAFETY: the pointer is only dereferenced through the disjoint-range
// protocol documented (and debug-verified) in `run_chunks`, so no two
// threads ever touch the same element.
unsafe impl Send for SendPtr {}

// SAFETY: as for `Send` — shared references to the wrapper only ever
// yield accesses to pairwise-disjoint ranges, never the same element
// from two threads.
unsafe impl Sync for SendPtr {}

/// Placeholder trampoline for the pre-first-epoch job slot.
///
/// # Safety
///
/// Never actually called: workers only invoke the trampoline after an
/// epoch bump, and every bump is preceded by a real job write.
unsafe fn noop_call(_data: *const (), _t: usize) {}

/// Re-types the erased closure pointer and runs task `t`.
///
/// # Safety
///
/// `data` must be the erased `&F` published by the current epoch's
/// `run`, which keeps the closure alive until every lane checks in.
unsafe fn call_typed<F: Fn(usize) + Sync>(data: *const (), t: usize) {
    // SAFETY: `data` was erased from an `&F` that the publishing
    // `run` keeps alive until every lane checks in.
    let f = unsafe { &*(data as *const F) };
    f(t);
}

fn worker_loop(shared: &Shared, idx: usize) {
    // Worker lanes are always "in a task" from the registry's point
    // of view: any pool use from kernel code they run must inline.
    in_task_set(true);
    let mut seen = 0u64;
    let mut spins = 0u32;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let e = shared.epoch.load(Ordering::Acquire);
        if e == seen {
            spins = spins.wrapping_add(1);
            if spins < SPIN_LIMIT {
                shim::thread::spin_hint();
            } else {
                let mut g = shared.lock.lock().unwrap_or_else(|p| p.into_inner());
                while !shared.shutdown.load(Ordering::Relaxed)
                    && shared.epoch.load(Ordering::Relaxed) == seen
                {
                    g = shared.cv.wait(g).unwrap_or_else(|p| p.into_inner());
                }
                spins = 0;
            }
            continue;
        }
        seen = e;
        spins = 0;
        // SAFETY: the Acquire epoch load above synchronises with the
        // caller's Release bump in `epoch_publish`, which happens
        // after the job write — so this read cannot race with it, and
        // the `Copy` lifts the job out before any other access.
        let job = shared.job.with(|j| unsafe { *j });
        let t = idx + 1;
        if t < job.n_tasks {
            let r = panic::catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: see `call_typed` — the closure outlives the
                // epoch because `run` blocks on our check-in below.
                unsafe { (job.call)(job.data, t) }
            }));
            if let Err(p) = r {
                let mut slot =
                    shared.panicked.lock().unwrap_or_else(|e| e.into_inner());
                slot.get_or_insert(p);
            }
        }
        done_check_in(&shared.done);
    }
}

/// The per-thread pool of the given lane count (min 1), built on
/// first use and resident until the calling thread exits. Distinct
/// OS threads get distinct pools — that is how each serve worker owns
/// its lanes. Inside a pool task this returns the serial pool, so
/// nested parallel sections inline instead of spawning.
pub fn sized(threads: usize) -> Rc<ThreadPool> {
    let threads = if in_task_get() { 1 } else { threads.max(1) };
    POOLS.with(|p| {
        Rc::clone(
            p.borrow_mut()
                .entry(threads)
                .or_insert_with(|| Rc::new(ThreadPool::new(threads))),
        )
    })
}

/// The calling thread's default pool: sized by
/// [`crate::dyad::kernel::num_threads`] (`DYAD_NUM_THREADS` env,
/// cached per process).
pub fn global() -> Rc<ThreadPool> {
    sized(crate::dyad::kernel::num_threads())
}

/// True while the current thread is executing a pool task.
pub fn in_task() -> bool {
    in_task_get()
}

/// Test/bench hook: run `f` with every pool-backed kernel entry point
/// routed through the legacy `std::thread::scope` spawn path instead.
/// This is how pool-vs-scoped bitwise parity is asserted (and how
/// `benches/pool_overhead.rs` measures the dispatch overhead) on the
/// *same* public kernels.
pub fn with_scoped_spawns<T>(f: impl FnOnce() -> T) -> T {
    let prev = FORCE_SCOPED.with(Cell::get);
    FORCE_SCOPED.with(|c| c.set(true));
    let out = f();
    FORCE_SCOPED.with(|c| c.set(prev));
    out
}

/// True when [`with_scoped_spawns`] is active on this thread.
pub fn scoped_spawns_forced() -> bool {
    FORCE_SCOPED.with(Cell::get)
}

/// Thread-local spawn/dispatch/allocation counters, in the mould of
/// [`crate::runtime::staging`]: cheap enough to stay on in release
/// builds, precise enough to *prove* the steady-state contract —
/// after warmup a train or serve hot loop performs zero OS thread
/// spawns and zero kernel-output heap allocations (every output
/// comes from the workspace arena or the kernel scratch recycler).
pub mod counters {
    use std::cell::Cell;

    #[cfg(not(loom))]
    thread_local! {
        static SPAWNS: Cell<u64> = const { Cell::new(0) };
        static POOL_RUNS: Cell<u64> = const { Cell::new(0) };
        static KERNEL_ALLOCS: Cell<u64> = const { Cell::new(0) };
        static ARENA_HITS: Cell<u64> = const { Cell::new(0) };
    }

    #[cfg(loom)]
    loom::thread_local! {
        static SPAWNS: Cell<u64> = Cell::new(0);
        static POOL_RUNS: Cell<u64> = Cell::new(0);
        static KERNEL_ALLOCS: Cell<u64> = Cell::new(0);
        static ARENA_HITS: Cell<u64> = Cell::new(0);
    }

    /// One or more OS threads created (pool construction or a scoped
    /// spawn inside a kernel).
    pub fn note_spawn(n: u64) {
        SPAWNS.with(|c| c.set(c.get() + n));
    }

    /// One job dispatched to resident pool workers.
    pub fn note_pool_run() {
        POOL_RUNS.with(|c| c.set(c.get() + 1));
    }

    /// One fresh heap allocation on a kernel hot path (output vector
    /// or internal scratch that missed its recycler).
    pub fn note_kernel_alloc() {
        KERNEL_ALLOCS.with(|c| c.set(c.get() + 1));
    }

    /// One hot-path buffer served from an arena/recycler free list.
    pub fn note_arena_hit() {
        ARENA_HITS.with(|c| c.set(c.get() + 1));
    }

    /// Point-in-time view of this thread's counters.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub struct PoolSnapshot {
        /// OS threads spawned (pool workers + scoped kernel spawns).
        pub spawns: u64,
        /// Jobs dispatched to resident workers.
        pub pool_runs: u64,
        /// Hot-path heap allocations (kernel outputs + scratch misses).
        pub kernel_allocs: u64,
        /// Hot-path buffers recycled instead of allocated.
        pub arena_hits: u64,
    }

    impl PoolSnapshot {
        /// Delta since an earlier snapshot.
        pub fn since(&self, earlier: &PoolSnapshot) -> PoolSnapshot {
            PoolSnapshot {
                spawns: self.spawns - earlier.spawns,
                pool_runs: self.pool_runs - earlier.pool_runs,
                kernel_allocs: self.kernel_allocs - earlier.kernel_allocs,
                arena_hits: self.arena_hits - earlier.arena_hits,
            }
        }
    }

    pub fn snapshot() -> PoolSnapshot {
        PoolSnapshot {
            spawns: SPAWNS.with(Cell::get),
            pool_runs: POOL_RUNS.with(Cell::get),
            kernel_allocs: KERNEL_ALLOCS.with(Cell::get),
            arena_hits: ARENA_HITS.with(Cell::get),
        }
    }

    pub fn reset() {
        SPAWNS.with(|c| c.set(0));
        POOL_RUNS.with(|c| c.set(0));
        KERNEL_ALLOCS.with(|c| c.set(0));
        ARENA_HITS.with(|c| c.set(0));
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        for n_tasks in [1, 2, 3, 4] {
            let hits: Vec<AtomicU32> = (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
            pool.run(n_tasks, &|t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} of {n_tasks}");
            }
        }
    }

    #[test]
    fn run_chunks_matches_chunks_mut_exactly() {
        let pool = ThreadPool::new(3);
        for (len, chunk_len) in [(12, 5), (12, 4), (7, 3), (1, 9), (9, 9)] {
            let mut pooled = vec![0.0f32; len];
            let mut scoped = vec![0.0f32; len];
            pool.run_chunks(&mut pooled, chunk_len, &|t, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (t * 1000 + i) as f32;
                }
            });
            for (t, chunk) in scoped.chunks_mut(chunk_len).enumerate() {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (t * 1000 + i) as f32;
                }
            }
            assert_eq!(pooled, scoped, "len={len} chunk_len={chunk_len}");
        }
    }

    #[test]
    fn pool_is_resident_across_runs_and_rebuilds_after_drop() {
        let before = counters::snapshot();
        let pool = ThreadPool::new(3);
        let after_build = counters::snapshot().since(&before);
        assert_eq!(after_build.spawns, 2);
        let mut out = vec![0.0f32; 64];
        for rep in 0..16 {
            pool.run_chunks(&mut out, 8, &|t, chunk| {
                for v in chunk.iter_mut() {
                    *v = (rep * 100 + t) as f32;
                }
            });
        }
        let steady = counters::snapshot().since(&before);
        assert_eq!(steady.spawns, 2, "resident workers must not respawn");
        assert_eq!(steady.pool_runs, 16);
        drop(pool);
        // rebuild: a fresh pool spawns fresh workers and still works
        let pool = ThreadPool::new(3);
        pool.run_chunks(&mut out, 8, &|_, chunk| chunk.fill(7.0));
        assert!(out.iter().all(|&v| v == 7.0));
        assert_eq!(counters::snapshot().since(&before).spawns, 4);
    }

    #[test]
    fn zero_row_and_zero_task_jobs_are_noops() {
        let pool = ThreadPool::new(4);
        pool.run(0, &|_| panic!("must not run"));
        let mut empty: Vec<f32> = Vec::new();
        pool.run_chunks(&mut empty, 8, &|_, _| panic!("must not run"));
        let mut out = vec![1.0f32; 4];
        pool.run_chunks(&mut out, 0, &|_, _| panic!("must not run"));
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn panic_in_task_surfaces_as_error_not_hang() {
        let pool = ThreadPool::new(4);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|t| {
                if t == 2 {
                    panic!("task 2 exploded");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the caller");
        // caller-lane panics propagate too
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|t| {
                if t == 0 {
                    panic!("task 0 exploded");
                }
            });
        }));
        assert!(r.is_err(), "caller panic must propagate");
        // and the pool stays usable afterwards
        let mut out = vec![0.0f32; 8];
        pool.run_chunks(&mut out, 2, &|t, chunk| chunk.fill(t as f32));
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
    }

    #[test]
    fn nested_runs_inline_without_deadlock() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU32::new(0);
        pool.run(4, &|_| {
            // nested use of the registry inside a task: serial pool
            let inner = sized(8);
            assert_eq!(inner.threads(), 1);
            inner.run(1, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn registry_caches_by_size_and_scoped_flag_toggles() {
        let a = sized(2);
        let b = sized(2);
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(sized(0).threads(), 1);
        assert!(!scoped_spawns_forced());
        let nested = with_scoped_spawns(|| {
            assert!(scoped_spawns_forced());
            with_scoped_spawns(scoped_spawns_forced)
        });
        assert!(nested);
        assert!(!scoped_spawns_forced());
    }

    #[test]
    fn debug_validator_accepts_every_divisor_partition() {
        // the validator is pure; sweep it directly over many shapes
        for len in 1..40usize {
            for chunk_len in 1..=len {
                debug_validate_chunk_cover(len, chunk_len, len.div_ceil(chunk_len));
            }
        }
    }

    #[test]
    fn debug_validator_rejects_wrong_task_count() {
        if !cfg!(debug_assertions) {
            return; // validator is compiled out in release test runs
        }
        let r = panic::catch_unwind(|| debug_validate_chunk_cover(10, 3, 3));
        assert!(r.is_err(), "undercounted partition must be rejected");
        let r = panic::catch_unwind(|| debug_validate_chunk_cover(10, 3, 5));
        assert!(r.is_err(), "overcounted partition must be rejected");
    }
}

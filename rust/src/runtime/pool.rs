//! Persistent worker pool: resident threads for every native kernel.
//!
//! Before this module, every parallel kernel call in
//! [`crate::dyad::kernel`] spawned and joined fresh OS threads via
//! `std::thread::scope`, so one transformer train step paid hundreds
//! of spawn/join cycles. A [`ThreadPool`] keeps its workers resident:
//! a job is published once (an erased closure pointer + task count),
//! workers wake by a spin-then-park epoch protocol, run their task,
//! and check in; the caller runs task 0 itself and returns when every
//! worker has checked in. After warmup the steady-state hot path
//! performs **zero thread spawns** (asserted by [`counters`]).
//!
//! ## Determinism contract
//!
//! The pool schedules **statically**: task `t` of a `run(n_tasks, f)`
//! always executes on the same logical lane (caller = lane 0, worker
//! `i` = lane `i+1`), and [`ThreadPool::run_chunks`] hands task `t`
//! exactly the `t`-th `chunks_mut(chunk_len)` chunk of the output
//! slice. Kernels built on it therefore produce **bitwise identical**
//! results to the scoped-spawn path at equal thread count — there is
//! no work stealing and no dynamic splitting anywhere. The scoped
//! reference path is kept behind [`with_scoped_spawns`] so tests and
//! `benches/pool_overhead.rs` can measure/verify pool-vs-scoped on
//! the *same* public kernel entry points.
//!
//! ## Lifecycle and sizing
//!
//! Pools are cached **per OS thread** in a size-keyed registry
//! ([`sized`]); [`global`] resolves [`crate::dyad::kernel::num_threads`]
//! (the `DYAD_NUM_THREADS` OnceLock default). Per-thread caching is
//! what gives each serve worker its own pool with zero plumbing: a
//! fleet of N workers sized `num_threads()/N` holds N independent
//! pools and never oversubscribes the machine, while two workers
//! never contend on one pool's job slot. Explicit
//! [`ThreadPool::new(n)`] always bypasses the `num_threads()` cache —
//! the env default is a default, not a ceiling. Dropping a pool joins
//! its workers; thread-exit drops the registry.
//!
//! A task that calls back into the pool (nested parallel section)
//! runs the inner job inline on its own lane — same chunk
//! assignment, still bitwise identical, no deadlock, no
//! oversubscription. A panic inside any task is caught, the job
//! still completes on the other lanes, and the panic is resumed on
//! the caller — a poisoned task surfaces as an error, never a hang.

use std::cell::{Cell, RefCell, UnsafeCell};
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Bounded busy-wait before a worker parks on the condvar (and before
/// the caller yields while waiting for check-ins). Kernels are
/// micro/millisecond scale, so the common case hits the spin window.
const SPIN_LIMIT: u32 = 1 << 14;

type PanicPayload = Box<dyn std::any::Any + Send>;

/// One published job: an erased `&F` plus the monomorphic trampoline
/// that re-types it. Valid only between epoch publication and the
/// last `done` check-in of that epoch, which `run` brackets.
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n_tasks: usize,
}

struct Shared {
    /// Written by the caller before the epoch bump (Release) that
    /// publishes it; read by workers after their Acquire epoch load.
    job: UnsafeCell<Job>,
    epoch: AtomicU64,
    /// Workers that finished the current epoch (idle lanes check in
    /// too, so the caller's wait is a single counter compare).
    done: AtomicUsize,
    shutdown: AtomicBool,
    /// First panic payload caught in a worker task this epoch.
    panicked: Mutex<Option<PanicPayload>>,
    /// Park/wake for idle workers; pairs with `epoch`/`shutdown`.
    lock: Mutex<()>,
    cv: Condvar,
}

// SAFETY: `job` is only written by the caller while every worker is
// waiting for the next epoch, and only read by workers between the
// epoch bump and their `done` check-in; `run` does not return (and so
// cannot re-write `job`) until all check-ins arrive.
unsafe impl Sync for Shared {}

thread_local! {
    static POOLS: RefCell<HashMap<usize, Rc<ThreadPool>>> = RefCell::new(HashMap::new());
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
    static FORCE_SCOPED: Cell<bool> = const { Cell::new(false) };
}

/// A persistent worker pool of `threads` logical lanes: `threads - 1`
/// resident OS threads plus the calling thread (lane 0).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool with exactly `threads` lanes (min 1). This always
    /// honours the explicit count — it does **not** consult the
    /// `num_threads()` OnceLock cache, so callers (serve workers,
    /// tests, benches) can size pools freely within one process.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            job: UnsafeCell::new(Job { data: std::ptr::null(), call: noop_call, n_tasks: 0 }),
            epoch: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            panicked: Mutex::new(None),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            counters::note_spawn(1);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dyad-pool-{i}"))
                    .spawn(move || worker_loop(&sh, i))
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool { shared, workers, threads }
    }

    /// Logical lane count (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `n_tasks` tasks (`f(0)..f(n_tasks-1)`) across the lanes:
    /// the caller executes task 0, worker `i` executes task `i + 1`.
    /// Blocks until every lane has checked in. `n_tasks` must not
    /// exceed [`ThreadPool::threads`]; kernels guarantee this because
    /// `div_ceil` panel splits produce at most `threads` chunks.
    ///
    /// Panics in any task are caught, the epoch still completes on
    /// every lane, and the first payload is resumed on the caller.
    pub fn run<F>(&self, n_tasks: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        // Serial lanes, nested parallel sections and 1-task jobs run
        // inline in task order — same chunk ownership, no dispatch.
        if n_tasks == 1 || self.workers.is_empty() || IN_TASK.get() {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        debug_assert!(
            n_tasks <= self.threads,
            "run: {n_tasks} tasks exceed {} pool lanes",
            self.threads
        );
        counters::note_pool_run();
        let shared = &*self.shared;
        shared.done.store(0, Ordering::Relaxed);
        // SAFETY: all workers from the previous epoch have checked in
        // (the previous `run` blocked on it), so no one reads `job`
        // while we write it; the epoch bump below publishes it.
        unsafe {
            *shared.job.get() =
                Job { data: f as *const F as *const (), call: call_typed::<F>, n_tasks };
        }
        {
            // Bump under the park lock so a worker that just decided
            // to wait cannot miss the notify.
            let _g = shared.lock.lock().unwrap_or_else(|p| p.into_inner());
            shared.epoch.fetch_add(1, Ordering::Release);
            shared.cv.notify_all();
        }
        // Caller is lane 0. Mark in-task so nested pool use inlines.
        IN_TASK.set(true);
        let caller = panic::catch_unwind(AssertUnwindSafe(|| f(0)));
        IN_TASK.set(false);
        let n_workers = self.workers.len();
        let mut spins = 0u32;
        while shared.done.load(Ordering::Acquire) < n_workers {
            spins = spins.wrapping_add(1);
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        let worker_panic =
            shared.panicked.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Err(p) = caller {
            panic::resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            panic::resume_unwind(p);
        }
    }

    /// The bitwise-exact panel primitive: hand task `t` the `t`-th
    /// `chunks_mut(chunk_len)` chunk of `out`, one task per chunk —
    /// byte-for-byte the iteration the scoped-spawn kernels ran, with
    /// resident lanes instead of fresh threads.
    pub fn run_chunks<F>(&self, out: &mut [f32], chunk_len: usize, f: &F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if out.is_empty() || chunk_len == 0 {
            return;
        }
        let len = out.len();
        let n_tasks = len.div_ceil(chunk_len);
        let base = SendPtr(out.as_mut_ptr());
        self.run(n_tasks, &move |t| {
            let start = t * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: tasks receive pairwise-disjoint [start, end)
            // ranges of `out`, and `run` blocks until every task has
            // finished, so the borrows never outlive the &mut.
            let chunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(t, chunk);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let _g = self.shared.lock.lock().unwrap_or_else(|p| p.into_inner());
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

struct SendPtr(*mut f32);
// SAFETY: the pointer is only dereferenced through the disjoint-range
// protocol documented in `run_chunks`.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

unsafe fn noop_call(_data: *const (), _t: usize) {}

unsafe fn call_typed<F: Fn(usize) + Sync>(data: *const (), t: usize) {
    // SAFETY: `data` was erased from an `&F` that the publishing
    // `run` keeps alive until every lane checks in.
    let f = unsafe { &*(data as *const F) };
    f(t);
}

fn worker_loop(shared: &Shared, idx: usize) {
    // Worker lanes are always "in a task" from the registry's point
    // of view: any pool use from kernel code they run must inline.
    IN_TASK.set(true);
    let mut seen = 0u64;
    let mut spins = 0u32;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let e = shared.epoch.load(Ordering::Acquire);
        if e == seen {
            spins = spins.wrapping_add(1);
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                let mut g = shared.lock.lock().unwrap_or_else(|p| p.into_inner());
                while !shared.shutdown.load(Ordering::Relaxed)
                    && shared.epoch.load(Ordering::Relaxed) == seen
                {
                    g = shared.cv.wait(g).unwrap_or_else(|p| p.into_inner());
                }
                spins = 0;
            }
            continue;
        }
        seen = e;
        spins = 0;
        // SAFETY: the Acquire epoch load synchronises with the
        // caller's Release bump, which happens after the job write.
        let job = unsafe { &*shared.job.get() };
        let t = idx + 1;
        if t < job.n_tasks {
            let r = panic::catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: see `call_typed` — the closure outlives the
                // epoch because `run` blocks on our check-in below.
                unsafe { (job.call)(job.data, t) }
            }));
            if let Err(p) = r {
                let mut slot =
                    shared.panicked.lock().unwrap_or_else(|e| e.into_inner());
                slot.get_or_insert(p);
            }
        }
        shared.done.fetch_add(1, Ordering::AcqRel);
    }
}

/// The per-thread pool of the given lane count (min 1), built on
/// first use and resident until the calling thread exits. Distinct
/// OS threads get distinct pools — that is how each serve worker owns
/// its lanes. Inside a pool task this returns the serial pool, so
/// nested parallel sections inline instead of spawning.
pub fn sized(threads: usize) -> Rc<ThreadPool> {
    let threads = if IN_TASK.get() { 1 } else { threads.max(1) };
    POOLS.with(|p| {
        Rc::clone(
            p.borrow_mut()
                .entry(threads)
                .or_insert_with(|| Rc::new(ThreadPool::new(threads))),
        )
    })
}

/// The calling thread's default pool: sized by
/// [`crate::dyad::kernel::num_threads`] (`DYAD_NUM_THREADS` env,
/// cached per process).
pub fn global() -> Rc<ThreadPool> {
    sized(crate::dyad::kernel::num_threads())
}

/// True while the current thread is executing a pool task.
pub fn in_task() -> bool {
    IN_TASK.get()
}

/// Test/bench hook: run `f` with every pool-backed kernel entry point
/// routed through the legacy `std::thread::scope` spawn path instead.
/// This is how pool-vs-scoped bitwise parity is asserted (and how
/// `benches/pool_overhead.rs` measures the dispatch overhead) on the
/// *same* public kernels.
pub fn with_scoped_spawns<T>(f: impl FnOnce() -> T) -> T {
    let prev = FORCE_SCOPED.get();
    FORCE_SCOPED.set(true);
    let out = f();
    FORCE_SCOPED.set(prev);
    out
}

/// True when [`with_scoped_spawns`] is active on this thread.
pub fn scoped_spawns_forced() -> bool {
    FORCE_SCOPED.get()
}

/// Thread-local spawn/dispatch/allocation counters, in the mould of
/// [`crate::runtime::staging`]: cheap enough to stay on in release
/// builds, precise enough to *prove* the steady-state contract —
/// after warmup a train or serve hot loop performs zero OS thread
/// spawns and zero kernel-output heap allocations (every output
/// comes from the workspace arena or the kernel scratch recycler).
pub mod counters {
    use std::cell::Cell;

    thread_local! {
        static SPAWNS: Cell<u64> = const { Cell::new(0) };
        static POOL_RUNS: Cell<u64> = const { Cell::new(0) };
        static KERNEL_ALLOCS: Cell<u64> = const { Cell::new(0) };
        static ARENA_HITS: Cell<u64> = const { Cell::new(0) };
    }

    /// One or more OS threads created (pool construction or a scoped
    /// spawn inside a kernel).
    pub fn note_spawn(n: u64) {
        SPAWNS.with(|c| c.set(c.get() + n));
    }

    /// One job dispatched to resident pool workers.
    pub fn note_pool_run() {
        POOL_RUNS.with(|c| c.set(c.get() + 1));
    }

    /// One fresh heap allocation on a kernel hot path (output vector
    /// or internal scratch that missed its recycler).
    pub fn note_kernel_alloc() {
        KERNEL_ALLOCS.with(|c| c.set(c.get() + 1));
    }

    /// One hot-path buffer served from an arena/recycler free list.
    pub fn note_arena_hit() {
        ARENA_HITS.with(|c| c.set(c.get() + 1));
    }

    /// Point-in-time view of this thread's counters.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub struct PoolSnapshot {
        /// OS threads spawned (pool workers + scoped kernel spawns).
        pub spawns: u64,
        /// Jobs dispatched to resident workers.
        pub pool_runs: u64,
        /// Hot-path heap allocations (kernel outputs + scratch misses).
        pub kernel_allocs: u64,
        /// Hot-path buffers recycled instead of allocated.
        pub arena_hits: u64,
    }

    impl PoolSnapshot {
        /// Delta since an earlier snapshot.
        pub fn since(&self, earlier: &PoolSnapshot) -> PoolSnapshot {
            PoolSnapshot {
                spawns: self.spawns - earlier.spawns,
                pool_runs: self.pool_runs - earlier.pool_runs,
                kernel_allocs: self.kernel_allocs - earlier.kernel_allocs,
                arena_hits: self.arena_hits - earlier.arena_hits,
            }
        }
    }

    pub fn snapshot() -> PoolSnapshot {
        PoolSnapshot {
            spawns: SPAWNS.with(Cell::get),
            pool_runs: POOL_RUNS.with(Cell::get),
            kernel_allocs: KERNEL_ALLOCS.with(Cell::get),
            arena_hits: ARENA_HITS.with(Cell::get),
        }
    }

    pub fn reset() {
        SPAWNS.with(|c| c.set(0));
        POOL_RUNS.with(|c| c.set(0));
        KERNEL_ALLOCS.with(|c| c.set(0));
        ARENA_HITS.with(|c| c.set(0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        for n_tasks in [1, 2, 3, 4] {
            let hits: Vec<AtomicU32> =
                (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
            pool.run(n_tasks, &|t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} of {n_tasks}");
            }
        }
    }

    #[test]
    fn run_chunks_matches_chunks_mut_exactly() {
        let pool = ThreadPool::new(3);
        for (len, chunk_len) in [(12, 5), (12, 4), (7, 3), (1, 9), (9, 9)] {
            let mut pooled = vec![0.0f32; len];
            let mut scoped = vec![0.0f32; len];
            pool.run_chunks(&mut pooled, chunk_len, &|t, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (t * 1000 + i) as f32;
                }
            });
            for (t, chunk) in scoped.chunks_mut(chunk_len).enumerate() {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (t * 1000 + i) as f32;
                }
            }
            assert_eq!(pooled, scoped, "len={len} chunk_len={chunk_len}");
        }
    }

    #[test]
    fn pool_is_resident_across_runs_and_rebuilds_after_drop() {
        let before = counters::snapshot();
        let pool = ThreadPool::new(3);
        let after_build = counters::snapshot().since(&before);
        assert_eq!(after_build.spawns, 2);
        let mut out = vec![0.0f32; 64];
        for rep in 0..16 {
            pool.run_chunks(&mut out, 8, &|t, chunk| {
                for v in chunk.iter_mut() {
                    *v = (rep * 100 + t) as f32;
                }
            });
        }
        let steady = counters::snapshot().since(&before);
        assert_eq!(steady.spawns, 2, "resident workers must not respawn");
        assert_eq!(steady.pool_runs, 16);
        drop(pool);
        // rebuild: a fresh pool spawns fresh workers and still works
        let pool = ThreadPool::new(3);
        pool.run_chunks(&mut out, 8, &|_, chunk| chunk.fill(7.0));
        assert!(out.iter().all(|&v| v == 7.0));
        assert_eq!(counters::snapshot().since(&before).spawns, 4);
    }

    #[test]
    fn zero_row_and_zero_task_jobs_are_noops() {
        let pool = ThreadPool::new(4);
        pool.run(0, &|_| panic!("must not run"));
        let mut empty: Vec<f32> = Vec::new();
        pool.run_chunks(&mut empty, 8, &|_, _| panic!("must not run"));
        let mut out = vec![1.0f32; 4];
        pool.run_chunks(&mut out, 0, &|_, _| panic!("must not run"));
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn panic_in_task_surfaces_as_error_not_hang() {
        let pool = ThreadPool::new(4);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|t| {
                if t == 2 {
                    panic!("task 2 exploded");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the caller");
        // caller-lane panics propagate too
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|t| {
                if t == 0 {
                    panic!("task 0 exploded");
                }
            });
        }));
        assert!(r.is_err(), "caller panic must propagate");
        // and the pool stays usable afterwards
        let mut out = vec![0.0f32; 8];
        pool.run_chunks(&mut out, 2, &|t, chunk| chunk.fill(t as f32));
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
    }

    #[test]
    fn nested_runs_inline_without_deadlock() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU32::new(0);
        pool.run(4, &|_| {
            // nested use of the registry inside a task: serial pool
            let inner = sized(8);
            assert_eq!(inner.threads(), 1);
            inner.run(1, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn registry_caches_by_size_and_scoped_flag_toggles() {
        let a = sized(2);
        let b = sized(2);
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(sized(0).threads(), 1);
        assert!(!scoped_spawns_forced());
        let nested = with_scoped_spawns(|| {
            assert!(scoped_spawns_forced());
            with_scoped_spawns(scoped_spawns_forced)
        });
        assert!(nested);
        assert!(!scoped_spawns_forced());
    }
}

//! PJRT engine: compile-once executable cache + typed execution.
//!
//! The XLA implementation of the [`Backend`]/[`Executable`] traits,
//! compiled only under the `xla` cargo feature. Loads AOT'd HLO text
//! from an `artifacts/` directory (produced by `make artifacts`),
//! compiles each artifact once per engine, and stages host tensors to
//! `xla::Literal`s at call boundaries.
//!
//! Residency on this backend: [`Backend::upload`] converts a host
//! tensor to a literal **once** and the handle keeps it alive, so a
//! resident-bindings caller (the trainer's `TrainState`, the serve
//! worker's weights) skips the per-call tensor→literal conversion and
//! validation that the legacy `run` path pays for every input. Note
//! the honest limit: PJRT's `execute(&[Literal])` still stages each
//! literal to a device buffer inside the call, so on real hardware
//! this is cached-staging, not true device residency — holding
//! `PjRtBuffer`s as the handle payload is the follow-up (see the
//! ROADMAP's GPU-backend item).
//!
//! Pattern per `/opt/xla-example/load_hlo`: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Multi-output executables return a single tuple buffer which we
//! decompose on the host (PJRT does not untuple; DESIGN.md §2).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, IoSpec, Manifest};
use super::backend::{
    note_legacy_staging, validate_bound_inputs, validate_inputs, validate_outputs, Backend,
    Executable,
};
use super::device::{staging, DeviceTensor, XLA_DEVICE};
use crate::tensor::{DType, Tensor};
use crate::util::timer::Timer;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Loaded>>>,
    verbose: bool,
}

pub struct Loaded {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Open an artifact directory (`artifacts/` produced by `make artifacts`).
    pub fn from_dir<P: AsRef<Path>>(dir: P) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
            verbose: std::env::var("REPRO_VERBOSE").is_ok(),
        })
    }

    /// Load (compile) an artifact by manifest name; cached per engine.
    pub fn load(&self, name: &str) -> Result<Rc<Loaded>> {
        if let Some(hit) = self.cache.borrow().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {name}"))?;
        if self.verbose {
            eprintln!("[engine] compiled {name} in {:.0} ms", t.elapsed_ms());
        }
        let loaded = Rc::new(Loaded { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Backend for Engine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<Rc<dyn Executable>> {
        let loaded: Rc<dyn Executable> = Engine::load(self, name)?;
        Ok(loaded)
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }

    /// Stage once: the literal lives as long as the handle, so
    /// resident inputs skip per-call tensor→literal conversion.
    fn upload(&self, t: Tensor) -> Result<DeviceTensor> {
        staging::note_upload(t.size_bytes());
        let lit = literal_of(&t)?;
        Ok(DeviceTensor::from_payload(
            t.shape.clone(),
            t.dtype(),
            XLA_DEVICE,
            Rc::new(lit),
        ))
    }

    fn download(&self, t: &DeviceTensor) -> Result<Tensor> {
        let lit = t.payload::<xla::Literal>().with_context(|| {
            format!(
                "download: handle belongs to the {:?} backend, not {XLA_DEVICE:?}",
                t.device()
            )
        })?;
        staging::note_download(t.size_bytes());
        match t.dtype() {
            DType::F32 => Tensor::from_f32(t.shape(), lit.to_vec::<f32>()?),
            DType::I32 => Tensor::from_i32(t.shape(), lit.to_vec::<i32>()?),
        }
    }

    fn alloc(&self, shape: &[usize], dtype: DType) -> Result<DeviceTensor> {
        let lit = literal_of(&Tensor::zeros(shape, dtype))?;
        Ok(DeviceTensor::from_payload(
            shape.to_vec(),
            dtype,
            XLA_DEVICE,
            Rc::new(lit),
        ))
    }
}

/// Host tensor -> XLA literal (shape/dtype taken from the tensor).
fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &t.to_bytes())
        .context("create literal")
}

/// Host tensor -> XLA literal (validates against the IoSpec).
pub fn tensor_to_literal(t: &Tensor, spec: &IoSpec) -> Result<xla::Literal> {
    if let Some(m) = super::backend::io_mismatch(&t.shape, t.dtype(), spec) {
        bail!("stage: {m}");
    }
    literal_of(t).with_context(|| format!("literal for {:?}", spec.name))
}

/// XLA literal -> host tensor (shape taken from the output IoSpec).
pub fn literal_to_tensor(lit: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let n = lit.element_count();
    if n != spec.numel() {
        bail!(
            "output {:?}: {} elements, manifest says {:?}",
            spec.name,
            n,
            spec.shape
        );
    }
    match spec.dtype {
        DType::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
        DType::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
    }
}

impl Executable for Loaded {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with host tensors; stages every input to a literal at
    /// the call boundary (the legacy convenience path).
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        validate_inputs(&self.spec, inputs)?;
        note_legacy_staging(inputs);
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .map(|(t, s)| tensor_to_literal(t, s))
            .collect::<Result<_>>()?;
        let out = self.run_literals(&lits)?;
        let tensors: Vec<Tensor> = out
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| literal_to_tensor(l, s))
            .collect::<Result<_>>()?;
        if cfg!(debug_assertions) {
            validate_outputs(&self.spec, &tensors)?;
        }
        Ok(tensors)
    }

    /// Execute over resident literals — no tensor→literal conversion
    /// at the call boundary (PJRT still moves literals into device
    /// buffers inside `execute`); the output tuple parts stay alive
    /// as backend-owned handles.
    fn run_bound(&self, inputs: &[&DeviceTensor]) -> Result<Vec<DeviceTensor>> {
        validate_bound_inputs(&self.spec, inputs)?;
        let lits: Vec<&xla::Literal> = inputs
            .iter()
            .enumerate()
            .map(|(i, d)| d.expect_payload::<xla::Literal>(&self.spec.name, i, XLA_DEVICE))
            .collect::<Result<_>>()?;
        let out = self.run_literals(&lits)?;
        // handle metadata comes from the manifest, so the drift check
        // must look at the literal itself: element counts, in debug
        out.into_iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| {
                if cfg!(debug_assertions) && l.element_count() != s.numel() {
                    bail!(
                        "{}: output {:?}: {} elements, manifest says {:?}",
                        self.spec.name,
                        s.name,
                        l.element_count(),
                        s.shape
                    );
                }
                Ok(DeviceTensor::from_payload(
                    s.shape.clone(),
                    s.dtype,
                    XLA_DEVICE,
                    Rc::new(l),
                ))
            })
            .collect()
    }
}

impl Loaded {
    /// Execute with pre-staged literals; returns the decomposed output
    /// tuple as literals (no host conversion).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, manifest wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let result = self
            .exe
            .execute(inputs)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let buf = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .context("empty execution result")?;
        let lit = buf.to_literal_sync()?;
        // return_tuple=True at lowering: the root is always a tuple.
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Fetch one named output from a literal set as a host tensor.
    pub fn output_tensor(
        &self,
        outputs: &[xla::Literal],
        name: &str,
    ) -> Result<Tensor> {
        let idx = self.spec.output_index(name)?;
        literal_to_tensor(&outputs[idx], &self.spec.outputs[idx])
    }
}

//! PJRT engine: compile-once executable cache + typed execution.
//!
//! The XLA implementation of the [`Backend`]/[`Executable`] traits,
//! compiled only under the `xla` cargo feature. Loads AOT'd HLO text
//! from an `artifacts/` directory (produced by `make artifacts`),
//! compiles each artifact once per engine, and stages host tensors to
//! `xla::Literal`s at call boundaries.
//!
//! Pattern per `/opt/xla-example/load_hlo`: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Multi-output executables return a single tuple buffer which we
//! decompose on the host (PJRT does not untuple; DESIGN.md §2).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, IoSpec, Manifest};
use super::backend::{validate_inputs, Backend, Executable};
use crate::tensor::{DType, Tensor};
use crate::util::timer::Timer;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Loaded>>>,
    verbose: bool,
}

pub struct Loaded {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Open an artifact directory (`artifacts/` produced by `make artifacts`).
    pub fn from_dir<P: AsRef<Path>>(dir: P) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
            verbose: std::env::var("REPRO_VERBOSE").is_ok(),
        })
    }

    /// Load (compile) an artifact by manifest name; cached per engine.
    pub fn load(&self, name: &str) -> Result<Rc<Loaded>> {
        if let Some(hit) = self.cache.borrow().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {name}"))?;
        if self.verbose {
            eprintln!("[engine] compiled {name} in {:.0} ms", t.elapsed_ms());
        }
        let loaded = Rc::new(Loaded { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Backend for Engine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<Rc<dyn Executable>> {
        let loaded: Rc<dyn Executable> = Engine::load(self, name)?;
        Ok(loaded)
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }
}

/// Host tensor -> XLA literal (validates against the IoSpec).
pub fn tensor_to_literal(t: &Tensor, spec: &IoSpec) -> Result<xla::Literal> {
    super::backend::validate_tensor(t, spec, "stage")?;
    let ty = match spec.dtype {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &t.to_bytes())
        .with_context(|| format!("literal for {:?}", spec.name))
}

/// XLA literal -> host tensor (shape taken from the output IoSpec).
pub fn literal_to_tensor(lit: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let n = lit.element_count();
    if n != spec.numel() {
        bail!(
            "output {:?}: {} elements, manifest says {:?}",
            spec.name,
            n,
            spec.shape
        );
    }
    match spec.dtype {
        DType::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
        DType::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
    }
}

impl Executable for Loaded {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with host tensors; stages to literals at the boundary.
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        validate_inputs(&self.spec, inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .map(|(t, s)| tensor_to_literal(t, s))
            .collect::<Result<_>>()?;
        let out = self.run_literals(&lits)?;
        out.iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| literal_to_tensor(l, s))
            .collect()
    }
}

impl Loaded {
    /// Execute with pre-staged literals; returns the decomposed output
    /// tuple as literals (no host conversion).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, manifest wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let result = self
            .exe
            .execute(inputs)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let buf = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .context("empty execution result")?;
        let lit = buf.to_literal_sync()?;
        // return_tuple=True at lowering: the root is always a tuple.
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Fetch one named output from a literal set as a host tensor.
    pub fn output_tensor(
        &self,
        outputs: &[xla::Literal],
        name: &str,
    ) -> Result<Tensor> {
        let idx = self.spec.output_index(name)?;
        literal_to_tensor(&outputs[idx], &self.spec.outputs[idx])
    }
}

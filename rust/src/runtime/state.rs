//! Training state carried between `train_step` executions.
//!
//! Holds params / Adam-m / Adam-v as host [`Tensor`]s plus the float
//! step counter, and threads them through any [`Executable`] backend.
//! One call advances K optimizer steps (the artifact's inner
//! microbatch scan); the coordinator recomputes the LR schedule
//! between calls.

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, Role};
use super::backend::Executable;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct TrainState {
    /// params ++ m ++ v, in manifest feed order.
    tensors: Vec<Tensor>,
    pub step: f32,
    n_params: usize,
}

impl TrainState {
    /// Initialise from the artifact's init specs (params) and zeros
    /// (optimizer moments). Deterministic in `seed`.
    pub fn init(spec: &ArtifactSpec, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param => {
                    let init = io
                        .init
                        .as_ref()
                        .with_context(|| format!("param {} has no init", io.name))?;
                    tensors.push(Tensor::init(&io.shape, init, &mut rng));
                    n_params += 1;
                }
                Role::OptM | Role::OptV => {
                    tensors.push(Tensor::zeros(&io.shape, io.dtype));
                }
                _ => {}
            }
        }
        Ok(TrainState { tensors, step: 0.0, n_params })
    }

    /// Restore from named checkpoint tensors (see [`TrainState::to_tensors`]).
    pub fn from_tensors(
        spec: &ArtifactSpec,
        entries: &[(String, Tensor)],
    ) -> Result<TrainState> {
        let map: std::collections::BTreeMap<&str, &Tensor> =
            entries.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let mut tensors = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    let t = map.get(io.name.as_str()).with_context(|| {
                        format!("checkpoint missing tensor {:?}", io.name)
                    })?;
                    if t.shape != io.shape {
                        bail!(
                            "checkpoint tensor {:?}: shape {:?} != manifest {:?}",
                            io.name,
                            t.shape,
                            io.shape
                        );
                    }
                    tensors.push((*t).clone());
                    if io.role == Role::Param {
                        n_params += 1;
                    }
                }
                _ => {}
            }
        }
        let step = map
            .get("__step")
            .map(|t| t.scalar_value_f32())
            .transpose()?
            .unwrap_or(0.0);
        Ok(TrainState { tensors, step, n_params })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// One coordinator-side training call: feeds
    /// `params ++ m ++ v ++ step ++ lr ++ data...`, absorbs the updated
    /// state from the output tuple, returns the per-microbatch losses.
    pub fn train_call(
        &mut self,
        art: &dyn Executable,
        lr: f32,
        data: &[Tensor],
    ) -> Result<Vec<f32>> {
        let spec = art.spec();
        let n_state = self.tensors.len();
        let data_specs: Vec<_> = spec
            .inputs
            .iter()
            .filter(|i| i.role == Role::Data)
            .collect();
        if data.len() != data_specs.len() {
            bail!(
                "{}: {} data tensors given, manifest wants {}",
                spec.name,
                data.len(),
                data_specs.len()
            );
        }
        let step_t = Tensor::scalar_f32(self.step);
        let lr_t = Tensor::scalar_f32(lr);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(spec.inputs.len());
        let mut state_i = 0;
        let mut data_i = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    inputs.push(&self.tensors[state_i]);
                    state_i += 1;
                }
                Role::Scalar => {
                    inputs.push(if io.name == "step" { &step_t } else { &lr_t });
                }
                Role::Data => {
                    inputs.push(&data[data_i]);
                    data_i += 1;
                }
            }
        }
        if state_i != n_state {
            bail!(
                "{}: artifact has {state_i} state inputs, state holds {n_state} \
                 (mismatched arch/variant?)",
                spec.name
            );
        }
        let mut outputs = art.run(&inputs)?;
        // outputs: params ++ m ++ v ++ step ++ losses
        if outputs.len() != n_state + 2 {
            bail!(
                "{}: expected {} outputs, got {}",
                spec.name,
                n_state + 2,
                outputs.len()
            );
        }
        let losses_t = outputs.pop().unwrap();
        let step_t = outputs.pop().unwrap();
        self.step = step_t.scalar_value_f32()?;
        self.tensors = outputs;
        Ok(losses_t.as_f32()?.to_vec())
    }

    /// Borrow the parameter tensors (feed order) for eval executables
    /// that take only params + data.
    pub fn param_tensors(&self) -> &[Tensor] {
        &self.tensors[..self.n_params]
    }

    /// Export the full state as named host tensors for checkpointing.
    pub fn to_tensors(&self, spec: &ArtifactSpec) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        let mut i = 0;
        for io in &spec.inputs {
            if matches!(io.role, Role::Param | Role::OptM | Role::OptV) {
                if i >= self.tensors.len() {
                    bail!("state/spec mismatch exporting {:?}", io.name);
                }
                out.push((io.name.clone(), self.tensors[i].clone()));
                i += 1;
            }
        }
        out.push(("__step".to_string(), Tensor::scalar_f32(self.step)));
        Ok(out)
    }

    /// Export only the model parameters (paper's checkpoint-size metric
    /// counts weights, not optimizer moments).
    pub fn params_to_tensors(
        &self,
        spec: &ArtifactSpec,
    ) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        for (i, io) in spec.param_specs().into_iter().enumerate() {
            out.push((io.name.clone(), self.tensors[i].clone()));
        }
        Ok(out)
    }
}

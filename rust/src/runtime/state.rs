//! Training state carried between `train_step` executions — held as
//! **backend-resident** [`DeviceTensor`] handles.
//!
//! Params / Adam-m / Adam-v live on the executing backend: the native
//! backend wraps them zero-copy, the XLA backend keeps them alive as
//! literals, so a training loop stages the state **once** at init (or
//! checkpoint restore) and every subsequent `train_call` uploads only
//! the per-call batch and the two control scalars. Both the
//! transformer `train_step` (native layer-module autodiff or XLA) and
//! the MNIST probe drive their loops through this type. One call advances K
//! optimizer steps (the artifact's inner microbatch scan); the
//! coordinator recomputes the LR schedule between calls. Host copies
//! exist only at the edges: `to_tensors`/`params_to_tensors` download
//! for checkpointing, `from_tensors` uploads on restore.

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, Role};
use super::backend::{Backend, Executable};
use super::device::DeviceTensor;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct TrainState {
    /// params ++ m ++ v, in manifest feed order, backend-resident.
    tensors: Vec<DeviceTensor>,
    pub step: f32,
    n_params: usize,
}

impl TrainState {
    /// Initialise from the artifact's init specs (params) and zeros
    /// (optimizer moments), then upload everything once onto
    /// `backend`. Deterministic in `seed`.
    pub fn init(backend: &dyn Backend, spec: &ArtifactSpec, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param => {
                    let init = io
                        .init
                        .as_ref()
                        .with_context(|| format!("param {} has no init", io.name))?;
                    tensors.push(backend.upload(Tensor::init(&io.shape, init, &mut rng))?);
                    n_params += 1;
                }
                Role::OptM | Role::OptV => {
                    tensors.push(backend.alloc(&io.shape, io.dtype)?);
                }
                _ => {}
            }
        }
        Ok(TrainState { tensors, step: 0.0, n_params })
    }

    /// Restore from named checkpoint tensors (see [`TrainState::to_tensors`]);
    /// stages the state onto `backend` once.
    pub fn from_tensors(
        backend: &dyn Backend,
        spec: &ArtifactSpec,
        entries: &[(String, Tensor)],
    ) -> Result<TrainState> {
        let map: std::collections::BTreeMap<&str, &Tensor> =
            entries.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let mut tensors = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    let t = map.get(io.name.as_str()).with_context(|| {
                        format!("checkpoint missing tensor {:?}", io.name)
                    })?;
                    if t.shape != io.shape {
                        bail!(
                            "checkpoint tensor {:?}: shape {:?} != manifest {:?}",
                            io.name,
                            t.shape,
                            io.shape
                        );
                    }
                    tensors.push(backend.upload((*t).clone())?);
                    if io.role == Role::Param {
                        n_params += 1;
                    }
                }
                _ => {}
            }
        }
        let step = map
            .get("__step")
            .map(|t| t.scalar_value_f32())
            .transpose()?
            .unwrap_or(0.0);
        Ok(TrainState { tensors, step, n_params })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// One coordinator-side training call: binds the resident
    /// `params ++ m ++ v` handles, uploads only `step`/`lr` and the
    /// per-call data, absorbs the updated state as fresh resident
    /// handles, returns the per-microbatch losses.
    pub fn train_call(
        &mut self,
        backend: &dyn Backend,
        art: &dyn Executable,
        lr: f32,
        data: Vec<Tensor>,
    ) -> Result<Vec<f32>> {
        let spec = art.spec();
        let n_state = self.tensors.len();
        let n_data = spec.inputs.iter().filter(|i| i.role == Role::Data).count();
        if data.len() != n_data {
            bail!(
                "{}: {} data tensors given, manifest wants {}",
                spec.name,
                data.len(),
                n_data
            );
        }
        let step_t = backend.upload(Tensor::scalar_f32(self.step))?;
        let lr_t = backend.upload(Tensor::scalar_f32(lr))?;
        let data_dev: Vec<DeviceTensor> = data
            .into_iter()
            .map(|t| backend.upload(t))
            .collect::<Result<_>>()?;
        let mut inputs: Vec<&DeviceTensor> = Vec::with_capacity(spec.inputs.len());
        let mut state_i = 0;
        let mut data_i = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    if state_i >= n_state {
                        bail!(
                            "{}: more state inputs than the {n_state} held \
                             (mismatched arch/variant?)",
                            spec.name
                        );
                    }
                    inputs.push(&self.tensors[state_i]);
                    state_i += 1;
                }
                Role::Scalar => {
                    inputs.push(if io.name == "step" { &step_t } else { &lr_t });
                }
                Role::Data => {
                    inputs.push(&data_dev[data_i]);
                    data_i += 1;
                }
            }
        }
        if state_i != n_state {
            bail!(
                "{}: artifact has {state_i} state inputs, state holds {n_state} \
                 (mismatched arch/variant?)",
                spec.name
            );
        }
        let mut outputs = art.run_bound(&inputs)?;
        // outputs: params ++ m ++ v ++ step ++ losses
        if outputs.len() != n_state + 2 {
            bail!(
                "{}: expected {} outputs, got {}",
                spec.name,
                n_state + 2,
                outputs.len()
            );
        }
        let losses_t = backend.take(outputs.pop().unwrap())?;
        let step_t = backend.take(outputs.pop().unwrap())?;
        self.step = step_t.scalar_value_f32()?;
        // updated params/m/v stay resident; old handles drop here
        self.tensors = outputs;
        Ok(losses_t.as_f32()?.to_vec())
    }

    /// Borrow the resident parameter handles (feed order) for eval
    /// executables that take only params + data — bind them with
    /// [`crate::runtime::Bindings::bind_role`].
    pub fn param_handles(&self) -> &[DeviceTensor] {
        &self.tensors[..self.n_params]
    }

    /// Total bytes held resident by this state (params + moments).
    pub fn resident_bytes(&self) -> usize {
        self.tensors.iter().map(DeviceTensor::size_bytes).sum()
    }

    /// Export the full state as named host tensors for checkpointing
    /// (downloads from the backend).
    pub fn to_tensors(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
    ) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        let mut i = 0;
        for io in &spec.inputs {
            if matches!(io.role, Role::Param | Role::OptM | Role::OptV) {
                if i >= self.tensors.len() {
                    bail!("state/spec mismatch exporting {:?}", io.name);
                }
                out.push((io.name.clone(), backend.download(&self.tensors[i])?));
                i += 1;
            }
        }
        out.push(("__step".to_string(), Tensor::scalar_f32(self.step)));
        Ok(out)
    }

    /// Export only the model parameters (paper's checkpoint-size metric
    /// counts weights, not optimizer moments).
    pub fn params_to_tensors(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
    ) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        for (i, io) in spec.param_specs().into_iter().enumerate() {
            out.push((io.name.clone(), backend.download(&self.tensors[i])?));
        }
        Ok(out)
    }
}

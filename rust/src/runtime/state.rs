//! Training state carried between `train_step` executions.
//!
//! Holds params / Adam-m / Adam-v as staged `xla::Literal`s plus the
//! float step counter. One PJRT call advances K optimizer steps (the
//! artifact's inner microbatch scan); between calls the state literals
//! are threaded straight back in — no host `Vec<f32>` round trip
//! (DESIGN.md §8).

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, Role};
use super::engine::{literal_to_tensor, tensor_to_literal, Loaded};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct TrainState {
    /// params ++ m ++ v, in manifest feed order.
    lits: Vec<xla::Literal>,
    pub step: f32,
    n_params: usize,
}

impl TrainState {
    /// Initialise from the artifact's init specs (params) and zeros
    /// (optimizer moments). Deterministic in `seed`.
    pub fn init(spec: &ArtifactSpec, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut lits = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param => {
                    let init = io
                        .init
                        .as_ref()
                        .with_context(|| format!("param {} has no init", io.name))?;
                    let t = Tensor::init(&io.shape, init, &mut rng);
                    lits.push(tensor_to_literal(&t, io)?);
                    n_params += 1;
                }
                Role::OptM | Role::OptV => {
                    let t = Tensor::zeros(&io.shape, io.dtype);
                    lits.push(tensor_to_literal(&t, io)?);
                }
                _ => {}
            }
        }
        Ok(TrainState { lits, step: 0.0, n_params })
    }

    /// Restore from named checkpoint tensors (see [`TrainState::to_tensors`]).
    pub fn from_tensors(
        spec: &ArtifactSpec,
        entries: &[(String, Tensor)],
    ) -> Result<TrainState> {
        let map: std::collections::BTreeMap<&str, &Tensor> =
            entries.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let mut lits = Vec::new();
        let mut n_params = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    let t = map.get(io.name.as_str()).with_context(|| {
                        format!("checkpoint missing tensor {:?}", io.name)
                    })?;
                    lits.push(tensor_to_literal(t, io)?);
                    if io.role == Role::Param {
                        n_params += 1;
                    }
                }
                _ => {}
            }
        }
        let step = map
            .get("__step")
            .map(|t| t.scalar_value_f32())
            .transpose()?
            .unwrap_or(0.0);
        Ok(TrainState { lits, step, n_params })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// One coordinator-side training call: feeds
    /// `params ++ m ++ v ++ step ++ lr ++ data...`, absorbs the updated
    /// state from the output tuple, returns the per-microbatch losses.
    pub fn train_call(
        &mut self,
        art: &Loaded,
        lr: f32,
        data: &[Tensor],
    ) -> Result<Vec<f32>> {
        let spec = &art.spec;
        let n_state = self.lits.len();
        let data_specs: Vec<_> = spec
            .inputs
            .iter()
            .filter(|i| i.role == Role::Data)
            .collect();
        if data.len() != data_specs.len() {
            bail!(
                "{}: {} data tensors given, manifest wants {}",
                spec.name,
                data.len(),
                data_specs.len()
            );
        }
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(spec.inputs.len());
        let step_lit = xla::Literal::scalar(self.step);
        let lr_lit = xla::Literal::scalar(lr);
        let data_lits: Vec<xla::Literal> = data
            .iter()
            .zip(&data_specs)
            .map(|(t, s)| tensor_to_literal(t, s))
            .collect::<Result<_>>()?;
        let mut state_i = 0;
        let mut data_i = 0;
        for io in &spec.inputs {
            match io.role {
                Role::Param | Role::OptM | Role::OptV => {
                    inputs.push(&self.lits[state_i]);
                    state_i += 1;
                }
                Role::Scalar => {
                    inputs.push(if io.name == "step" { &step_lit } else { &lr_lit });
                }
                Role::Data => {
                    inputs.push(&data_lits[data_i]);
                    data_i += 1;
                }
            }
        }
        if state_i != n_state {
            bail!(
                "{}: artifact has {state_i} state inputs, state holds {n_state} \
                 (mismatched arch/variant?)",
                spec.name
            );
        }
        let mut outputs = art.run_literals(&inputs)?;
        // outputs: params ++ m ++ v ++ step ++ losses
        if outputs.len() != n_state + 2 {
            bail!(
                "{}: expected {} outputs, got {}",
                spec.name,
                n_state + 2,
                outputs.len()
            );
        }
        let losses_lit = outputs.pop().unwrap();
        let step_out = outputs.pop().unwrap();
        self.step = step_out.to_vec::<f32>()?[0];
        self.lits = outputs;
        let losses = losses_lit.to_vec::<f32>()?;
        Ok(losses)
    }

    /// Borrow the parameter literals (feed order) for eval executables
    /// that take only params + data.
    pub fn param_literals(&self) -> &[xla::Literal] {
        &self.lits[..self.n_params]
    }

    /// Export the full state as named host tensors for checkpointing.
    pub fn to_tensors(&self, spec: &ArtifactSpec) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        let mut i = 0;
        for io in &spec.inputs {
            if matches!(io.role, Role::Param | Role::OptM | Role::OptV) {
                out.push((io.name.clone(), literal_to_tensor(&self.lits[i], io)?));
                i += 1;
            }
        }
        out.push(("__step".to_string(), Tensor::scalar_f32(self.step)));
        Ok(out)
    }

    /// Export only the model parameters (paper's checkpoint-size metric
    /// counts weights, not optimizer moments).
    pub fn params_to_tensors(
        &self,
        spec: &ArtifactSpec,
    ) -> Result<Vec<(String, Tensor)>> {
        let mut out = Vec::new();
        for (i, io) in spec.param_specs().into_iter().enumerate() {
            out.push((io.name.clone(), literal_to_tensor(&self.lits[i], io)?));
        }
        Ok(out)
    }
}

//! In-process artifact catalog: the native backend's manifest.
//!
//! Mirrors `python/compile/aot.py` — same artifact names, same
//! positional input/output contracts, same init specs and meta — but
//! built in pure Rust, so the native backend serves the full inventory
//! with no files on disk. The python emitter and this module are the
//! twin sources of the L2→L3 contract; keep them in sync.

use std::collections::BTreeMap;

use crate::tensor::{DType, InitSpec};
use crate::util::json::{num, obj, s, Json};

use super::artifact::{AdamCfg, ArchCfg, ArtifactSpec, IoSpec, Manifest, Role, VariantCfg};

pub mod mmap;

pub const TRAIN_BATCH: usize = 8;
pub const TRAIN_MICROBATCHES: usize = 8;
pub const EVAL_BATCH: usize = 8;

pub const MNIST_HIDDEN: usize = 256;
pub const MNIST_BATCH: usize = 64;
pub const MNIST_CLASSES: usize = 10;
pub const MNIST_IN: usize = 784;
pub const MNIST_K: usize = 4;

/// ff-micro geometries: (label, d_model, d_ff, tokens per minibatch).
pub const FF_GEOMETRIES: [(&str, usize, usize, usize); 3] = [
    ("opt125m-ff", 768, 3072, 512),
    ("opt350m-ff", 1024, 4096, 256),
    ("pythia160m-ff", 768, 3072, 512),
];

/// Figure 6 width sweep: ff geometry (w, 4w) at these widths.
pub const WIDTH_SWEEP: [usize; 4] = [256, 512, 1024, 2048];
pub const WIDTH_SWEEP_TOKENS: usize = 128;
/// Weight-stream precisions the native backend can execute
/// (`--precision`); benches sweep these arms.
pub const PRECISIONS: [&str; 3] = ["f32", "bf16", "i8"];

pub const ADAM: AdamCfg = AdamCfg { b1: 0.9, b2: 0.999, eps: 1e-8, grad_clip: 1.0 };

/// One parameter's (name, shape, init) — the unit of the contract.
pub type ParamSpec = (String, Vec<usize>, InitSpec);

fn uniform(bound: f64) -> InitSpec {
    InitSpec::Uniform { bound: bound as f32 }
}

/// Paper-scale architecture names map onto the mini reproductions the
/// catalog actually ships (`repro train --arch opt125m` runs the
/// `opt-mini` config); unknown names pass through untouched so
/// manifest errors stay actionable.
pub fn canonical_arch(name: &str) -> &str {
    match name {
        "opt125m" | "opt-125m" => "opt-mini",
        "opt350m" | "opt-350m" => "opt-mid",
        "pythia160m" | "pythia-160m" => "pythia-mini",
        other => other,
    }
}

/// Variant shorthand: bare `dyad` means the paper's default DYAD-IT.
pub fn canonical_variant(name: &str) -> &str {
    match name {
        "dyad" => "dyad_it",
        other => other,
    }
}

pub fn archs() -> BTreeMap<String, ArchCfg> {
    let mut m = BTreeMap::new();
    m.insert(
        "opt-mini".to_string(),
        ArchCfg {
            vocab: 512,
            d_model: 256,
            d_ff: 1024,
            n_layers: 4,
            n_heads: 8,
            seq: 128,
            parallel_residual: false,
        },
    );
    m.insert(
        "pythia-mini".to_string(),
        ArchCfg {
            vocab: 512,
            d_model: 256,
            d_ff: 1024,
            n_layers: 4,
            n_heads: 8,
            seq: 128,
            parallel_residual: true,
        },
    );
    m.insert(
        "opt-mid".to_string(),
        ArchCfg {
            vocab: 512,
            d_model: 384,
            d_ff: 1536,
            n_layers: 6,
            n_heads: 8,
            seq: 128,
            parallel_residual: false,
        },
    );
    m
}

pub fn variants() -> BTreeMap<String, VariantCfg> {
    let mut m = BTreeMap::new();
    let mk = |kind: &str, dv: &str, nd: usize, sched: &[&str]| VariantCfg {
        kind: kind.to_string(),
        dyad_variant: dv.to_string(),
        n_dyad: nd,
        layer_schedule: sched.iter().map(|x| x.to_string()).collect(),
    };
    m.insert("dense".to_string(), mk("dense", "it", 4, &[]));
    m.insert("dyad_it".to_string(), mk("dyad", "it", 4, &[]));
    m.insert("dyad_ot".to_string(), mk("dyad", "ot", 4, &[]));
    m.insert("dyad_dt".to_string(), mk("dyad", "dt", 4, &[]));
    m.insert("dyad_it_cat".to_string(), mk("dyad", "it_cat", 4, &[]));
    m.insert("dyad_it_8".to_string(), mk("dyad", "it", 8, &[]));
    m.insert(
        "dyad_hetero".to_string(),
        mk("dyad", "it", 4, &["it", "ot", "dt"]),
    );
    m
}

/// Specs for one ff linear layer under the chosen variant
/// (`model.py::_ff_linear_specs`).
pub fn ff_linear_specs(
    prefix: &str,
    f_in: usize,
    f_out: usize,
    var: &VariantCfg,
) -> Vec<ParamSpec> {
    let k = 1.0 / (f_in as f64).sqrt();
    if var.kind == "dense" {
        return vec![
            (format!("{prefix}.w"), vec![f_out, f_in], uniform(k)),
            (format!("{prefix}.b"), vec![f_out], uniform(k)),
        ];
    }
    let nd = var.n_dyad;
    let (n_in, n_out) = (f_in / nd, f_out / nd);
    vec![
        (format!("{prefix}.wl"), vec![nd, n_out, n_in], uniform(k)),
        (format!("{prefix}.wu"), vec![nd, n_out, n_in], uniform(k)),
        (format!("{prefix}.b"), vec![f_out], uniform(k)),
    ]
}

/// Ordered parameter list for the whole LM (`model.py::param_specs`).
pub fn model_param_specs(arch: &ArchCfg, var: &VariantCfg) -> Vec<ParamSpec> {
    let (d, ff) = (arch.d_model, arch.d_ff);
    let ka = 1.0 / (d as f64).sqrt();
    let mut specs: Vec<ParamSpec> = vec![
        ("tok_emb".into(), vec![arch.vocab, d], InitSpec::Normal { std: 0.02 }),
        ("pos_emb".into(), vec![arch.seq, d], InitSpec::Normal { std: 0.02 }),
    ];
    for l in 0..arch.n_layers {
        let p = format!("layer{l}");
        specs.push((format!("{p}.ln1.scale"), vec![d], InitSpec::Ones));
        specs.push((format!("{p}.ln1.bias"), vec![d], InitSpec::Zeros));
        for m in ["wq", "wk", "wv", "wo"] {
            specs.push((format!("{p}.attn.{m}"), vec![d, d], uniform(ka)));
            specs.push((format!("{p}.attn.{m}_b"), vec![d], InitSpec::Zeros));
        }
        specs.push((format!("{p}.ln2.scale"), vec![d], InitSpec::Ones));
        specs.push((format!("{p}.ln2.bias"), vec![d], InitSpec::Zeros));
        specs.extend(ff_linear_specs(&format!("{p}.ff.fc1"), d, ff, var));
        specs.extend(ff_linear_specs(&format!("{p}.ff.fc2"), ff, d, var));
    }
    specs.push(("final_ln.scale".into(), vec![d], InitSpec::Ones));
    specs.push(("final_ln.bias".into(), vec![d], InitSpec::Zeros));
    specs
}

/// ff-micro parameter list (`model.py::ff_param_specs`).
pub fn ff_param_specs(d: usize, ff: usize, var: &VariantCfg) -> Vec<ParamSpec> {
    let mut specs = ff_linear_specs("fc1", d, ff, var);
    specs.extend(ff_linear_specs("fc2", ff, d, var));
    specs
}

/// MNIST MLP parameter list (`mnist.py::mnist_param_specs`).
pub fn mnist_param_specs(var: &VariantCfg) -> Vec<ParamSpec> {
    let h = MNIST_HIDDEN;
    let kh = 1.0 / (h as f64).sqrt();
    let mut specs = ff_linear_specs("fc1", MNIST_IN, h, var);
    specs.extend(ff_linear_specs("fc2", h, h, var));
    specs.push(("head.w".into(), vec![MNIST_CLASSES, h], uniform(kh)));
    specs.push(("head.b".into(), vec![MNIST_CLASSES], uniform(kh)));
    specs
}

fn io(name: &str, shape: &[usize], dtype: DType, role: Role, init: Option<InitSpec>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype,
        role,
        init,
    }
}

fn param_inputs(specs: &[ParamSpec]) -> Vec<IoSpec> {
    specs
        .iter()
        .map(|(n, sh, init)| io(n, sh, DType::F32, Role::Param, Some(init.clone())))
        .collect()
}

/// Adam m/v mirrors of the params, zero-init (`aot.py::opt_state_inputs`).
fn opt_inputs(specs: &[ParamSpec]) -> Vec<IoSpec> {
    let mut out = Vec::with_capacity(2 * specs.len());
    for (prefix, role) in [("m.", Role::OptM), ("v.", Role::OptV)] {
        for (n, sh, _) in specs {
            out.push(io(&format!("{prefix}{n}"), sh, DType::F32, role, Some(InitSpec::Zeros)));
        }
    }
    out
}

fn f32_out(name: &str, shape: &[usize]) -> IoSpec {
    io(name, shape, DType::F32, Role::Data, None)
}

/// State-machine outputs of a train-step artifact:
/// params ++ m ++ v ++ step ++ losses(k).
fn train_outputs(specs: &[ParamSpec], k: usize) -> Vec<IoSpec> {
    let mut outs = Vec::with_capacity(3 * specs.len() + 2);
    for prefix in ["", "m.", "v."] {
        for (n, sh, _) in specs {
            outs.push(f32_out(&format!("{prefix}{n}"), sh));
        }
    }
    outs.push(f32_out("step", &[]));
    outs.push(f32_out("losses", &[k]));
    outs
}

fn meta_kv(pairs: Vec<(&str, Json)>) -> Json {
    obj(pairs)
}

fn model_artifacts(
    out: &mut Vec<ArtifactSpec>,
    arch_name: &str,
    arch: &ArchCfg,
    variant_names: &[&str],
    variants: &BTreeMap<String, VariantCfg>,
) {
    let (bt, st, k_full) = (TRAIN_BATCH, arch.seq, TRAIN_MICROBATCHES);
    let eb = EVAL_BATCH;
    for vname in variant_names {
        let var = &variants[*vname];
        let specs = model_param_specs(arch, var);
        let params_in = param_inputs(&specs);
        let base = format!("{arch_name}/{vname}");
        let meta_common = |extra: Vec<(&str, Json)>| {
            let mut kv = vec![
                ("batch", num(eb as f64)),
                ("seq", num(st as f64)),
                ("arch", s(arch_name)),
                ("variant", s(vname)),
            ];
            kv.extend(extra);
            meta_kv(kv)
        };

        for k in [k_full, 1] {
            let mut inputs = params_in.clone();
            inputs.extend(opt_inputs(&specs));
            inputs.push(io("step", &[], DType::F32, Role::Scalar, None));
            inputs.push(io("lr", &[], DType::F32, Role::Scalar, None));
            inputs.push(io("tokens", &[k, bt, st], DType::I32, Role::Data, None));
            out.push(ArtifactSpec {
                name: format!("{base}/train_k{k}"),
                file: "<native>".into(),
                kind: "train_step".into(),
                inputs,
                outputs: train_outputs(&specs, k),
                meta: meta_kv(vec![
                    ("k_micro", num(k as f64)),
                    ("batch", num(bt as f64)),
                    ("seq", num(st as f64)),
                    ("arch", s(arch_name)),
                    ("variant", s(vname)),
                ]),
            });
        }

        let mut score_in = params_in.clone();
        score_in.push(io("tokens", &[eb, st], DType::I32, Role::Data, None));
        score_in.push(io("mask", &[eb, st], DType::F32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("{base}/score"),
            file: "<native>".into(),
            kind: "score".into(),
            inputs: score_in.clone(),
            outputs: vec![f32_out("sum_logp", &[eb]), f32_out("n_tok", &[eb])],
            meta: meta_common(vec![]),
        });
        out.push(ArtifactSpec {
            name: format!("{base}/features"),
            file: "<native>".into(),
            kind: "features".into(),
            inputs: score_in,
            outputs: vec![f32_out("features", &[eb, arch.d_model])],
            meta: meta_common(vec![]),
        });
        let mut nl_in = params_in.clone();
        nl_in.push(io("tokens", &[eb, st], DType::I32, Role::Data, None));
        nl_in.push(io("lengths", &[eb], DType::I32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("{base}/next_logits"),
            file: "<native>".into(),
            kind: "next_logits".into(),
            inputs: nl_in,
            outputs: vec![f32_out("logits", &[eb, arch.vocab])],
            meta: meta_common(vec![]),
        });
        // incremental decode: the K/V cache is a resident handle from
        // `Executable::make_decode_cache` bound once to `kv_cache`
        // (shape = `n_layers · 2 · lanes · seq · d` floats, the
        // per-worker cache memory cost); per step only the token /
        // reset ids and one logits row per lane cross the boundary.
        // `tokens[lane] < 0` = idle lane, `resets[lane] != 0` = free
        // the lane before feeding (continuous-batching admission).
        let mut dec_in = params_in.clone();
        dec_in.push(io(
            "kv_cache",
            &[arch.n_layers, 2, eb, st, arch.d_model],
            DType::F32,
            Role::Data,
            None,
        ));
        dec_in.push(io("tokens", &[eb], DType::I32, Role::Data, None));
        dec_in.push(io("resets", &[eb], DType::I32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("{base}/decode_step"),
            file: "<native>".into(),
            kind: "decode_step".into(),
            inputs: dec_in,
            outputs: vec![f32_out("logits", &[eb, arch.vocab])],
            meta: meta_common(vec![]),
        });
        let mut el_in = params_in.clone();
        el_in.push(io("tokens", &[eb, st], DType::I32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("{base}/eval_loss"),
            file: "<native>".into(),
            kind: "eval_loss".into(),
            inputs: el_in,
            outputs: vec![f32_out("loss", &[])],
            meta: meta_common(vec![]),
        });
    }
}

fn ff_artifacts(
    out: &mut Vec<ArtifactSpec>,
    label: &str,
    d: usize,
    ff: usize,
    tokens: usize,
    variant_names: &[&str],
    variants: &BTreeMap<String, VariantCfg>,
) {
    for vname in variant_names {
        let var = &variants[*vname];
        let specs = ff_param_specs(d, ff, var);
        let params_in = param_inputs(&specs);
        let meta = meta_kv(vec![
            ("d_model", num(d as f64)),
            ("d_ff", num(ff as f64)),
            ("tokens", num(tokens as f64)),
            ("variant", s(vname)),
        ]);
        let mut fwd_in = params_in.clone();
        fwd_in.push(io("x", &[tokens, d], DType::F32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("ff/{label}/{vname}/fwd"),
            file: "<native>".into(),
            kind: "ff_fwd".into(),
            inputs: fwd_in.clone(),
            outputs: vec![f32_out("y", &[tokens, d])],
            meta: meta.clone(),
        });
        let mut fb_in = fwd_in;
        fb_in.push(io("ct", &[tokens, d], DType::F32, Role::Data, None));
        let mut fb_out = vec![f32_out("loss", &[])];
        for (n, sh, _) in &specs {
            fb_out.push(f32_out(&format!("g.{n}"), sh));
        }
        out.push(ArtifactSpec {
            name: format!("ff/{label}/{vname}/fwdbwd"),
            file: "<native>".into(),
            kind: "ff_fwdbwd".into(),
            inputs: fb_in,
            outputs: fb_out,
            meta,
        });
    }
}

fn mnist_artifacts(out: &mut Vec<ArtifactSpec>, variants: &BTreeMap<String, VariantCfg>) {
    let (b, k) = (MNIST_BATCH, MNIST_K);
    for vname in ["dense", "dyad_it"] {
        let var = &variants[vname];
        let specs = mnist_param_specs(var);
        let params_in = param_inputs(&specs);
        let mut train_in = params_in.clone();
        train_in.extend(opt_inputs(&specs));
        train_in.push(io("step", &[], DType::F32, Role::Scalar, None));
        train_in.push(io("lr", &[], DType::F32, Role::Scalar, None));
        train_in.push(io("images", &[k, b, MNIST_IN], DType::F32, Role::Data, None));
        train_in.push(io("labels", &[k, b], DType::I32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("mnist/{vname}/train_k{k}"),
            file: "<native>".into(),
            kind: "mnist_train".into(),
            inputs: train_in,
            outputs: train_outputs(&specs, k),
            meta: meta_kv(vec![
                ("k_micro", num(k as f64)),
                ("batch", num(b as f64)),
                ("variant", s(vname)),
            ]),
        });
        let mut acc_in = params_in.clone();
        acc_in.push(io("images", &[b, MNIST_IN], DType::F32, Role::Data, None));
        acc_in.push(io("labels", &[b], DType::I32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("mnist/{vname}/accuracy"),
            file: "<native>".into(),
            kind: "mnist_accuracy".into(),
            inputs: acc_in,
            outputs: vec![io("n_correct", &[], DType::I32, Role::Data, None)],
            meta: meta_kv(vec![("batch", num(b as f64)), ("variant", s(vname))]),
        });
        let mut hf_in = params_in.clone();
        hf_in.push(io("x", &[b, MNIST_IN], DType::F32, Role::Data, None));
        out.push(ArtifactSpec {
            name: format!("mnist/{vname}/hidden_fwd"),
            file: "<native>".into(),
            kind: "mnist_hidden_fwd".into(),
            inputs: hf_in,
            outputs: vec![f32_out("h", &[b, MNIST_HIDDEN])],
            meta: meta_kv(vec![("batch", num(b as f64)), ("variant", s(vname))]),
        });
    }
}

/// The full native-backend manifest (same inventory as `aot.py`, minus
/// the Pallas validation artifact, which is PJRT-only by nature).
pub fn native_manifest() -> Manifest {
    let archs = archs();
    let variants = variants();
    let mut artifacts = Vec::new();
    model_artifacts(
        &mut artifacts,
        "opt-mini",
        &archs["opt-mini"],
        &["dense", "dyad_it", "dyad_it_cat", "dyad_ot", "dyad_dt", "dyad_it_8", "dyad_hetero"],
        &variants,
    );
    model_artifacts(
        &mut artifacts,
        "pythia-mini",
        &archs["pythia-mini"],
        &["dense", "dyad_it", "dyad_it_8"],
        &variants,
    );
    model_artifacts(&mut artifacts, "opt-mid", &archs["opt-mid"], &["dense", "dyad_it"], &variants);

    let ff_variants = ["dense", "dyad_it", "dyad_ot", "dyad_dt", "dyad_it_8", "dyad_it_cat"];
    for (label, d, ff, toks) in FF_GEOMETRIES {
        ff_artifacts(&mut artifacts, label, d, ff, toks, &ff_variants, &variants);
    }
    for w in WIDTH_SWEEP {
        ff_artifacts(
            &mut artifacts,
            &format!("width{w}"),
            w,
            4 * w,
            WIDTH_SWEEP_TOKENS,
            &["dense", "dyad_it", "dyad_it_cat", "dyad_it_8"],
            &variants,
        );
    }
    mnist_artifacts(&mut artifacts, &variants);
    Manifest::from_parts(ADAM, archs, variants, artifacts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_has_expected_inventory() {
        let m = native_manifest();
        // 12 (arch, variant) pairs x 7 model artifacts
        // + (3 geos x 6 + 4 widths x 4) ff variants x 2 artifacts
        // + 2 mnist variants x 3 artifacts
        assert_eq!(m.artifacts.len(), 12 * 7 + (3 * 6 + 4 * 4) * 2 + 2 * 3);
        for name in [
            "opt-mini/dyad_it/train_k8",
            "opt-mini/dense/score",
            "opt-mini/dyad_it_cat/train_k8",
            "ff/width1024/dyad_it_cat/fwd",
            "pythia-mini/dyad_it_8/eval_loss",
            "opt-mid/dyad_it/next_logits",
            "opt-mini/dyad_it/decode_step",
            "ff/opt125m-ff/dyad_it_cat/fwdbwd",
            "ff/width2048/dyad_it_8/fwd",
            "mnist/dyad_it/train_k4",
            "mnist/dense/hidden_fwd",
        ] {
            assert!(m.artifact(name).is_ok(), "missing {name}");
        }
        assert_eq!(m.arch("opt-mini").unwrap().d_model, 256);
        assert_eq!(m.variant("dyad_it_8").unwrap().n_dyad, 8);
        assert_eq!(m.variant("dyad_hetero").unwrap().layer_schedule.len(), 3);
    }

    #[test]
    fn param_accounting_matches_paper() {
        // dense - dyad_4 = ff weights reduced to 2/n_dyad of dense
        let m = native_manifest();
        let dense = m.artifact("opt-mini/dense/train_k1").unwrap().param_count();
        let dyad = m.artifact("opt-mini/dyad_it/train_k1").unwrap().param_count();
        let dyad8 = m.artifact("opt-mini/dyad_it_8/train_k1").unwrap().param_count();
        let arch = m.arch("opt-mini").unwrap();
        let ff_w = 2 * arch.n_layers * arch.d_model * arch.d_ff;
        assert_eq!(dense - dyad, ff_w - 2 * ff_w / 4);
        assert_eq!(dense - dyad8, ff_w - 2 * ff_w / 8);
    }

    #[test]
    fn paper_scale_aliases_resolve() {
        let m = native_manifest();
        assert!(m.arch(canonical_arch("opt125m")).is_ok());
        assert!(m.arch(canonical_arch("opt350m")).is_ok());
        assert!(m.arch(canonical_arch("pythia160m")).is_ok());
        assert!(m.variant(canonical_variant("dyad")).is_ok());
        // unknown names pass through (and then fail actionably)
        assert_eq!(canonical_arch("opt-mini"), "opt-mini");
        assert_eq!(canonical_variant("dyad_ot"), "dyad_ot");
        assert!(m.arch(canonical_arch("gpt5")).is_err());
    }

    /// The in-process manifest serializes to the manifest.json wire
    /// format and parses back identically — the same artifact count,
    /// and per-artifact contracts that survive the trip.
    #[test]
    fn manifest_json_roundtrips() {
        let m = native_manifest();
        let text = m.to_json().to_string();
        let m2 = Manifest::parse(&text).expect("re-parse serialized manifest");
        assert_eq!(m.artifacts.len(), m2.artifacts.len());
        assert_eq!(m.adam.b1, m2.adam.b1);
        assert_eq!(m.adam.grad_clip, m2.adam.grad_clip);
        assert_eq!(m.archs.len(), m2.archs.len());
        assert_eq!(m.variants.len(), m2.variants.len());
        for (a, b) in m.artifacts.iter().zip(&m2.artifacts) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.inputs.len(), b.inputs.len(), "{}", a.name);
            assert_eq!(a.outputs.len(), b.outputs.len(), "{}", a.name);
        }
    }

    #[test]
    fn train_artifact_contract_shape() {
        let m = native_manifest();
        let a = m.artifact("mnist/dyad_it/train_k4").unwrap();
        let n_params = a.param_specs().len();
        // inputs: params + m + v + step + lr + images + labels
        assert_eq!(a.inputs.len(), 3 * n_params + 4);
        // outputs: params + m + v + step + losses
        assert_eq!(a.outputs.len(), 3 * n_params + 2);
        assert_eq!(a.meta_usize("k_micro").unwrap(), 4);
        assert_eq!(a.meta_usize("batch").unwrap(), 64);
    }
}

//! Manifest parsing: the typed view of `artifacts/manifest.json`.
//!
//! The manifest is the single source of truth for the L2→L3 contract:
//! positional input order, parameter init specs, output layout, and the
//! architecture/variant dictionaries. Everything is validated here so
//! downstream code can index confidently.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, InitSpec};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Model parameter (has an init spec; checkpointed).
    Param,
    /// Adam first moment (zero-init; checkpointed).
    OptM,
    /// Adam second moment (zero-init; checkpointed).
    OptV,
    /// Scalar control input (step, lr).
    Scalar,
    /// Per-call data (tokens, masks, images...).
    Data,
}

impl Role {
    fn from_str(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "scalar" => Role::Scalar,
            "data" => Role::Data,
            _ => bail!("unknown role {s:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Role::Param => "param",
            Role::OptM => "opt_m",
            Role::OptV => "opt_v",
            Role::Scalar => "scalar",
            Role::Data => "data",
        }
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
    pub init: Option<InitSpec>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    pub fn inputs_with_role(&self, role: Role) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|i| i.role == role).collect()
    }

    /// Names+shapes of the model parameters, in feed order.
    pub fn param_specs(&self) -> Vec<&IoSpec> {
        self.inputs_with_role(Role::Param)
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .with_context(|| format!("{}: no input named {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .with_context(|| format!("{}: no output named {name:?}", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta.req(key)?.as_usize()
    }

    /// Total parameter count (the paper's "# Params" metric).
    pub fn param_count(&self) -> usize {
        self.param_specs().iter().map(|p| p.numel()).sum()
    }

    /// Bytes of f32 parameter storage this artifact's model needs —
    /// what one resident weight copy costs (a serve worker's heap
    /// copy, or the data section of a DYW1 weight map before
    /// alignment padding).
    pub fn param_bytes(&self) -> u64 {
        self.param_count() as u64 * std::mem::size_of::<f32>() as u64
    }
}

#[derive(Debug, Clone, Copy)]
pub struct AdamCfg {
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
    pub grad_clip: f64,
}

#[derive(Debug, Clone)]
pub struct ArchCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq: usize,
    pub parallel_residual: bool,
}

impl ArchCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

#[derive(Debug, Clone)]
pub struct VariantCfg {
    pub kind: String,
    pub dyad_variant: String,
    pub n_dyad: usize,
    /// §4 heterogeneous schedules: layer `l` uses
    /// `layer_schedule[l % len]` as its dyad variant when non-empty
    /// (resolution lives in `runtime::native::VariantSpec::for_layer`).
    pub layer_schedule: Vec<String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub adam: AdamCfg,
    pub archs: BTreeMap<String, ArchCfg>,
    pub variants: BTreeMap<String, VariantCfg>,
    pub artifacts: Vec<ArtifactSpec>,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    /// Assemble a manifest from in-process parts (the native backend's
    /// `runtime::catalog` builds one without any files on disk).
    pub fn from_parts(
        adam: AdamCfg,
        archs: BTreeMap<String, ArchCfg>,
        variants: BTreeMap<String, VariantCfg>,
        artifacts: Vec<ArtifactSpec>,
    ) -> Manifest {
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Manifest { adam, archs, variants, artifacts, by_name }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json")?;
        let version = j.req("version")?.as_usize()?;
        if version != 1 {
            bail!("manifest version {version} unsupported");
        }
        let adam = {
            let a = j.req("adam")?;
            AdamCfg {
                b1: a.req("b1")?.as_f64()?,
                b2: a.req("b2")?.as_f64()?,
                eps: a.req("eps")?.as_f64()?,
                grad_clip: a.req("grad_clip")?.as_f64()?,
            }
        };
        let mut archs = BTreeMap::new();
        for (name, a) in j.req("archs")?.as_obj()? {
            archs.insert(
                name.clone(),
                ArchCfg {
                    vocab: a.req("vocab")?.as_usize()?,
                    d_model: a.req("d_model")?.as_usize()?,
                    d_ff: a.req("d_ff")?.as_usize()?,
                    n_layers: a.req("n_layers")?.as_usize()?,
                    n_heads: a.req("n_heads")?.as_usize()?,
                    seq: a.req("seq")?.as_usize()?,
                    parallel_residual: a.req("parallel_residual")?.as_bool()?,
                },
            );
        }
        let mut variants = BTreeMap::new();
        for (name, v) in j.req("variants")?.as_obj()? {
            variants.insert(
                name.clone(),
                VariantCfg {
                    kind: v.req("kind")?.as_str()?.to_string(),
                    dyad_variant: v.req("dyad_variant")?.as_str()?.to_string(),
                    n_dyad: v.req("n_dyad")?.as_usize()?,
                    layer_schedule: match v.get("layer_schedule") {
                        Some(ls) => ls
                            .as_arr()?
                            .iter()
                            .map(|x| Ok(x.as_str()?.to_string()))
                            .collect::<Result<Vec<_>>>()?,
                        None => Vec::new(),
                    },
                },
            );
        }
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr()? {
            artifacts.push(parse_artifact(a)?);
        }
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Manifest { adam, archs, variants, artifacts, by_name })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.by_name
            .get(name)
            .map(|&i| &self.artifacts[i])
            .with_context(|| {
                let mut close: Vec<_> = self
                    .by_name
                    .keys()
                    .filter(|k| k.contains(name.split('/').next().unwrap_or("")))
                    .take(5)
                    .cloned()
                    .collect();
                close.sort();
                format!("no artifact {name:?}; similar: {close:?}")
            })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchCfg> {
        self.archs
            .get(name)
            .with_context(|| format!("no arch {name:?}"))
    }

    pub fn variant(&self, name: &str) -> Result<&VariantCfg> {
        self.variants
            .get(name)
            .with_context(|| format!("no variant {name:?}"))
    }

    /// All artifact names, for `repro list-artifacts`.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// Serialize to the `manifest.json` wire format ([`Manifest::parse`]
    /// is the exact inverse). This is how the in-process native catalog
    /// and the on-disk manifest the XLA engine loads are held to the
    /// same contract (parity-tested in `tests/device_api.rs`).
    pub fn to_json(&self) -> Json {
        use crate::util::json::{arr, num, obj, s};
        let adam = obj(vec![
            ("b1", num(self.adam.b1)),
            ("b2", num(self.adam.b2)),
            ("eps", num(self.adam.eps)),
            ("grad_clip", num(self.adam.grad_clip)),
        ]);
        let archs = Json::Obj(
            self.archs
                .iter()
                .map(|(name, a)| {
                    (
                        name.clone(),
                        obj(vec![
                            ("vocab", num(a.vocab as f64)),
                            ("d_model", num(a.d_model as f64)),
                            ("d_ff", num(a.d_ff as f64)),
                            ("n_layers", num(a.n_layers as f64)),
                            ("n_heads", num(a.n_heads as f64)),
                            ("seq", num(a.seq as f64)),
                            ("parallel_residual", Json::Bool(a.parallel_residual)),
                        ]),
                    )
                })
                .collect(),
        );
        let variants = Json::Obj(
            self.variants
                .iter()
                .map(|(name, v)| {
                    (
                        name.clone(),
                        obj(vec![
                            ("kind", s(&v.kind)),
                            ("dyad_variant", s(&v.dyad_variant)),
                            ("n_dyad", num(v.n_dyad as f64)),
                            (
                                "layer_schedule",
                                arr(v.layer_schedule.iter().map(|x| s(x))),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let artifacts = arr(self.artifacts.iter().map(|a| {
            obj(vec![
                ("name", s(&a.name)),
                ("file", s(&a.file)),
                ("kind", s(&a.kind)),
                ("inputs", arr(a.inputs.iter().map(|io| io_to_json(io, true)))),
                ("outputs", arr(a.outputs.iter().map(|io| io_to_json(io, false)))),
                ("meta", a.meta.clone()),
            ])
        }));
        obj(vec![
            ("version", num(1.0)),
            ("adam", adam),
            ("archs", archs),
            ("variants", variants),
            ("artifacts", artifacts),
        ])
    }
}

fn io_to_json(io: &IoSpec, with_role: bool) -> Json {
    use crate::util::json::{arr, num, obj, s};
    let mut kv = vec![
        ("name", s(&io.name)),
        ("shape", arr(io.shape.iter().map(|&d| num(d as f64)))),
        ("dtype", s(io.dtype.name())),
    ];
    if with_role {
        kv.push(("role", s(io.role.as_str())));
    }
    if let Some(init) = &io.init {
        kv.push((
            "init",
            match init {
                InitSpec::Zeros => obj(vec![("kind", s("zeros"))]),
                InitSpec::Ones => obj(vec![("kind", s("ones"))]),
                InitSpec::Uniform { bound } => obj(vec![
                    ("kind", s("uniform")),
                    ("bound", num(*bound as f64)),
                ]),
                InitSpec::Normal { std } => {
                    obj(vec![("kind", s("normal")), ("std", num(*std as f64))])
                }
            },
        ));
    }
    obj(kv)
}

fn parse_init(j: &Json) -> Result<InitSpec> {
    Ok(match j.req("kind")?.as_str()? {
        "zeros" => InitSpec::Zeros,
        "ones" => InitSpec::Ones,
        "uniform" => InitSpec::Uniform {
            bound: j.req("bound")?.as_f64()? as f32,
        },
        "normal" => InitSpec::Normal {
            std: j.req("std")?.as_f64()? as f32,
        },
        k => bail!("unknown init kind {k:?}"),
    })
}

fn parse_io(j: &Json, with_role: bool) -> Result<IoSpec> {
    let shape = j
        .req("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: j.req("name")?.as_str()?.to_string(),
        shape,
        dtype: DType::from_str(j.req("dtype")?.as_str()?)?,
        role: if with_role {
            Role::from_str(j.req("role")?.as_str()?)?
        } else {
            Role::Data
        },
        init: match j.get("init") {
            Some(init) => Some(parse_init(init)?),
            None => None,
        },
    })
}

fn parse_artifact(j: &Json) -> Result<ArtifactSpec> {
    let name = j.req("name")?.as_str()?.to_string();
    let inputs = j
        .req("inputs")?
        .as_arr()?
        .iter()
        .map(|i| parse_io(i, true))
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("artifact {name}: inputs"))?;
    let outputs = j
        .req("outputs")?
        .as_arr()?
        .iter()
        .map(|o| parse_io(o, false))
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("artifact {name}: outputs"))?;
    Ok(ArtifactSpec {
        name,
        file: j.req("file")?.as_str()?.to_string(),
        kind: j.req("kind")?.as_str()?.to_string(),
        inputs,
        outputs,
        meta: j.get("meta").cloned().unwrap_or(Json::Obj(vec![])),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "adam": {"b1": 0.9, "b2": 0.999, "eps": 1e-8, "grad_clip": 1.0},
      "archs": {"tiny": {"vocab": 64, "d_model": 32, "d_ff": 64,
                 "n_layers": 2, "n_heads": 4, "seq": 16,
                 "parallel_residual": false}},
      "variants": {"dyad_it": {"kind": "dyad", "dyad_variant": "it", "n_dyad": 4}},
      "artifacts": [
        {"name": "tiny/dyad_it/score", "file": "f.hlo.txt", "kind": "score",
         "inputs": [
            {"name": "w", "shape": [4, 2, 2], "dtype": "f32", "role": "param",
             "init": {"kind": "uniform", "bound": 0.125}},
            {"name": "tokens", "shape": [8, 16], "dtype": "i32", "role": "data"}
         ],
         "outputs": [{"name": "sum_logp", "shape": [8], "dtype": "f32"}],
         "meta": {"batch": 8}}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.adam.b1, 0.9);
        assert_eq!(m.arch("tiny").unwrap().d_model, 32);
        assert_eq!(m.variant("dyad_it").unwrap().n_dyad, 4);
        let a = m.artifact("tiny/dyad_it/score").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].role, Role::Param);
        assert_eq!(
            a.inputs[0].init,
            Some(InitSpec::Uniform { bound: 0.125 })
        );
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.param_count(), 16);
        assert_eq!(a.meta_usize("batch").unwrap(), 8);
        assert_eq!(a.output_index("sum_logp").unwrap(), 0);
        assert!(a.output_index("nope").is_err());
    }

    #[test]
    fn unknown_artifact_suggests() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = format!("{:#}", m.artifact("tiny/dense/score").unwrap_err());
        assert!(err.contains("tiny/dyad_it/score"), "{err}");
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }
}

//! Backend abstraction: who executes an artifact, and how.
//!
//! Everything the runtime consumers (`serve`, `eval`, `coordinator`,
//! `bench_support`, the CLI) need from an execution engine is captured
//! by two traits:
//!
//! * [`Backend`] — owns a [`Manifest`] (the artifact contract) and
//!   loads executables by manifest name, caching per backend.
//! * [`Executable`] — runs one artifact on positional host tensors and
//!   returns its outputs as host tensors, in manifest output order.
//!
//! Two implementations exist:
//!
//! * the **native CPU backend** ([`crate::runtime::NativeBackend`]) —
//!   pure Rust, always available, backed by `dyad::kernel`'s parallel
//!   blocked matmuls and the fused DYAD forward; its manifest is
//!   synthesised in-process (`runtime::catalog`), so no artifact files
//!   are needed on disk;
//! * the **PJRT/XLA backend** ([`crate::runtime::Engine`], behind the
//!   `xla` cargo feature) — compiles AOT'd HLO text from an
//!   `artifacts/` directory produced by `make artifacts`.
//!
//! Backends hold non-`Send` state (the PJRT client); like the previous
//! concrete `Engine`, a backend lives and dies on one thread — the
//! serve worker constructs its own.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use super::artifact::{ArtifactSpec, IoSpec, Manifest};
use crate::tensor::Tensor;

/// One loaded artifact: validated positional-tensor execution.
pub trait Executable {
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with the full positional input set (manifest order).
    /// Outputs come back in manifest output order.
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Convenience: fetch one named output from a result set.
    fn output_index(&self, name: &str) -> Result<usize> {
        self.spec().output_index(name)
    }
}

/// An execution engine: manifest + load-by-name.
pub trait Backend {
    /// The artifact contract this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Load an artifact by manifest name (cached per backend).
    fn load(&self, name: &str) -> Result<Rc<dyn Executable>>;

    /// Human-readable platform tag ("native-cpu", "Host", ...).
    fn platform(&self) -> String;
}

/// Which backend to execute on. Parsed from `--backend` / config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust CPU execution (default; no artifacts required).
    #[default]
    Native,
    /// PJRT/XLA execution of AOT'd HLO artifacts (`xla` feature).
    Xla,
}

impl BackendKind {
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<BackendKind> {
        match s {
            "native" | "cpu" => Ok(BackendKind::Native),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            _ => bail!("unknown backend {s:?} (expected native|xla)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Open a backend. `artifacts_dir` is only read by the XLA backend;
/// the native backend synthesises its manifest in-process.
pub fn open_backend(kind: BackendKind, artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(super::native::NativeBackend::new())),
        BackendKind::Xla => open_xla(artifacts_dir),
    }
}

#[cfg(feature = "xla")]
fn open_xla(artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::engine::Engine::from_dir(artifacts_dir)?))
}

#[cfg(not(feature = "xla"))]
fn open_xla(_artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    bail!(
        "the xla backend is not compiled in; add the `xla` dependency \
         in rust/Cargo.toml (see its [features] note), rebuild with \
         `cargo build --features xla`, or use `--backend native`"
    )
}

/// Shape/dtype/arity validation shared by every backend.
pub fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: {} inputs given, manifest wants {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (t, io) in inputs.iter().zip(&spec.inputs) {
        validate_tensor(t, io, &spec.name)?;
    }
    Ok(())
}

pub fn validate_tensor(t: &Tensor, io: &IoSpec, artifact: &str) -> Result<()> {
    if t.shape != io.shape {
        bail!(
            "{artifact}: input {:?} shape {:?} != manifest {:?}",
            io.name,
            t.shape,
            io.shape
        );
    }
    if t.dtype() != io.dtype {
        bail!(
            "{artifact}: input {:?} dtype {:?} != manifest {:?}",
            io.name,
            t.dtype(),
            io.dtype
        );
    }
    Ok(())
}

//! Backend abstraction: who executes an artifact, and how.
//!
//! Everything the runtime consumers (`serve`, `eval`, `coordinator`,
//! `bench_support`, the CLI) need from an execution engine is captured
//! by two traits:
//!
//! * [`Backend`] — owns a [`Manifest`] (the artifact contract), loads
//!   executables by manifest name (cached per backend), and owns the
//!   buffer plane: [`Backend::upload`]/[`Backend::download`]/
//!   [`Backend::alloc`] move data across the host↔backend boundary and
//!   hand out opaque [`DeviceTensor`] handles.
//! * [`Executable`] — runs one artifact. The primary call path is
//!   [`Executable::run_bound`] over device-resident handles (params
//!   and optimizer state stay backend-side across calls); the
//!   host-tensor [`Executable::run`] remains as the stage-everything
//!   convenience wrapper.
//!
//! [`Bindings`] is the builder callers use to mark inputs *resident*
//! (bound once — params, Adam moments) versus *per-call* (activations,
//! token batches), then `call` with just the per-call handles.
//!
//! Two implementations exist:
//!
//! * the **native CPU backend** ([`crate::runtime::NativeBackend`]) —
//!   pure Rust, always available; `upload` wraps the host tensor in an
//!   `Rc` (zero-copy), so residency costs nothing and `run_bound`
//!   executes straight over the wrapped buffers;
//! * the **PJRT/XLA backend** ([`crate::runtime::Engine`], behind the
//!   `xla` cargo feature) — keeps uploaded tensors alive as
//!   `xla::Literal`s, so resident state skips the per-call
//!   tensor→literal staging entirely.
//!
//! Backends hold non-`Send` state (the PJRT client, `Rc` handles);
//! like the previous concrete `Engine`, a backend lives and dies on
//! one thread — the serve worker constructs its own.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, IoSpec, Manifest, Role};
use super::device::{staging, DeviceTensor};
use crate::tensor::{DType, Tensor};

/// One loaded artifact: validated positional execution.
pub trait Executable {
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with the full positional host-tensor input set
    /// (manifest order). Outputs come back as host tensors in manifest
    /// output order.
    ///
    /// This is the stage-everything convenience path: every input
    /// crosses the host→backend boundary on every call. Hot loops that
    /// reuse weights should upload them once and go through
    /// [`Executable::run_bound`] / [`Bindings`] instead.
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with the full positional set of device-resident
    /// handles; outputs stay backend-resident. Inputs must have been
    /// produced by the same backend (`upload`/`alloc`/`run_bound`).
    fn run_bound(&self, inputs: &[&DeviceTensor]) -> Result<Vec<DeviceTensor>>;

    /// Allocate this artifact's device-resident decode cache (the K/V
    /// ring buffers of a `decode_step` artifact), all lanes empty. The
    /// handle binds to the artifact's `kv_cache` input and is mutated
    /// **in place** by every `run_bound` call — it never crosses the
    /// host boundary, so per-step staging stays at token ids in /
    /// logits out. Only `decode_step` programs have one; everything
    /// else errors.
    fn make_decode_cache(&self) -> Result<DeviceTensor> {
        bail!("{}: this artifact has no decode cache", self.spec().name)
    }

    /// Convenience: fetch one named output from a result set.
    fn output_index(&self, name: &str) -> Result<usize> {
        self.spec().output_index(name)
    }
}

/// An execution engine: manifest + load-by-name + buffer plane.
pub trait Backend {
    /// The artifact contract this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Load an artifact by manifest name (cached per backend).
    fn load(&self, name: &str) -> Result<Rc<dyn Executable>>;

    /// Human-readable platform tag ("native-cpu", "Host", ...).
    fn platform(&self) -> String;

    /// Move a host tensor onto the backend. Takes ownership so
    /// backends that store host memory (native CPU) can wrap the
    /// buffer without copying its elements.
    fn upload(&self, t: Tensor) -> Result<DeviceTensor>;

    /// Copy a device-resident buffer back to a host tensor.
    fn download(&self, t: &DeviceTensor) -> Result<Tensor>;

    /// Consume a handle and return its host tensor. Semantically
    /// `download`, but backends that store host memory recover the
    /// buffer without copying when the handle is the last owner (the
    /// native backend does — fresh `run_bound` outputs always are).
    fn take(&self, t: DeviceTensor) -> Result<Tensor> {
        self.download(&t)
    }

    /// Allocate a zero-filled backend buffer.
    fn alloc(&self, shape: &[usize], dtype: DType) -> Result<DeviceTensor>;
}

/// Positional input bindings for one executable: slots marked
/// *resident* hold a [`DeviceTensor`] across calls; the remaining
/// slots are filled left-to-right from the per-call handles at
/// [`Bindings::call`] time.
///
/// ```text
/// let mut b = Bindings::new(art.as_ref());
/// b.bind_role(Role::Param, state.param_handles())?;   // resident
/// let out = b.call(&[&tokens_dev, &mask_dev])?;       // per-call
/// ```
pub struct Bindings<'e> {
    exe: &'e dyn Executable,
    slots: Vec<Option<DeviceTensor>>,
}

impl<'e> Bindings<'e> {
    /// All slots start unbound (per-call).
    pub fn new(exe: &'e dyn Executable) -> Bindings<'e> {
        let n = exe.spec().inputs.len();
        Bindings { exe, slots: vec![None; n] }
    }

    pub fn spec(&self) -> &ArtifactSpec {
        self.exe.spec()
    }

    /// Mark one positional input resident. Validates shape/dtype
    /// against the manifest immediately.
    pub fn bind(&mut self, index: usize, t: DeviceTensor) -> Result<&mut Self> {
        let spec = self.exe.spec();
        let io = spec.inputs.get(index).with_context(|| {
            format!(
                "{}: input index {index} out of range ({} inputs)",
                spec.name,
                spec.inputs.len()
            )
        })?;
        validate_device_tensor(&t, io, &spec.name, index)?;
        self.slots[index] = Some(t);
        Ok(self)
    }

    /// Mark one named input resident.
    pub fn bind_named(&mut self, name: &str, t: DeviceTensor) -> Result<&mut Self> {
        let index = self.exe.spec().input_index(name)?;
        self.bind(index, t)
    }

    /// Mark every input of `role` resident, in manifest feed order —
    /// the one-liner for "params (and moments) live on the backend".
    pub fn bind_role(&mut self, role: Role, handles: &[DeviceTensor]) -> Result<&mut Self> {
        let spec = self.exe.spec();
        let idxs: Vec<usize> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, io)| io.role == role)
            .map(|(i, _)| i)
            .collect();
        if idxs.len() != handles.len() {
            bail!(
                "{}: {} inputs with role {role:?}, {} handles given",
                spec.name,
                idxs.len(),
                handles.len()
            );
        }
        for (i, h) in idxs.into_iter().zip(handles) {
            self.bind(i, h.clone())?;
        }
        Ok(self)
    }

    /// Unbind a slot (returns the previously resident handle, if any).
    pub fn unbind(&mut self, index: usize) -> Option<DeviceTensor> {
        self.slots.get_mut(index).and_then(Option::take)
    }

    /// How many slots are currently resident.
    pub fn resident_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Total bytes held resident by this binding set.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(DeviceTensor::size_bytes)
            .sum()
    }

    /// Execute: resident slots from the bindings, unbound slots filled
    /// left-to-right from `per_call`. Outputs stay device-resident.
    pub fn call(&self, per_call: &[&DeviceTensor]) -> Result<Vec<DeviceTensor>> {
        let spec = self.exe.spec();
        let n_unbound = self.slots.len() - self.resident_count();
        if per_call.len() != n_unbound {
            bail!(
                "{}: {} per-call inputs given, bindings leave {} slots unbound",
                spec.name,
                per_call.len(),
                n_unbound
            );
        }
        let mut next = per_call.iter();
        let full: Vec<&DeviceTensor> = self
            .slots
            .iter()
            .map(|slot| match slot {
                Some(t) => t,
                // counts match, so `next` cannot run dry
                None => *next.next().expect("per-call slot"),
            })
            .collect();
        self.exe.run_bound(&full)
    }
}

/// Which backend to execute on. Parsed from `--backend` / config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust CPU execution (default; no artifacts required).
    #[default]
    Native,
    /// PJRT/XLA execution of AOT'd HLO artifacts (`xla` feature).
    Xla,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendKind> {
        match s {
            "native" | "cpu" => Ok(BackendKind::Native),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            _ => bail!("unknown backend {s:?} (expected native|xla)"),
        }
    }
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Open a backend. `artifacts_dir` is only read by the XLA backend;
/// the native backend synthesises its manifest in-process.
pub fn open_backend(kind: BackendKind, artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    open_backend_with_precision(kind, artifacts_dir, crate::tensor::Precision::F32)
}

/// [`open_backend`] with a weight-stream precision (`--precision`).
/// Only the native backend executes quantized swap-site linears; the
/// XLA backend's AOT'd artifacts are f32-only, so any other tag is
/// rejected up front rather than silently ignored.
pub fn open_backend_with_precision(
    kind: BackendKind,
    artifacts_dir: &Path,
    precision: crate::tensor::Precision,
) -> Result<Box<dyn Backend>> {
    open_backend_sized(
        kind,
        artifacts_dir,
        precision,
        crate::dyad::kernel::num_threads(),
    )
}

/// [`open_backend_with_precision`] with an explicit worker-pool size
/// for the native backend. Serve workers use this to open their
/// backend on a per-worker share of the machine
/// (`num_threads() / n_workers`) instead of each shard spinning up a
/// full-width pool — see [`crate::serve::Router`]. The XLA backend
/// manages its own device threading, so `threads` is native-only and
/// ignored there.
pub fn open_backend_sized(
    kind: BackendKind,
    artifacts_dir: &Path,
    precision: crate::tensor::Precision,
    threads: usize,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(
            super::native::NativeBackend::with_precision_and_threads(precision, threads),
        )),
        BackendKind::Xla => {
            if precision != crate::tensor::Precision::F32 {
                bail!(
                    "--precision {precision} is native-only: the xla backend executes \
                     AOT'd f32 artifacts; use `--backend native` or drop --precision"
                );
            }
            open_xla(artifacts_dir)
        }
    }
}

#[cfg(feature = "xla")]
fn open_xla(artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::engine::Engine::from_dir(artifacts_dir)?))
}

#[cfg(not(feature = "xla"))]
fn open_xla(_artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    bail!(
        "the xla backend is not compiled in; rebuild with \
         `cargo build --features xla` (links the PJRT engine against \
         the `xla` crate — see rust/Cargo.toml's [features] note), or \
         use `--backend native`"
    )
}

/// Arity + per-input shape/dtype validation shared by every backend's
/// host-tensor path. Errors carry the positional index alongside the
/// IO name.
pub fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: {} inputs given, manifest wants {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (i, (t, io)) in inputs.iter().zip(&spec.inputs).enumerate() {
        validate_tensor(t, io, &spec.name, i)?;
    }
    Ok(())
}

/// The one shape/dtype comparison behind every validator below.
/// Returns the mismatch description (IO name + field + values), or
/// `None` when the metadata matches; callers prefix the artifact and
/// slot. Allocates only on failure.
pub(crate) fn io_mismatch(shape: &[usize], dtype: DType, io: &IoSpec) -> Option<String> {
    if shape != io.shape.as_slice() {
        return Some(format!(
            "{:?} shape {:?} != manifest {:?}",
            io.name, shape, io.shape
        ));
    }
    if dtype != io.dtype {
        return Some(format!(
            "{:?} dtype {:?} != manifest {:?}",
            io.name, dtype, io.dtype
        ));
    }
    None
}

/// Validate one host tensor against its IoSpec. `index` is the
/// positional slot, reported alongside the IO name.
pub fn validate_tensor(t: &Tensor, io: &IoSpec, artifact: &str, index: usize) -> Result<()> {
    match io_mismatch(&t.shape, t.dtype(), io) {
        Some(m) => bail!("{artifact}: input #{index} {m}"),
        None => Ok(()),
    }
}

/// Validate one device handle against its IoSpec (metadata only — the
/// payload is checked by the executing backend).
pub fn validate_device_tensor(
    t: &DeviceTensor,
    io: &IoSpec,
    artifact: &str,
    index: usize,
) -> Result<()> {
    match io_mismatch(t.shape(), t.dtype(), io) {
        Some(m) => bail!("{artifact}: input #{index} {m}"),
        None => Ok(()),
    }
}

/// Arity + shape/dtype validation for a bound (device-handle) input
/// set.
pub fn validate_bound_inputs(spec: &ArtifactSpec, inputs: &[&DeviceTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: {} inputs given, manifest wants {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (i, (t, io)) in inputs.iter().zip(&spec.inputs).enumerate() {
        validate_device_tensor(t, io, &spec.name, i)?;
    }
    Ok(())
}

/// Debug-build output validation (count + shape + dtype): backends
/// call this after executing so contract drift fails loudly in tests
/// instead of flowing downstream. Compiled out of release hot paths.
pub fn validate_outputs(spec: &ArtifactSpec, outputs: &[Tensor]) -> Result<()> {
    if outputs.len() != spec.outputs.len() {
        bail!(
            "{}: produced {} outputs, manifest says {}",
            spec.name,
            outputs.len(),
            spec.outputs.len()
        );
    }
    for (i, (t, io)) in outputs.iter().zip(&spec.outputs).enumerate() {
        if let Some(m) = io_mismatch(&t.shape, t.dtype(), io) {
            bail!("{}: output #{i} {m}", spec.name);
        }
    }
    Ok(())
}

/// Debug-build output validation for device-resident results.
pub fn validate_bound_outputs(spec: &ArtifactSpec, outputs: &[DeviceTensor]) -> Result<()> {
    if outputs.len() != spec.outputs.len() {
        bail!(
            "{}: produced {} outputs, manifest says {}",
            spec.name,
            outputs.len(),
            spec.outputs.len()
        );
    }
    for (i, (t, io)) in outputs.iter().zip(&spec.outputs).enumerate() {
        if let Some(m) = io_mismatch(t.shape(), t.dtype(), io) {
            bail!("{}: output #{i} {m}", spec.name);
        }
    }
    Ok(())
}

/// Count the host-boundary bytes of a legacy `run` input set (all
/// positional tensors are re-presented per call).
pub(crate) fn note_legacy_staging(inputs: &[&Tensor]) {
    staging::note_legacy_run(inputs.iter().map(|t| t.size_bytes()).sum());
}

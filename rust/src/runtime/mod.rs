//! Runtime: the backend abstraction plus its two implementations.
//!
//! * `backend` — the [`Backend`]/[`Executable`] trait pair every
//!   consumer (`serve`, `eval`, `coordinator`, `bench_support`, CLI)
//!   programs against, plus the [`Bindings`] builder (resident vs
//!   per-call inputs) and [`open_backend`]/[`BackendKind`].
//! * `device` — opaque backend-owned buffers ([`DeviceTensor`]) and
//!   the host↔backend [`staging`] traffic counters.
//! * `pool` — the persistent worker-pool runtime every native kernel
//!   parallelises on: resident threads, spin-then-park wakeup,
//!   deterministic static panel partitioning (bitwise identical to
//!   the old scoped-spawn path), plus spawn/alloc [`pool::counters`].
//! * `native` — the pure-Rust CPU backend (default): transformer
//!   inference **and training** (layer-module autodiff, see
//!   `native::layers`), MNIST training, ff-micro timing — no artifacts
//!   needed; device handles wrap host tensors zero-copy.
//! * `engine` (`xla` feature) — the PJRT backend: loads AOT artifacts
//!   (HLO text) produced by `make artifacts` and executes them;
//!   device handles keep `xla::Literal`s alive across calls.
//! * `artifact` — the manifest types (the L2→L3 contract);
//!   `catalog` synthesises the native backend's manifest in-process.
//! * `state` — backend-resident training state threaded between
//!   `train_step` calls (staged once, not per call).

mod artifact;
mod backend;
pub mod catalog;
mod device;
#[cfg(feature = "xla")]
mod engine;
pub mod native;
pub mod pool;
mod state;

pub use artifact::{AdamCfg, ArchCfg, ArtifactSpec, IoSpec, Manifest, Role, VariantCfg};
pub use backend::{
    open_backend, open_backend_sized, open_backend_with_precision, validate_bound_inputs,
    validate_bound_outputs,
    validate_device_tensor, validate_inputs, validate_outputs, validate_tensor, Backend,
    BackendKind, Bindings, Executable,
};
pub use device::{staging, DeviceTensor};
#[cfg(feature = "xla")]
pub use engine::{literal_to_tensor, tensor_to_literal, Engine, Loaded};
pub use native::{LinearView, NativeBackend, Params, VariantSpec};
pub use pool::ThreadPool;
pub use state::TrainState;

//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! `Engine` owns the PJRT CPU client and an executable cache;
//! `artifact` parses `artifacts/manifest.json` (the L2→L3 contract);
//! `state` carries training state between `train_step` calls.
//!
//! Pattern per `/opt/xla-example/load_hlo`: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Multi-output executables return a single tuple buffer which we
//! decompose on the host (PJRT does not untuple; DESIGN.md §2).

mod artifact;
mod engine;
mod state;

pub use artifact::{AdamCfg, ArchCfg, ArtifactSpec, IoSpec, Manifest, Role, VariantCfg};
pub use engine::{literal_to_tensor, tensor_to_literal, Engine, Loaded};
pub use state::TrainState;

//! Runtime: the backend abstraction plus its two implementations.
//!
//! * `backend` — the [`Backend`]/[`Executable`] trait pair every
//!   consumer (`serve`, `eval`, `coordinator`, `bench_support`, CLI)
//!   programs against, plus [`open_backend`]/[`BackendKind`].
//! * `native` — the pure-Rust CPU backend (default): transformer
//!   inference, MNIST training, ff-micro timing — no artifacts needed.
//! * `engine` (`xla` feature) — the PJRT backend: loads AOT artifacts
//!   (HLO text) produced by `make artifacts` and executes them.
//! * `artifact` — the manifest types (the L2→L3 contract);
//!   `catalog` synthesises the native backend's manifest in-process.
//! * `state` — training state threaded between `train_step` calls.

mod artifact;
mod backend;
pub mod catalog;
#[cfg(feature = "xla")]
mod engine;
mod native;
mod state;

pub use artifact::{AdamCfg, ArchCfg, ArtifactSpec, IoSpec, Manifest, Role, VariantCfg};
pub use backend::{open_backend, Backend, BackendKind, Executable};
#[cfg(feature = "xla")]
pub use engine::{literal_to_tensor, tensor_to_literal, Engine, Loaded};
pub use native::{LinearView, NativeBackend, Params, VariantSpec};
pub use state::TrainState;

//! DYW1: catalog weights serialized once, memory-mapped by every
//! serve shard.
//!
//! The fleet memory model (ISSUE / Fig. 8 / Table 11 as a serving
//! win): the front-end writes one weight file per (arch, variant,
//! seed) — either the deterministic init stream or checkpoint params —
//! and each shard *process* opens it through
//! [`crate::tensor::Mapping`], a read-only `MAP_SHARED` mapping. All
//! shards then share the same page-cache pages, so fleet resident
//! weight bytes stay ~1× instead of N× (asserted in
//! `benches/fleet_sweep.rs`). Tensors come out as zero-copy
//! [`Tensor::from_mapped`] views the native backend binds resident
//! without ever touching the elements.
//!
//! Layout (little-endian, data blocks 64-byte aligned):
//! ```text
//!   magic   b"DYW1"
//!   u32     version (1)
//!   u32     entry count
//!   entry*  { u32 name_len, name bytes (utf-8),
//!             u8 dtype (0=f32), u32 ndim, u64 dims[ndim],
//!             u64 offset (from file start), u64 byte_len }
//!   ...     64-aligned f32 data blocks
//! ```
//! Parsing is corruption-bounded like `tensor/io.rs` (DYT1): counts
//! and lengths are validated against the file size before any
//! allocation, offsets must land inside the mapping and be 4-byte
//! aligned, so a truncated or bit-flipped file errors — never panics,
//! never over-allocates.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::{Backend, DeviceTensor, Role};
use crate::tensor::{MappedF32, Mapping, Tensor};
use crate::util::rng::Rng;

use super::super::artifact::ArtifactSpec;

const MAGIC: &[u8; 4] = b"DYW1";
const VERSION: u32 = 1;
/// Data blocks align to cache lines; also guarantees the 4-byte f32
/// alignment [`MappedF32`] checks.
const ALIGN: usize = 64;

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// Serialize named f32 tensors into a DYW1 weight file.
pub fn write(path: &Path, entries: &[(String, &Tensor)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // pass 1: header size, then 64-aligned data offsets
    let mut header = 4 + 4 + 4;
    for (name, t) in entries {
        if t.as_f32().is_err() {
            bail!("weight file entries must be f32, {name:?} is {:?}", t.dtype());
        }
        header += 4 + name.len() + 1 + 4 + 8 * t.shape.len() + 8 + 8;
    }
    let mut offsets = Vec::with_capacity(entries.len());
    let mut cursor = align_up(header);
    for (_, t) in entries {
        offsets.push(cursor);
        cursor = align_up(cursor + t.size_bytes());
    }
    let mut w = BufWriter::new(File::create(path).context("create weight file")?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for ((name, t), off) in entries.iter().zip(&offsets) {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[0u8])?; // dtype f32
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        w.write_all(&(*off as u64).to_le_bytes())?;
        w.write_all(&(t.size_bytes() as u64).to_le_bytes())?;
    }
    let mut written = header;
    for ((_, t), off) in entries.iter().zip(&offsets) {
        w.write_all(&vec![0u8; off - written])?;
        let bytes = t.to_bytes();
        w.write_all(&bytes)?;
        written = off + bytes.len();
    }
    w.flush()?;
    Ok(())
}

/// Write the artifact's **initial** parameters — the exact tensors
/// [`crate::runtime::TrainState::init`] would upload for this spec and
/// seed. Contract: `TrainState::init` draws rng values for `Param`
/// inputs only (moments are zero-allocated), in feed order, so
/// replaying the same `Rng(seed)` over the param specs is bit-identical
/// — a shard serving from this file scores bitwise the same as one
/// initialising in-process (pinned in tests).
pub fn write_init(path: &Path, spec: &ArtifactSpec, seed: u64) -> Result<()> {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::new();
    for io in &spec.inputs {
        if io.role == Role::Param {
            let init = io
                .init
                .as_ref()
                .with_context(|| format!("param {} has no init", io.name))?;
            tensors.push((io.name.clone(), Tensor::init(&io.shape, init, &mut rng)));
        }
    }
    let refs: Vec<(String, &Tensor)> =
        tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
    write(path, &refs)
}

/// Convert a params-only DYT checkpoint (`model.dyt`) into a weight
/// file — serving a trained model from shared storage.
pub fn write_from_checkpoint(path: &Path, params_file: &Path) -> Result<()> {
    let entries = crate::tensor::load_checkpoint(params_file)?;
    let refs: Vec<(String, &Tensor)> =
        entries.iter().map(|(n, t)| (n.clone(), t)).collect();
    write(path, &refs)
}

struct Entry {
    shape: Vec<usize>,
    offset: usize,
    byte_len: usize,
}

/// An open weight file: the shared mapping plus its parsed index.
pub struct MappedWeights {
    map: Arc<Mapping>,
    index: Vec<Entry>,
    by_name: BTreeMap<String, usize>,
}

/// Bounds-checked little-endian reads over the mapped header.
struct Cursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(end) => {
                let s = &self.b[self.off..end];
                self.off = end;
                Ok(s)
            }
            None => bail!("corrupt weight file: truncated header"),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
}

impl MappedWeights {
    /// Map `path` read-only and parse its index. Every field is
    /// validated against the file size, so corrupt input errors here
    /// rather than panicking later.
    pub fn open(path: &Path) -> Result<MappedWeights> {
        let map = Mapping::open(path)?;
        let bytes = map.as_bytes();
        let mut c = Cursor { b: bytes, off: 0 };
        if c.take(4)? != MAGIC {
            bail!("{}: not a DYW1 weight file", path.display());
        }
        let version = c.u32()?;
        if version != VERSION {
            bail!("{}: weight file version {version}, expected {VERSION}", path.display());
        }
        let count = c.u32()? as usize;
        // each entry needs >= 29 header bytes: bound before allocating
        if count > bytes.len() / 29 {
            bail!("corrupt weight file: entry count {count} exceeds file size");
        }
        let mut index = Vec::with_capacity(count);
        let mut by_name = BTreeMap::new();
        for _ in 0..count {
            let name_len = c.u32()? as usize;
            if name_len > 4096 {
                bail!("corrupt weight file: name length {name_len}");
            }
            let name = String::from_utf8(c.take(name_len)?.to_vec())
                .context("weight name utf-8")?;
            let dtype = c.u8()?;
            if dtype != 0 {
                bail!("corrupt weight file: {name}: dtype tag {dtype} (only f32=0)");
            }
            let ndim = c.u32()? as usize;
            if ndim > 16 {
                bail!("corrupt weight file: {name}: ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u64()? as usize);
            }
            let offset = c.u64()? as usize;
            let byte_len = c.u64()? as usize;
            let expect = shape
                .iter()
                .try_fold(4usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow::anyhow!("corrupt weight file: {name}: shape overflow"))?;
            if byte_len != expect {
                bail!("corrupt weight file: {name}: {byte_len} bytes for shape {shape:?}");
            }
            if offset % 4 != 0 {
                bail!("corrupt weight file: {name}: unaligned offset {offset}");
            }
            match offset.checked_add(byte_len) {
                Some(end) if end <= bytes.len() => {}
                _ => bail!(
                    "corrupt weight file: {name}: data [{offset}..+{byte_len}) \
                     exceeds file of {} bytes",
                    bytes.len()
                ),
            }
            if by_name.insert(name.clone(), index.len()).is_some() {
                bail!("corrupt weight file: duplicate tensor {name:?}");
            }
            index.push(Entry { shape, offset, byte_len });
        }
        Ok(MappedWeights { map, index, by_name })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }

    /// Total tensor data bytes (the fleet's shared resident-weight
    /// footprint when [`Self::is_shared`]).
    pub fn data_bytes(&self) -> u64 {
        self.index.iter().map(|e| e.byte_len as u64).sum()
    }

    /// Whether the storage is a real shared file mapping (page cache
    /// shared across shard processes) rather than a private heap copy.
    pub fn is_shared(&self) -> bool {
        self.map.is_shared()
    }

    /// Zero-copy mapped view of one tensor.
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let &i = self
            .by_name
            .get(name)
            .with_context(|| format!("weight file has no tensor {name:?}"))?;
        let e = &self.index[i];
        let view = MappedF32::new(self.map.clone(), e.offset, e.byte_len / 4)?;
        Tensor::from_mapped(&e.shape, view)
    }

    /// The artifact's parameter handles in feed order, shape-checked
    /// against the manifest and uploaded (zero-copy on native) onto
    /// `backend` — a drop-in for `TrainState::param_handles`, minus
    /// the optimizer moments serving never needs.
    pub fn param_handles(
        &self,
        backend: &dyn Backend,
        spec: &ArtifactSpec,
    ) -> Result<Vec<DeviceTensor>> {
        let mut handles = Vec::new();
        for io in spec.param_specs() {
            let t = self.tensor(&io.name)?;
            if t.shape != io.shape {
                bail!(
                    "weight file tensor {:?}: shape {:?} != manifest {:?}",
                    io.name,
                    t.shape,
                    io.shape
                );
            }
            handles.push(backend.upload(t)?);
        }
        Ok(handles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{open_backend_sized, BackendKind, TrainState};
    use crate::tensor::Precision;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dyad-repro-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_alignment() {
        let a = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::from_f32(&[5], vec![0.5; 5]).unwrap();
        let path = tmpfile("weights_roundtrip.dyw");
        write(&path, &[("w".into(), &a), ("b".into(), &b)]).unwrap();
        let w = MappedWeights::open(&path).unwrap();
        assert_eq!(w.names().collect::<Vec<_>>(), vec!["b", "w"]);
        assert_eq!(w.data_bytes(), (6 + 5) * 4);
        let wa = w.tensor("w").unwrap();
        assert!(wa.is_mapped());
        assert_eq!(wa, a);
        assert_eq!(w.tensor("b").unwrap(), b);
        assert!(w.tensor("nope").is_err());
        // every data block is 64-aligned in the file
        for e in &w.index {
            assert_eq!(e.offset % 64, 0, "offset {}", e.offset);
        }
    }

    #[test]
    fn rejects_non_f32() {
        let t = Tensor::from_i32(&[2], vec![1, 2]).unwrap();
        let path = tmpfile("weights_i32.dyw");
        assert!(write(&path, &[("t".into(), &t)]).is_err());
    }

    #[test]
    fn rejects_garbage_truncation_and_corruption() {
        let path = tmpfile("weights_garbage.dyw");
        std::fs::write(&path, b"definitely not a weight file").unwrap();
        assert!(MappedWeights::open(&path).is_err());

        let a = Tensor::from_f32(&[64], vec![0.25; 64]).unwrap();
        let good = tmpfile("weights_good.dyw");
        write(&good, &[("a".into(), &a)]).unwrap();
        let bytes = std::fs::read(&good).unwrap();

        // truncated: index points past the end
        let trunc = tmpfile("weights_trunc.dyw");
        std::fs::write(&trunc, &bytes[..bytes.len() - 32]).unwrap();
        assert!(MappedWeights::open(&trunc).is_err());

        // absurd entry count
        let mut huge = bytes.clone();
        huge[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let hpath = tmpfile("weights_huge_count.dyw");
        std::fs::write(&hpath, &huge).unwrap();
        assert!(MappedWeights::open(&hpath).is_err());

        // bad version
        let mut vbad = bytes.clone();
        vbad[4..8].copy_from_slice(&9u32.to_le_bytes());
        let vpath = tmpfile("weights_bad_version.dyw");
        std::fs::write(&vpath, &vbad).unwrap();
        assert!(MappedWeights::open(&vpath).is_err());
    }

    /// The rng-stream contract behind `write_init`: a file written for
    /// (spec, seed) holds bit-identical params to `TrainState::init`
    /// on the same (spec, seed) — what makes a weight-file shard score
    /// bitwise the same as an in-process worker.
    #[test]
    fn write_init_matches_train_state_init() {
        let backend = open_backend_sized(
            BackendKind::Native,
            std::path::Path::new("artifacts"),
            Precision::F32,
            1,
        )
        .unwrap();
        let spec = backend
            .manifest()
            .artifact("opt-mini/dyad_it/train_k1")
            .unwrap()
            .clone();
        let path = tmpfile("weights_init.dyw");
        write_init(&path, &spec, 7).unwrap();
        let w = MappedWeights::open(&path).unwrap();
        let state = TrainState::init(backend.as_ref(), &spec, 7).unwrap();
        let handles = state.param_handles();
        for (i, io) in spec.param_specs().into_iter().enumerate() {
            let host = backend.download(&handles[i]).unwrap();
            assert_eq!(w.tensor(&io.name).unwrap(), host, "param {}", io.name);
        }
        // and the uploaded handles really are zero-copy mapped views
        let dev = w.param_handles(backend.as_ref(), &spec).unwrap();
        assert_eq!(dev.len(), state.n_params());
    }

    #[test]
    fn checkpoint_conversion_roundtrips() {
        let a = Tensor::from_f32(&[3, 2], vec![1., -1., 2., -2., 3., -3.]).unwrap();
        let ckpt = tmpfile("weights_src.dyt");
        crate::tensor::save_checkpoint(&ckpt, &[("emb".into(), &a)]).unwrap();
        let path = tmpfile("weights_from_ckpt.dyw");
        write_from_checkpoint(&path, &ckpt).unwrap();
        let w = MappedWeights::open(&path).unwrap();
        assert_eq!(w.tensor("emb").unwrap(), a);
    }
}

//! Device-resident tensor handles and staging-traffic accounting.
//!
//! A [`DeviceTensor`] is an opaque, backend-owned buffer plus the
//! shape/dtype metadata every caller needs for validation. The payload
//! is whatever the owning backend stores per buffer — the native CPU
//! backend wraps a host [`Tensor`] (so `upload` is a move, not a
//! copy), the PJRT backend keeps an `xla::Literal` alive. Handles are
//! `Rc`-backed: cloning one is O(1) and never touches the elements,
//! which is what makes residency (params bound once, reused every
//! call) free.
//!
//! Handles are created by [`crate::runtime::Backend::upload`] /
//! `alloc` and by `run_bound` outputs; they are consumed by
//! `run_bound` inputs and read back with `download`. A handle is only
//! meaningful on the backend that created it — feeding it elsewhere
//! fails with a typed error, never garbage.
//!
//! The [`staging`] module counts every byte the *application* presents
//! at the host→backend boundary (uploads plus legacy host-tensor
//! `run` calls), so benches and tests can prove that the bindings
//! path hands params/optimizer state over once instead of per step.
//! What the backend does past that boundary is its own business (the
//! native backend does nothing; PJRT converts once per upload but
//! still buffers literals inside `execute`).

use std::any::Any;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::tensor::{DType, Tensor};

/// An opaque, backend-owned buffer with host-visible metadata.
///
/// Cheap to clone (`Rc` payload). The payload itself is private to the
/// owning backend; callers interact through shape/dtype and the
/// `Backend`/`Executable` methods.
#[derive(Clone)]
pub struct DeviceTensor {
    shape: Vec<usize>,
    dtype: DType,
    /// Tag of the backend family that owns the payload
    /// ("native-cpu", "xla") — used for actionable mixup errors.
    device: &'static str,
    payload: Rc<dyn Any>,
}

impl DeviceTensor {
    /// Wrap a backend payload. Only backends construct handles;
    /// callers obtain them via `upload`/`alloc`/`run_bound`.
    pub(crate) fn from_payload(
        shape: Vec<usize>,
        dtype: DType,
        device: &'static str,
        payload: Rc<dyn Any>,
    ) -> DeviceTensor {
        DeviceTensor { shape, dtype, device, payload }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Which backend family owns the payload.
    pub fn device(&self) -> &'static str {
        self.device
    }

    /// Borrow the backend payload, or `None` if this handle belongs to
    /// a different backend.
    pub(crate) fn payload<T: Any>(&self) -> Option<&T> {
        self.payload.downcast_ref::<T>()
    }

    /// Consume the handle and recover the payload by value: without a
    /// copy when this was the last owner, via `Clone` otherwise.
    /// `None` if the payload belongs to a different backend.
    pub(crate) fn try_unwrap_payload<T: Any + Clone>(self) -> Option<T> {
        let rc = self.payload.downcast::<T>().ok()?;
        Some(Rc::try_unwrap(rc).unwrap_or_else(|shared| (*shared).clone()))
    }

    /// Borrow the payload with an actionable error naming the input
    /// position and the expected device.
    pub(crate) fn expect_payload<T: Any>(
        &self,
        artifact: &str,
        index: usize,
        want_device: &str,
    ) -> Result<&T> {
        match self.payload::<T>() {
            Some(p) => Ok(p),
            None => bail!(
                "{artifact}: input #{index} is a {:?} handle, not resident \
                 on the {want_device:?} backend (upload it through the \
                 backend that executes this artifact)",
                self.device
            ),
        }
    }
}

impl std::fmt::Debug for DeviceTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceTensor")
            .field("shape", &self.shape)
            .field("dtype", &self.dtype)
            .field("device", &self.device)
            .finish_non_exhaustive()
    }
}

/// The native backend's device tag.
pub(crate) const NATIVE_DEVICE: &str = "native-cpu";
/// The PJRT backend's device tag.
#[cfg(feature = "xla")]
pub(crate) const XLA_DEVICE: &str = "xla";

/// Wrap a host tensor as a native-backend handle. Zero-copy: the
/// tensor (and its element buffer) is moved into the `Rc`, no
/// element-wise copy happens.
pub(crate) fn wrap_native(t: Tensor) -> DeviceTensor {
    DeviceTensor::from_payload(t.shape.clone(), t.dtype(), NATIVE_DEVICE, Rc::new(t))
}

/// Host→backend staging-traffic counters.
///
/// Backends are single-threaded (they hold non-`Send` state and live
/// on the thread that opened them), so the counters are thread-local:
/// each worker / test thread observes exactly its own traffic, with no
/// cross-test interference.
///
/// Two kinds of boundary crossings are counted separately:
/// * `upload_*` — explicit [`crate::runtime::Backend::upload`] calls
///   (the bindings path stages *only* per-call data this way);
/// * `legacy_run_bytes` — full positional host-tensor sets presented
///   to `Executable::run`, which re-stages every input (params,
///   optimizer moments, data) on every call.
pub mod staging {
    use std::cell::Cell;

    thread_local! {
        static UPLOAD_BYTES: Cell<u64> = const { Cell::new(0) };
        static UPLOAD_TENSORS: Cell<u64> = const { Cell::new(0) };
        static DOWNLOAD_BYTES: Cell<u64> = const { Cell::new(0) };
        static LEGACY_RUN_BYTES: Cell<u64> = const { Cell::new(0) };
    }

    /// Point-in-time reading of this thread's staging counters.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct StagingSnapshot {
        /// Bytes moved host→backend through `Backend::upload`.
        pub upload_bytes: u64,
        /// Number of `Backend::upload` calls.
        pub upload_tensors: u64,
        /// Bytes moved backend→host through `Backend::download`.
        pub download_bytes: u64,
        /// Bytes presented at the host boundary by legacy
        /// `Executable::run(&[&Tensor])` calls (all inputs, per call).
        pub legacy_run_bytes: u64,
    }

    impl StagingSnapshot {
        /// Total host→backend traffic (uploads + legacy run staging).
        pub fn host_to_backend_bytes(&self) -> u64 {
            self.upload_bytes + self.legacy_run_bytes
        }

        /// Counter deltas since an earlier snapshot.
        pub fn since(&self, earlier: &StagingSnapshot) -> StagingSnapshot {
            StagingSnapshot {
                upload_bytes: self.upload_bytes - earlier.upload_bytes,
                upload_tensors: self.upload_tensors - earlier.upload_tensors,
                download_bytes: self.download_bytes - earlier.download_bytes,
                legacy_run_bytes: self.legacy_run_bytes - earlier.legacy_run_bytes,
            }
        }
    }

    pub(crate) fn note_upload(bytes: usize) {
        UPLOAD_BYTES.with(|c| c.set(c.get() + bytes as u64));
        UPLOAD_TENSORS.with(|c| c.set(c.get() + 1));
    }

    pub(crate) fn note_download(bytes: usize) {
        DOWNLOAD_BYTES.with(|c| c.set(c.get() + bytes as u64));
    }

    pub(crate) fn note_legacy_run(bytes: usize) {
        LEGACY_RUN_BYTES.with(|c| c.set(c.get() + bytes as u64));
    }

    /// Read this thread's counters.
    pub fn snapshot() -> StagingSnapshot {
        StagingSnapshot {
            upload_bytes: UPLOAD_BYTES.with(Cell::get),
            upload_tensors: UPLOAD_TENSORS.with(Cell::get),
            download_bytes: DOWNLOAD_BYTES.with(Cell::get),
            legacy_run_bytes: LEGACY_RUN_BYTES.with(Cell::get),
        }
    }

    /// Zero this thread's counters.
    pub fn reset() {
        UPLOAD_BYTES.with(|c| c.set(0));
        UPLOAD_TENSORS.with(|c| c.set(0));
        DOWNLOAD_BYTES.with(|c| c.set(0));
        LEGACY_RUN_BYTES.with(|c| c.set(0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_native_keeps_metadata_and_payload() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0; 6]).unwrap();
        let d = wrap_native(t.clone());
        assert_eq!(d.shape(), &[2, 3]);
        assert_eq!(d.dtype(), DType::F32);
        assert_eq!(d.numel(), 6);
        assert_eq!(d.size_bytes(), 24);
        assert_eq!(d.device(), NATIVE_DEVICE);
        assert_eq!(d.payload::<Tensor>().unwrap(), &t);
        assert!(d.payload::<String>().is_none());
    }

    #[test]
    fn clone_is_shallow() {
        let d = wrap_native(Tensor::zeros(&[128], DType::F32));
        let d2 = d.clone();
        // both clones see the same payload allocation
        let p1 = d.payload::<Tensor>().unwrap() as *const Tensor;
        let p2 = d2.payload::<Tensor>().unwrap() as *const Tensor;
        assert_eq!(p1, p2);
    }

    #[test]
    fn expect_payload_names_position_and_device() {
        let d = wrap_native(Tensor::zeros(&[1], DType::F32));
        let err = d
            .expect_payload::<String>("art", 3, "xla")
            .unwrap_err()
            .to_string();
        assert!(err.contains("#3"), "{err}");
        assert!(err.contains("native-cpu"), "{err}");
        assert!(err.contains("xla"), "{err}");
    }

    #[test]
    fn staging_counters_roundtrip() {
        staging::reset();
        staging::note_upload(100);
        staging::note_upload(28);
        staging::note_download(4);
        staging::note_legacy_run(1000);
        let s = staging::snapshot();
        assert_eq!(s.upload_bytes, 128);
        assert_eq!(s.upload_tensors, 2);
        assert_eq!(s.download_bytes, 4);
        assert_eq!(s.legacy_run_bytes, 1000);
        assert_eq!(s.host_to_backend_bytes(), 1128);
        let later = staging::snapshot();
        assert_eq!(later.since(&s), StagingSnapshot::default());
        staging::reset();
        assert_eq!(staging::snapshot(), StagingSnapshot::default());
    }
}

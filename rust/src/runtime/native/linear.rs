//! A borrowed view of one linear layer (DENSE or DYAD) with row-major
//! forward and backward passes.
//!
//! Forward runs the fast path: `dyad::kernel::dense_linear` /
//! `dyad::kernel::dyad_linear` (the fused blocked schedule).
//!
//! Backward is structured too: the DYAD arm runs the per-block
//! kernels `dyad::kernel::dyad_backward_dw` (component gradients
//! accumulated directly, permutation-aware) and
//! `dyad::kernel::dyad_linear_backward_dx` (`dx = dy @ (W1 + W2)` as
//! two fused block-sparse passes) — the full `(f_out, f_in)` matrix is
//! never materialised, so the timed bwd path keeps DYAD's
//! O(rows·cols/n_dyad) FLOP advantage. Equivalence with the old
//! materialise-and-project path (`dyad::math::dyad_backward`) is
//! property- and gradcheck-tested below.

use anyhow::{bail, Result};

use crate::dyad::kernel::{
    dense_linear_prec_into, dense_linear_prec_with_threads, dyad_backward_dw_with_threads,
    dyad_linear_backward_dx_prec_with_threads, dyad_linear_prec_into,
    dyad_linear_prec_with_threads, matmul_fast_prec_with_threads, matmul_fast_with_threads,
    num_threads, transpose,
};
use crate::dyad::layout::dyad_full;
use crate::dyad::{DyadDims, Variant};
use crate::tensor::Precision;

use super::ops::col_sums;

/// Both arms carry a [`Precision`] tag selecting the weight-stream
/// storage for the forward and the `dx` backward (`dw` accumulates
/// activations and gradients — both f32 streams — so it is always
/// f32). `Precision::F32` is bitwise identical to the pre-precision
/// code paths.
pub enum LinearView<'a> {
    Dense {
        w: &'a [f32],
        b: &'a [f32],
        f_in: usize,
        f_out: usize,
        precision: Precision,
    },
    Dyad {
        wl: &'a [f32],
        wu: &'a [f32],
        b: &'a [f32],
        dims: DyadDims,
        variant: Variant,
        precision: Precision,
    },
}

impl LinearView<'_> {
    pub fn f_in(&self) -> usize {
        match self {
            LinearView::Dense { f_in, .. } => *f_in,
            LinearView::Dyad { dims, .. } => dims.f_in(),
        }
    }

    pub fn f_out(&self) -> usize {
        match self {
            LinearView::Dense { f_out, .. } => *f_out,
            LinearView::Dyad { dims, .. } => dims.f_out(),
        }
    }

    /// `x (t, f_in)` -> `(t, f_out)`, bias applied.
    pub fn forward(&self, x: &[f32], t: usize) -> Vec<f32> {
        self.forward_with_threads(x, t, num_threads())
    }

    /// [`LinearView::forward`] on an explicit worker count (the layer
    /// modules thread the pool size resolved once per step through
    /// their [`super::layers::Workspace`]).
    pub fn forward_with_threads(&self, x: &[f32], t: usize, threads: usize) -> Vec<f32> {
        match self {
            LinearView::Dense { w, b, f_in, f_out, precision } => {
                dense_linear_prec_with_threads(
                    x, w, Some(b), t, *f_in, *f_out, *precision, threads,
                )
            }
            LinearView::Dyad { wl, wu, b, dims, variant, precision } => {
                dyad_linear_prec_with_threads(
                    wl, wu, x, *dims, *variant, t, Some(b), *precision, threads,
                )
            }
        }
    }

    /// [`LinearView::forward_with_threads`] into a caller-owned output
    /// (`t * f_out` values, fully overwritten) — the allocation-free
    /// entry point for arena-backed hot loops.
    pub fn forward_into(&self, x: &[f32], t: usize, threads: usize, y: &mut [f32]) {
        match self {
            LinearView::Dense { w, b, f_in, f_out, precision } => {
                dense_linear_prec_into(x, w, Some(b), t, *f_in, *f_out, *precision, threads, y);
            }
            LinearView::Dyad { wl, wu, b, dims, variant, precision } => {
                dyad_linear_prec_into(
                    wl, wu, x, *dims, *variant, t, Some(b), *precision, threads, y,
                );
            }
        }
    }

    /// Materialise the full `(f_out, f_in)` weight matrix.
    pub fn materialize(&self) -> Vec<f32> {
        match self {
            LinearView::Dense { w, .. } => w.to_vec(),
            LinearView::Dyad { wl, wu, dims, variant, .. } => {
                dyad_full(wl, wu, *dims, *variant)
            }
        }
    }

    /// Backward pass for `y = x @ W^T + b` given upstream `dy (t, f_out)`
    /// and the layer input `x (t, f_in)`.
    ///
    /// Returns the parameter gradients in *spec order* (`[dw, db]` for
    /// dense, `[dwl, dwu, db]` for DYAD) and, when requested, `dx`.
    pub fn backward(
        &self,
        x: &[f32],
        dy: &[f32],
        t: usize,
        need_dx: bool,
    ) -> Result<(Vec<Vec<f32>>, Option<Vec<f32>>)> {
        self.backward_with_threads(x, dy, t, need_dx, num_threads())
    }

    /// [`LinearView::backward`] on an explicit worker count.
    pub fn backward_with_threads(
        &self,
        x: &[f32],
        dy: &[f32],
        t: usize,
        need_dx: bool,
        threads: usize,
    ) -> Result<(Vec<Vec<f32>>, Option<Vec<f32>>)> {
        let (f_in, f_out) = (self.f_in(), self.f_out());
        if x.len() != t * f_in || dy.len() != t * f_out {
            bail!(
                "linear backward: x {} / dy {} for t={t}, f_in={f_in}, f_out={f_out}",
                x.len(),
                dy.len()
            );
        }
        let db = col_sums(dy, f_out);
        Ok(match self {
            LinearView::Dense { w, precision, .. } => {
                // dW = dy^T @ x  (f_out, f_in) — both streams are f32,
                // so the weight gradient is always full precision
                let dyt = transpose(dy, t, f_out);
                let dw = matmul_fast_with_threads(&dyt, x, f_out, t, f_in, threads);
                // dx = dy @ W  (t, f_in) — the weight stream, at the
                // view's precision
                let dx = need_dx.then(|| {
                    matmul_fast_prec_with_threads(dy, w, t, f_out, f_in, *precision, threads)
                });
                (vec![dw, db], dx)
            }
            LinearView::Dyad { wl, wu, dims, variant, precision, .. } => {
                let (dwl, dwu) = dyad_backward_dw_with_threads(x, dy, *dims, *variant, t, threads);
                let dx = need_dx.then(|| {
                    dyad_linear_backward_dx_prec_with_threads(
                        wl, wu, dy, *dims, *variant, t, *precision, threads,
                    )
                });
                (vec![dwl, dwu, db], dx)
            }
        })
    }

    /// Parameter-gradient names for this view under `prefix`, in the
    /// same order [`LinearView::backward`] returns the gradients
    /// (`[w, b]` dense, `[wl, wu, b]` DYAD) — the catalog's
    /// `ff_linear_specs` order.
    pub fn grad_names(&self, prefix: &str) -> Vec<String> {
        match self {
            LinearView::Dense { .. } => {
                vec![format!("{prefix}.w"), format!("{prefix}.b")]
            }
            LinearView::Dyad { .. } => vec![
                format!("{prefix}.wl"),
                format!("{prefix}.wu"),
                format!("{prefix}.b"),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect()
    }

    /// The structured backward equals the old materialise-and-project
    /// path (`dyad::math::dyad_backward`) to float tolerance: all
    /// variants, rectangular blocks, `n_dyad == 1` and
    /// `n_dyad == f_out` edges.
    #[test]
    fn structured_backward_matches_materialise_and_project() {
        let mut rng = Rng::new(77);
        for (nd, n_in, n_out, t) in
            [(2, 3, 2, 4), (1, 5, 3, 2), (4, 2, 1, 3), (3, 4, 5, 1)]
        {
            let dims = DyadDims { n_dyad: nd, n_in, n_out };
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let b = rand_vec(&mut rng, dims.f_out());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let dy = rand_vec(&mut rng, t * dims.f_out());
            for variant in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
                let view = LinearView::Dyad {
                    wl: &wl,
                    wu: &wu,
                    b: &b,
                    dims,
                    variant,
                    precision: Precision::F32,
                };
                let (grads, dx) = view.backward(&x, &dy, t, true).unwrap();
                let (rwl, rwu, rdx) =
                    crate::dyad::math::dyad_backward(&wl, &wu, &x, &dy, dims, variant, t);
                for (name, got, want) in [
                    ("dwl", &grads[0], &rwl),
                    ("dwu", &grads[1], &rwu),
                    ("dx", dx.as_ref().unwrap(), &rdx),
                ] {
                    for (i, (a, b)) in got.iter().zip(want).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{variant:?} {dims:?} {name}[{i}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    /// Finite-difference gradcheck of the structured DYAD backward
    /// through a sum(y * ct) scalar loss: all variants, rectangular
    /// blocks, `n_dyad == 1` and `n_dyad == f_out` edges.
    #[test]
    fn dyad_backward_gradcheck() {
        let mut rng = Rng::new(42);
        for dims in [
            DyadDims { n_dyad: 2, n_in: 3, n_out: 2 },
            DyadDims { n_dyad: 1, n_in: 4, n_out: 3 },
            DyadDims { n_dyad: 4, n_in: 2, n_out: 1 },
        ] {
            dyad_backward_gradcheck_at(&mut rng, dims);
        }
    }

    fn dyad_backward_gradcheck_at(rng: &mut Rng, dims: DyadDims) {
        let t = 4;
        for variant in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
            let wl = rand_vec(rng, dims.component_params());
            let wu = rand_vec(rng, dims.component_params());
            let b = rand_vec(rng, dims.f_out());
            let x = rand_vec(rng, t * dims.f_in());
            let ct = rand_vec(rng, t * dims.f_out());
            let loss = |wl: &[f32], wu: &[f32], b: &[f32], x: &[f32]| -> f32 {
                let v = LinearView::Dyad {
                    wl,
                    wu,
                    b,
                    dims,
                    variant,
                    precision: Precision::F32,
                };
                v.forward(x, t).iter().zip(ct.iter()).map(|(a, c)| a * c).sum()
            };
            let view = LinearView::Dyad {
                wl: &wl,
                wu: &wu,
                b: &b,
                dims,
                variant,
                precision: Precision::F32,
            };
            let (grads, dx) = view.backward(&x, &ct, t, true).unwrap();
            let (dwl, dwu, db) = (&grads[0], &grads[1], &grads[2]);
            let dx = dx.unwrap();
            let h = 1e-2f32;
            let check = |an: f32, fd: f32, what: &str| {
                assert!(
                    (an - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{variant:?} {what}: analytic {an} vs fd {fd}"
                );
            };
            for idx in [0usize, 3, dims.component_params() - 1] {
                let mut wp = wl.clone();
                wp[idx] += h;
                let mut wm = wl.clone();
                wm[idx] -= h;
                let fd = (loss(&wp, &wu, &b, &x) - loss(&wm, &wu, &b, &x)) / (2.0 * h);
                check(dwl[idx], fd, "dwl");
                let mut up = wu.clone();
                up[idx] += h;
                let mut um = wu.clone();
                um[idx] -= h;
                let fd = (loss(&wl, &up, &b, &x) - loss(&wl, &um, &b, &x)) / (2.0 * h);
                check(dwu[idx], fd, "dwu");
            }
            for idx in [0usize, dims.f_out() - 1] {
                let mut bp = b.clone();
                bp[idx] += h;
                let mut bm = b.clone();
                bm[idx] -= h;
                let fd = (loss(&wl, &wu, &bp, &x) - loss(&wl, &wu, &bm, &x)) / (2.0 * h);
                check(db[idx], fd, "db");
            }
            for idx in [0usize, t * dims.f_in() - 1] {
                let mut xp = x.clone();
                xp[idx] += h;
                let mut xm = x.clone();
                xm[idx] -= h;
                let fd = (loss(&wl, &wu, &b, &xp) - loss(&wl, &wu, &b, &xm)) / (2.0 * h);
                check(dx[idx], fd, "dx");
            }
        }
    }

    #[test]
    fn dense_backward_gradcheck() {
        let mut rng = Rng::new(9);
        let (f_in, f_out, t) = (5, 3, 4);
        let w = rand_vec(&mut rng, f_out * f_in);
        let b = rand_vec(&mut rng, f_out);
        let x = rand_vec(&mut rng, t * f_in);
        let ct = rand_vec(&mut rng, t * f_out);
        let loss = |w: &[f32], x: &[f32]| -> f32 {
            let v = LinearView::Dense { w, b: &b, f_in, f_out, precision: Precision::F32 };
            v.forward(x, t).iter().zip(ct.iter()).map(|(a, c)| a * c).sum()
        };
        let view = LinearView::Dense { w: &w, b: &b, f_in, f_out, precision: Precision::F32 };
        let (grads, dx) = view.backward(&x, &ct, t, true).unwrap();
        let h = 1e-2f32;
        for idx in [0usize, 7, f_out * f_in - 1] {
            let mut wp = w.clone();
            wp[idx] += h;
            let mut wm = w.clone();
            wm[idx] -= h;
            let fd = (loss(&wp, &x) - loss(&wm, &x)) / (2.0 * h);
            assert!((grads[0][idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()));
        }
        let dx = dx.unwrap();
        let mut xp = x.clone();
        xp[2] += h;
        let mut xm = x.clone();
        xm[2] -= h;
        let fd = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * h);
        assert!((dx[2] - fd).abs() < 2e-2 * (1.0 + fd.abs()));
    }

    /// bf16 rounds weights *elementwise*, which commutes with the
    /// block transpose the dx pass applies — so the bf16 `dx` is the
    /// exact input-gradient of the bf16 *forward*, and finite
    /// differences of that forward must match it. (i8 quantises per
    /// row along different axes in fwd vs dx, so it gets the
    /// tolerance-vs-f32 treatment in the kernel tests instead.)
    #[test]
    fn bf16_dx_gradchecks_against_bf16_forward() {
        let mut rng = Rng::new(101);
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 4 };
        let t = 3;
        for variant in [Variant::It, Variant::ItCat, Variant::Ot, Variant::Dt] {
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let b = rand_vec(&mut rng, dims.f_out());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let ct = rand_vec(&mut rng, t * dims.f_out());
            let loss = |x: &[f32]| -> f32 {
                let v = LinearView::Dyad {
                    wl: &wl,
                    wu: &wu,
                    b: &b,
                    dims,
                    variant,
                    precision: Precision::Bf16,
                };
                v.forward(x, t).iter().zip(ct.iter()).map(|(a, c)| a * c).sum()
            };
            let view = LinearView::Dyad {
                wl: &wl,
                wu: &wu,
                b: &b,
                dims,
                variant,
                precision: Precision::Bf16,
            };
            let (_, dx) = view.backward(&x, &ct, t, true).unwrap();
            let dx = dx.unwrap();
            let h = 1e-2f32;
            for idx in [0usize, 5, t * dims.f_in() - 1] {
                let mut xp = x.to_vec();
                xp[idx] += h;
                let mut xm = x.to_vec();
                xm[idx] -= h;
                let fd = (loss(&xp) - loss(&xm)) / (2.0 * h);
                assert!(
                    (dx[idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{variant:?} dx[{idx}]: analytic {} vs fd {fd}",
                    dx[idx]
                );
            }
        }
    }

    /// `forward_into` on a dirty caller buffer is bitwise identical to
    /// the `Vec`-returning forward, both arms.
    #[test]
    fn forward_into_matches_forward_bitwise() {
        let mut rng = Rng::new(55);
        let dims = DyadDims { n_dyad: 2, n_in: 4, n_out: 3 };
        let t = 5;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let b = rand_vec(&mut rng, dims.f_out());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let wd = rand_vec(&mut rng, dims.f_out() * dims.f_in());
        let views = [
            LinearView::Dyad {
                wl: &wl,
                wu: &wu,
                b: &b,
                dims,
                variant: Variant::ItCat,
                precision: Precision::Bf16,
            },
            LinearView::Dense {
                w: &wd,
                b: &b,
                f_in: dims.f_in(),
                f_out: dims.f_out(),
                precision: Precision::F32,
            },
        ];
        for view in &views {
            let want = view.forward_with_threads(&x, t, 2);
            let mut got = vec![f32::NAN; t * view.f_out()];
            view.forward_into(&x, t, 2, &mut got);
            assert_eq!(got, want);
        }
    }

    /// Quantized views stay close to the f32 view on the forward —
    /// the view-level version of the kernel quantisation tests, and
    /// the invariant the backend quality gate asserts end to end.
    #[test]
    fn quantized_views_track_f32_forward() {
        let mut rng = Rng::new(103);
        let dims = DyadDims { n_dyad: 4, n_in: 8, n_out: 6 };
        let t = 5;
        let wl = rand_vec(&mut rng, dims.component_params());
        let wu = rand_vec(&mut rng, dims.component_params());
        let b = rand_vec(&mut rng, dims.f_out());
        let x = rand_vec(&mut rng, t * dims.f_in());
        let mk = |precision: Precision| LinearView::Dyad {
            wl: &wl,
            wu: &wu,
            b: &b,
            dims,
            variant: Variant::ItCat,
            precision,
        };
        let base = mk(Precision::F32).forward(&x, t);
        for (precision, tol) in [(Precision::Bf16, 1e-2f32), (Precision::I8, 3e-2f32)] {
            let got = mk(precision).forward(&x, t);
            let mut num = 0.0f32;
            let mut den = 0.0f32;
            for (a, b) in got.iter().zip(&base) {
                num += (a - b) * (a - b);
                den += b * b;
            }
            let rel = (num / den.max(1e-12)).sqrt();
            assert!(rel < tol, "{precision:?}: relative L2 {rel} >= {tol}");
        }
    }
}

//! A borrowed view of one linear layer (DENSE or DYAD) with row-major
//! forward and backward passes.
//!
//! Forward runs the fast path: `dyad::kernel::dense_linear` /
//! `dyad::kernel::dyad_linear` (the fused blocked schedule).
//!
//! Backward materialises the full `(f_out, f_in)` matrix once and runs
//! dense gradient matmuls, then projects `dW` back onto the DYAD block
//! structure (each `wl`/`wu` entry reads the `dW` cell its layout
//! places it in — permutations included). This is exactly correct for
//! both components, including where their supports overlap, because
//! `W = W1 + W2` is linear in each stored entry. A structured
//! (materialisation-free) backward is a ROADMAP item.

use anyhow::{bail, Result};

use crate::dyad::kernel::{dense_linear, dyad_linear, matmul_fast, transpose};
use crate::dyad::layout::{dyad_full, perm_vector};
use crate::dyad::{DyadDims, Variant};

use super::ops::col_sums;

pub enum LinearView<'a> {
    Dense {
        w: &'a [f32],
        b: &'a [f32],
        f_in: usize,
        f_out: usize,
    },
    Dyad {
        wl: &'a [f32],
        wu: &'a [f32],
        b: &'a [f32],
        dims: DyadDims,
        variant: Variant,
    },
}

impl LinearView<'_> {
    pub fn f_in(&self) -> usize {
        match self {
            LinearView::Dense { f_in, .. } => *f_in,
            LinearView::Dyad { dims, .. } => dims.f_in(),
        }
    }

    pub fn f_out(&self) -> usize {
        match self {
            LinearView::Dense { f_out, .. } => *f_out,
            LinearView::Dyad { dims, .. } => dims.f_out(),
        }
    }

    /// `x (t, f_in)` -> `(t, f_out)`, bias applied.
    pub fn forward(&self, x: &[f32], t: usize) -> Vec<f32> {
        match self {
            LinearView::Dense { w, b, f_in, f_out } => {
                dense_linear(x, w, Some(b), t, *f_in, *f_out)
            }
            LinearView::Dyad { wl, wu, b, dims, variant } => {
                dyad_linear(wl, wu, x, *dims, *variant, t, Some(b))
            }
        }
    }

    /// Materialise the full `(f_out, f_in)` weight matrix.
    pub fn materialize(&self) -> Vec<f32> {
        match self {
            LinearView::Dense { w, .. } => w.to_vec(),
            LinearView::Dyad { wl, wu, dims, variant, .. } => {
                dyad_full(wl, wu, *dims, *variant)
            }
        }
    }

    /// Backward pass for `y = x @ W^T + b` given upstream `dy (t, f_out)`
    /// and the layer input `x (t, f_in)`.
    ///
    /// Returns the parameter gradients in *spec order* (`[dw, db]` for
    /// dense, `[dwl, dwu, db]` for DYAD) and, when requested, `dx`.
    pub fn backward(
        &self,
        x: &[f32],
        dy: &[f32],
        t: usize,
        need_dx: bool,
    ) -> Result<(Vec<Vec<f32>>, Option<Vec<f32>>)> {
        let (f_in, f_out) = (self.f_in(), self.f_out());
        if x.len() != t * f_in || dy.len() != t * f_out {
            bail!(
                "linear backward: x {} / dy {} for t={t}, f_in={f_in}, f_out={f_out}",
                x.len(),
                dy.len()
            );
        }
        // dW = dy^T @ x  (f_out, f_in)
        let dyt = transpose(dy, t, f_out);
        let dw_full = matmul_fast(&dyt, x, f_out, t, f_in);
        let db = col_sums(dy, f_out);
        let dx = if need_dx {
            // dx = dy @ W  (t, f_in)
            let w_full = self.materialize();
            Some(matmul_fast(dy, &w_full, t, f_out, f_in))
        } else {
            None
        };
        let grads = match self {
            LinearView::Dense { .. } => vec![dw_full, db],
            LinearView::Dyad { dims, variant, .. } => {
                let (dwl, dwu) = project_dyad_grads(&dw_full, *dims, *variant);
                vec![dwl, dwu, db]
            }
        };
        Ok((grads, dx))
    }
}

/// Read the block-structured component gradients out of the full `dW`.
fn project_dyad_grads(dw: &[f32], dims: DyadDims, variant: Variant) -> (Vec<f32>, Vec<f32>) {
    let DyadDims { n_dyad, n_in, n_out } = dims;
    let f_in = dims.f_in();
    let in_perm = matches!(variant, Variant::It | Variant::Dt);
    let out_perm = matches!(variant, Variant::Ot | Variant::Dt);
    let pi_in = perm_vector(n_in, n_dyad);
    let pi_out = perm_vector(n_out, n_dyad);
    let mut dwl = vec![0.0f32; dims.component_params()];
    let mut dwu = vec![0.0f32; dims.component_params()];
    for i in 0..n_dyad {
        for o in 0..n_out {
            for k in 0..n_in {
                let idx = (i * n_out + o) * n_in + k;
                dwl[idx] = dw[(i * n_out + o) * f_in + (i * n_in + k)];
                let r = if out_perm { pi_out[i * n_out + o] } else { i * n_out + o };
                let c = if in_perm { pi_in[i * n_in + k] } else { i * n_in + k };
                dwu[idx] = dw[r * f_in + c];
            }
        }
    }
    (dwl, dwu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect()
    }

    /// Finite-difference gradcheck of the DYAD backward through a
    /// sum(y * ct) scalar loss, all variants, rectangular blocks.
    #[test]
    fn dyad_backward_gradcheck() {
        let mut rng = Rng::new(42);
        let dims = DyadDims { n_dyad: 2, n_in: 3, n_out: 2 };
        let t = 4;
        for variant in [Variant::It, Variant::Ot, Variant::Dt] {
            let wl = rand_vec(&mut rng, dims.component_params());
            let wu = rand_vec(&mut rng, dims.component_params());
            let b = rand_vec(&mut rng, dims.f_out());
            let x = rand_vec(&mut rng, t * dims.f_in());
            let ct = rand_vec(&mut rng, t * dims.f_out());
            let loss = |wl: &[f32], wu: &[f32], b: &[f32], x: &[f32]| -> f32 {
                let v = LinearView::Dyad { wl, wu, b, dims, variant };
                v.forward(x, t).iter().zip(ct.iter()).map(|(a, c)| a * c).sum()
            };
            let view = LinearView::Dyad { wl: &wl, wu: &wu, b: &b, dims, variant };
            let (grads, dx) = view.backward(&x, &ct, t, true).unwrap();
            let (dwl, dwu, db) = (&grads[0], &grads[1], &grads[2]);
            let dx = dx.unwrap();
            let h = 1e-2f32;
            let check = |an: f32, fd: f32, what: &str| {
                assert!(
                    (an - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{variant:?} {what}: analytic {an} vs fd {fd}"
                );
            };
            for idx in [0usize, 3, dims.component_params() - 1] {
                let mut wp = wl.clone();
                wp[idx] += h;
                let mut wm = wl.clone();
                wm[idx] -= h;
                let fd = (loss(&wp, &wu, &b, &x) - loss(&wm, &wu, &b, &x)) / (2.0 * h);
                check(dwl[idx], fd, "dwl");
                let mut up = wu.clone();
                up[idx] += h;
                let mut um = wu.clone();
                um[idx] -= h;
                let fd = (loss(&wl, &up, &b, &x) - loss(&wl, &um, &b, &x)) / (2.0 * h);
                check(dwu[idx], fd, "dwu");
            }
            for idx in [0usize, dims.f_out() - 1] {
                let mut bp = b.clone();
                bp[idx] += h;
                let mut bm = b.clone();
                bm[idx] -= h;
                let fd = (loss(&wl, &wu, &bp, &x) - loss(&wl, &wu, &bm, &x)) / (2.0 * h);
                check(db[idx], fd, "db");
            }
            for idx in [0usize, t * dims.f_in() - 1] {
                let mut xp = x.clone();
                xp[idx] += h;
                let mut xm = x.clone();
                xm[idx] -= h;
                let fd = (loss(&wl, &wu, &b, &xp) - loss(&wl, &wu, &b, &xm)) / (2.0 * h);
                check(dx[idx], fd, "dx");
            }
        }
    }

    #[test]
    fn dense_backward_gradcheck() {
        let mut rng = Rng::new(9);
        let (f_in, f_out, t) = (5, 3, 4);
        let w = rand_vec(&mut rng, f_out * f_in);
        let b = rand_vec(&mut rng, f_out);
        let x = rand_vec(&mut rng, t * f_in);
        let ct = rand_vec(&mut rng, t * f_out);
        let loss = |w: &[f32], x: &[f32]| -> f32 {
            let v = LinearView::Dense { w, b: &b, f_in, f_out };
            v.forward(x, t).iter().zip(ct.iter()).map(|(a, c)| a * c).sum()
        };
        let view = LinearView::Dense { w: &w, b: &b, f_in, f_out };
        let (grads, dx) = view.backward(&x, &ct, t, true).unwrap();
        let h = 1e-2f32;
        for idx in [0usize, 7, f_out * f_in - 1] {
            let mut wp = w.clone();
            wp[idx] += h;
            let mut wm = w.clone();
            wm[idx] -= h;
            let fd = (loss(&wp, &x) - loss(&wm, &x)) / (2.0 * h);
            assert!((grads[0][idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()));
        }
        let dx = dx.unwrap();
        let mut xp = x.clone();
        xp[2] += h;
        let mut xm = x.clone();
        xm[2] -= h;
        let fd = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * h);
        assert!((dx[2] - fd).abs() < 2e-2 * (1.0 + fd.abs()));
    }
}

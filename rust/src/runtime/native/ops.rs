//! Elementwise / normalisation primitives shared by the native model
//! implementations. All row-major, f32, matching the L2 JAX semantics
//! (tanh-approximate GELU, population-variance LayerNorm, eps 1e-5).

/// jax.nn.gelu (approximate=True): 0.5x(1 + tanh(c(x + a x^3))).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    const A: f32 = 0.044715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// d/dx of the tanh-approximate GELU.
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    const A: f32 = 0.044715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * A * x * x)
}

pub fn gelu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = gelu(*v);
    }
}

pub fn relu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// LayerNorm over the last axis of row-major `(rows, d)`:
/// `(x - mean) / sqrt(var + eps) * scale + bias`, population variance.
pub fn layer_norm(x: &mut [f32], d: usize, scale: &[f32], bias: &[f32]) {
    const EPS: f32 = 1e-5;
    assert_eq!(scale.len(), d);
    assert_eq!(bias.len(), d);
    for row in x.chunks_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (v, (&sc, &b)) in row.iter_mut().zip(scale.iter().zip(bias)) {
            *v = (*v - mean) * inv * sc + b;
        }
    }
}

/// In-place softmax over one row.
pub fn softmax_row(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// log-softmax of one row into `out`.
pub fn log_softmax_row(row: &[f32], out: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for &v in row {
        sum += (v - max).exp();
    }
    let lse = max + sum.ln();
    for (o, &v) in out.iter_mut().zip(row) {
        *o = v - lse;
    }
}

/// Column sums of a row-major `(rows, n)` matrix (bias gradients).
pub fn col_sums(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for row in x.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        // large |x|: identity / zero asymptotes
        assert!((gelu(6.0) - 6.0).abs() < 1e-4);
        assert!(gelu(-6.0).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.3, 0.0, 0.4, 1.2, 2.5] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            let an = gelu_grad(x);
            assert!((fd - an).abs() < 1e-3, "x={x}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn layer_norm_normalises() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let scale = vec![1.0; 4];
        let bias = vec![0.0; 4];
        layer_norm(&mut x, 4, &scale, &bias);
        let mean: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_and_log_softmax_agree() {
        let row = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut sm = row.clone();
        softmax_row(&mut sm);
        assert!((sm.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        let mut lsm = vec![0.0; 4];
        log_softmax_row(&row, &mut lsm);
        for (a, b) in sm.iter().zip(&lsm) {
            assert!((a.ln() - b).abs() < 1e-5);
        }
    }
}

//! Elementwise / normalisation primitives shared by the native model
//! implementations. All row-major, f32, matching the L2 JAX semantics
//! (tanh-approximate GELU, population-variance LayerNorm, eps 1e-5).
//!
//! Every `Vec`-returning primitive draws its output from the
//! thread-local kernel recycler (`dyad::kernel::scratch`), so a
//! steady-state loop that recycles its buffers (the layer stack does,
//! via `Workspace::recycle`) allocates nothing here after warmup.

use crate::dyad::kernel::{axpy, dot, parallel_rows, scratch};

/// jax.nn.gelu (approximate=True): 0.5x(1 + tanh(c(x + a x^3))).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    const A: f32 = 0.044715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// d/dx of the tanh-approximate GELU.
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    const A: f32 = 0.044715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * A * x * x)
}

pub fn gelu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = gelu(*v);
    }
}

pub fn relu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

const LN_EPS: f32 = 1e-5;

/// LayerNorm over the last axis of row-major `(rows, d)`:
/// `(x - mean) / sqrt(var + eps) * scale + bias`, population variance.
pub fn layer_norm(x: &mut [f32], d: usize, scale: &[f32], bias: &[f32]) {
    assert_eq!(scale.len(), d);
    assert_eq!(bias.len(), d);
    for row in x.chunks_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (v, (&sc, &b)) in row.iter_mut().zip(scale.iter().zip(bias)) {
            *v = (*v - mean) * inv * sc + b;
        }
    }
}

/// LayerNorm forward that also returns what the backward needs:
/// `y = xhat * scale + bias`, plus the normalised activations `xhat`
/// (rows, d) and the per-row inverse std `inv` (rows).
pub fn layer_norm_forward(
    x: &[f32],
    d: usize,
    scale: &[f32],
    bias: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(scale.len(), d);
    assert_eq!(bias.len(), d);
    let rows = x.len() / d;
    let mut y = scratch::take_f32(x.len());
    let mut xhat = scratch::take_f32(x.len());
    let mut inv = scratch::take_f32(rows);
    for (r, row) in x.chunks(d).enumerate() {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for (j, &v) in row.iter().enumerate() {
            xh[j] = (v - mean) * iv;
            yr[j] = xh[j] * scale[j] + bias[j];
        }
    }
    (y, xhat, inv)
}

/// LayerNorm backward from the cached `xhat`/`inv` of
/// [`layer_norm_forward`]:
///
/// `dxhat = dy * scale`;
/// `dx = inv * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))`;
/// `dscale = Σ_rows dy * xhat`; `dbias = Σ_rows dy`.
///
/// Accumulation runs in fixed row order (deterministic).
pub fn layer_norm_backward(
    dy: &[f32],
    xhat: &[f32],
    inv: &[f32],
    d: usize,
    scale: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(dy.len(), xhat.len());
    assert_eq!(dy.len(), inv.len() * d);
    let mut dx = scratch::take_f32(dy.len());
    let mut dscale = scratch::take_f32(d);
    let mut dbias = scratch::take_f32(d);
    let mut dxhat = scratch::take_f32(d);
    for (r, (dyr, xh)) in dy.chunks(d).zip(xhat.chunks(d)).enumerate() {
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            dscale[j] += dyr[j] * xh[j];
            dbias[j] += dyr[j];
            let dh = dyr[j] * scale[j];
            dxhat[j] = dh;
            m1 += dh;
            m2 += dh * xh[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = inv[r] * (dxhat[j] - m1 - xh[j] * m2);
        }
    }
    scratch::put_f32(dxhat);
    (dx, dscale, dbias)
}

/// Softmax-jacobian backward for one row:
/// `dscore_j = p_j * (dp_j - Σ_k p_k * dp_k)` where `p` is the
/// softmax output and `dp` the upstream gradient.
pub fn softmax_backward_row(p: &[f32], dp: &[f32], dscore: &mut [f32]) {
    debug_assert_eq!(p.len(), dp.len());
    debug_assert_eq!(p.len(), dscore.len());
    let dot: f32 = p.iter().zip(dp).map(|(a, b)| a * b).sum();
    for ((o, &pv), &dv) in dscore.iter_mut().zip(p).zip(dp) {
        *o = pv * (dv - dot);
    }
}

/// One row of softmax cross-entropy with its gradient: returns
/// `-log softmax(row)[target]` and writes
/// `(softmax(row) - onehot(target)) * scale` into `drow`. `logp` is
/// caller-owned scratch (len = row len). Shared by the MNIST and LM
/// losses so the softmax/log-softmax math lives in one place.
pub fn softmax_xent_row(
    row: &[f32],
    target: usize,
    scale: f32,
    drow: &mut [f32],
    logp: &mut [f32],
) -> f32 {
    log_softmax_row(row, logp);
    let loss = -logp[target];
    for (o, &lp) in drow.iter_mut().zip(logp.iter()) {
        *o = lp.exp() * scale;
    }
    drow[target] -= scale;
    loss
}

/// In-place softmax over one row.
pub fn softmax_row(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// log-softmax of one row into `out`.
pub fn log_softmax_row(row: &[f32], out: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for &v in row {
        sum += (v - max).exp();
    }
    let lse = max + sum.ln();
    for (o, &v) in out.iter_mut().zip(row) {
        *o = v - lse;
    }
}

/// One causal attention step against cached K/V. xtask:hot-path
///
/// The new-token queries `q` are `(a*nh, hd)` head-blocked rows for
/// the `a` **active** lanes (compacted); the caches hold the full
/// batch, `(b*nh, s, hd)` head-blocked, with `lens[lane]` valid
/// positions per lane — the current token's K/V row must already be
/// appended, so `lens[lane]` **includes** it. `lanes[g]` maps compact
/// group `g` (= row / nh) back to its cache lane. Writes the per-head
/// context rows into `out` (`(a*nh, hd)`, must be zeroed).
///
/// Op order per row is byte-for-byte the `ti = len-1` iteration of the
/// batch inference kernel (`layers::Attention::forward`): dot-scale
/// scores over positions `0..len`, [`softmax_row`], then `axpy`
/// accumulation in position order — which is what makes incremental
/// decode bitwise identical to full recompute. Pool-parallel over
/// `(lane, head)` rows; score scratch comes from the recycler, so the
/// steady state allocates nothing.
pub fn attention_decode_step(
    out: &mut [f32],
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    lanes: &[usize],
    lens: &[usize],
    nh: usize,
    s: usize,
    hd: usize,
    threads: usize,
) {
    debug_assert_eq!(out.len(), q.len());
    debug_assert_eq!(out.len(), lanes.len() * nh * hd);
    let scale = 1.0 / (hd as f32).sqrt();
    parallel_rows(out, hd, threads, &|r, row| {
        let lane = lanes[r / nh];
        let head = r % nh;
        let len = lens[lane];
        debug_assert!(len >= 1 && len <= s);
        let blk = ((lane * nh + head) * s) * hd;
        let kb = &k_cache[blk..blk + len * hd];
        let vb = &v_cache[blk..blk + len * hd];
        let qrow = &q[r * hd..(r + 1) * hd];
        // fixed-size score scratch (not `len`): a constant request size
        // is what keeps the best-fit recycler at 100% hits while the
        // cache grows token by token
        let mut att = scratch::take_f32(s);
        {
            let att = &mut att[..len];
            for (tj, a) in att.iter_mut().enumerate() {
                *a = dot(qrow, &kb[tj * hd..(tj + 1) * hd]) * scale;
            }
            softmax_row(att);
            for (tj, &a) in att.iter().enumerate() {
                axpy(row, a, &vb[tj * hd..(tj + 1) * hd]);
            }
        }
        scratch::put_f32(att);
    });
}

/// Column sums of a row-major `(rows, n)` matrix (bias gradients).
pub fn col_sums(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = scratch::take_f32(n);
    for row in x.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        // large |x|: identity / zero asymptotes
        assert!((gelu(6.0) - 6.0).abs() < 1e-4);
        assert!(gelu(-6.0).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.3, 0.0, 0.4, 1.2, 2.5] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            let an = gelu_grad(x);
            assert!((fd - an).abs() < 1e-3, "x={x}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn layer_norm_normalises() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let scale = vec![1.0; 4];
        let bias = vec![0.0; 4];
        layer_norm(&mut x, 4, &scale, &bias);
        let mean: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    /// layer_norm_forward must agree with the in-place layer_norm and
    /// its backward with central finite differences of a sum(y * ct)
    /// loss (per element: dx, dscale, dbias).
    #[test]
    fn layer_norm_backward_matches_finite_difference() {
        let d = 6;
        let rows = 3;
        let mut rng = crate::util::rng::Rng::new(12);
        let x: Vec<f32> = (0..rows * d).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let scale: Vec<f32> = (0..d).map(|_| rng.uniform(0.5, 1.5)).collect();
        let bias: Vec<f32> = (0..d).map(|_| rng.uniform(-0.2, 0.2)).collect();
        let ct: Vec<f32> = (0..rows * d).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let loss = |x: &[f32], scale: &[f32], bias: &[f32]| -> f32 {
            let mut y = x.to_vec();
            layer_norm(&mut y, d, scale, bias);
            y.iter().zip(&ct).map(|(a, c)| a * c).sum()
        };
        let (y, xhat, inv) = layer_norm_forward(&x, d, &scale, &bias);
        let mut y2 = x.clone();
        layer_norm(&mut y2, d, &scale, &bias);
        assert_eq!(y, y2, "forward paths diverge");
        let (dx, dscale, dbias) = layer_norm_backward(&ct, &xhat, &inv, d, &scale);
        let h = 1e-3f32;
        let check = |an: f32, fd: f32, what: &str| {
            assert!(
                (an - fd).abs() < 1e-2 * (1.0 + fd.abs()),
                "{what}: analytic {an} vs fd {fd}"
            );
        };
        for idx in [0usize, 7, rows * d - 1] {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let fd = (loss(&xp, &scale, &bias) - loss(&xm, &scale, &bias)) / (2.0 * h);
            check(dx[idx], fd, "dx");
        }
        for idx in [0usize, d - 1] {
            let mut sp = scale.clone();
            sp[idx] += h;
            let mut sm = scale.clone();
            sm[idx] -= h;
            let fd = (loss(&x, &sp, &bias) - loss(&x, &sm, &bias)) / (2.0 * h);
            check(dscale[idx], fd, "dscale");
            let mut bp = bias.clone();
            bp[idx] += h;
            let mut bm = bias.clone();
            bm[idx] -= h;
            let fd = (loss(&x, &scale, &bp) - loss(&x, &scale, &bm)) / (2.0 * h);
            check(dbias[idx], fd, "dbias");
        }
    }

    /// Softmax-jacobian backward vs finite differences of
    /// sum(softmax(score) * ct).
    #[test]
    fn softmax_backward_matches_finite_difference() {
        let mut rng = crate::util::rng::Rng::new(4);
        let n = 5;
        let score: Vec<f32> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let ct: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let loss = |sc: &[f32]| -> f32 {
            let mut p = sc.to_vec();
            softmax_row(&mut p);
            p.iter().zip(&ct).map(|(a, c)| a * c).sum()
        };
        let mut p = score.clone();
        softmax_row(&mut p);
        let mut dscore = vec![0.0f32; n];
        softmax_backward_row(&p, &ct, &mut dscore);
        let h = 1e-3f32;
        for idx in 0..n {
            let mut sp = score.clone();
            sp[idx] += h;
            let mut sm = score.clone();
            sm[idx] -= h;
            let fd = (loss(&sp) - loss(&sm)) / (2.0 * h);
            assert!(
                (dscore[idx] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "dscore[{idx}]: {} vs fd {fd}",
                dscore[idx]
            );
        }
    }

    /// softmax_xent_row: loss equals -log_softmax[target]; the gradient
    /// equals (softmax - onehot) * scale and finite differences agree.
    #[test]
    fn softmax_xent_row_loss_and_grad() {
        let mut rng = crate::util::rng::Rng::new(8);
        let n = 7;
        let target = 3usize;
        let scale = 0.25f32;
        let row: Vec<f32> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let mut drow = vec![0.0f32; n];
        let mut logp = vec![0.0f32; n];
        let loss = softmax_xent_row(&row, target, scale, &mut drow, &mut logp);
        assert!((loss + logp[target]).abs() < 1e-6);
        // gradient rows sum to zero (softmax sums to one, one-hot too)
        let sum: f32 = drow.iter().sum();
        assert!(sum.abs() < 1e-5, "grad sum {sum}");
        let h = 1e-3f32;
        for idx in [0usize, target, n - 1] {
            let fd = {
                let f = |r: &[f32]| -> f32 {
                    let mut lp = vec![0.0; n];
                    log_softmax_row(r, &mut lp);
                    -lp[target] * scale
                };
                let mut rp = row.clone();
                rp[idx] += h;
                let mut rm = row.clone();
                rm[idx] -= h;
                (f(&rp) - f(&rm)) / (2.0 * h)
            };
            assert!(
                (drow[idx] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "drow[{idx}]: {} vs fd {fd}",
                drow[idx]
            );
        }
    }

    #[test]
    fn softmax_and_log_softmax_agree() {
        let row = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut sm = row.clone();
        softmax_row(&mut sm);
        assert!((sm.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        let mut lsm = vec![0.0; 4];
        log_softmax_row(&row, &mut lsm);
        for (a, b) in sm.iter().zip(&lsm) {
            assert!((a.ln() - b).abs() < 1e-5);
        }
    }
}

//! Layer modules with caching forward and backward — the native
//! backend's autodiff stack.
//!
//! Every module implements [`Layer`]: `forward` maps row-major
//! activations `(rows, in)` → `(rows, out)` and pushes whatever its
//! backward needs onto the step's [`Workspace`] tape; `backward` pops
//! that frame (LIFO, tag-checked so mis-ordered stacks fail loudly),
//! accumulates parameter gradients into a [`GradStore`] keyed by the
//! manifest parameter names, and returns the input gradient.
//!
//! Linear layers dispatch through [`LinearView`], so the DYAD arm
//! rides the structured per-block kernels
//! (`dyad::kernel::{dyad_backward_dw, dyad_backward_dx}`) — no
//! `(f_out, f_in)` materialisation anywhere in training — and the
//! dense arm the blocked microkernels. Attention backward applies the
//! softmax jacobian per (batch, head) row, parallelised exactly like
//! the forward; layer-norm backward consumes the cached `xhat`/`inv`
//! statistics.
//!
//! The worker-pool size is resolved **once** per workspace
//! ([`Workspace::threads`]) and threaded through every kernel call via
//! the `*_with_threads` escape hatches, which dispatch on the resident
//! [`crate::runtime::pool`] — so nested parallel sections can't each
//! re-derive a pool and oversubscribe the machine, and the steady
//! state spawns no threads at all.
//!
//! The workspace also fronts the **buffer arena**: every activation,
//! tape frame and gradient buffer a layer produces comes from
//! [`Workspace::alloc_zeroed`]/[`Workspace::alloc_copy`] (the
//! thread-local recycler every kernel output already draws from) and
//! is handed back via [`Workspace::recycle`] at its last use, so after
//! one warmup step the train/serve hot paths perform zero
//! kernel-output heap allocations ([`crate::runtime::pool::counters`]
//! asserts this in tests and CI).
//!
//! Every parallel section assigns each output row to exactly one
//! thread with a fixed sequential accumulation order, so forward *and*
//! backward are bitwise deterministic across thread counts (tested).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dyad::kernel::{
    axpy, dense_linear_with_threads, dot, matmul_bt_with_threads, matmul_fast_with_threads,
    num_threads, parallel_rows, scratch, transpose,
};
use crate::runtime::artifact::{ArtifactSpec, Role};
use crate::tensor::Precision;

use super::linear::LinearView;
use super::ops::{
    attention_decode_step, gelu_grad, gelu_inplace, layer_norm, layer_norm_backward,
    layer_norm_forward, relu_inplace, softmax_backward_row, softmax_row,
};
use super::params::Params;

/// Per-step tape + execution context shared by all layer modules.
///
/// `forward` pushes one tagged frame per module; `backward` pops them
/// in reverse. A non-recording workspace ([`Workspace::inference`])
/// skips all caching, so the inference hot paths stay allocation-lean.
pub struct Workspace {
    threads: usize,
    recording: bool,
    tape: Vec<(&'static str, Vec<Vec<f32>>)>,
}

impl Workspace {
    /// A recording workspace for training, worker count resolved once
    /// from [`num_threads`].
    pub fn training() -> Workspace {
        Workspace::training_with_threads(num_threads())
    }

    pub fn training_with_threads(threads: usize) -> Workspace {
        Workspace { threads: threads.max(1), recording: true, tape: Vec::new() }
    }

    /// A non-recording workspace: forward passes skip all caching.
    pub fn inference() -> Workspace {
        Workspace::inference_with_threads(num_threads())
    }

    pub fn inference_with_threads(threads: usize) -> Workspace {
        Workspace { threads: threads.max(1), recording: false, tape: Vec::new() }
    }

    /// The cached worker-pool size every layer kernel call uses.
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn recording(&self) -> bool {
        self.recording
    }

    /// Open tape frames (0 after a complete forward+backward).
    pub fn depth(&self) -> usize {
        self.tape.len()
    }

    /// A zero-filled arena buffer of `len`. The arena is the
    /// thread-local recycler every kernel output draws from, so
    /// buffers recycled here feed the kernels' own `Vec` entry points
    /// (and vice versa) — after warmup the whole step cycles one fixed
    /// set of allocations.
    pub fn alloc_zeroed(&self, len: usize) -> Vec<f32> {
        scratch::take_f32(len)
    }

    /// An arena buffer holding a copy of `src` (tape caching without a
    /// fresh `to_vec` allocation).
    pub fn alloc_copy(&self, src: &[f32]) -> Vec<f32> {
        let mut v = scratch::take_f32(src.len());
        v.copy_from_slice(src);
        v
    }

    /// Hand a no-longer-needed buffer back to the arena. Layers call
    /// this at the last use of every activation, tape frame and
    /// intermediate — the discipline the zero-alloc counters assert.
    pub fn recycle(&self, v: Vec<f32>) {
        scratch::put_f32(v);
    }

    pub(crate) fn push(&mut self, tag: &'static str, frame: Vec<Vec<f32>>) {
        if self.recording {
            self.tape.push((tag, frame));
        }
    }

    pub(crate) fn pop(&mut self, tag: &'static str) -> Result<Vec<Vec<f32>>> {
        match self.tape.pop() {
            Some((t, f)) if t == tag => Ok(f),
            Some((t, _)) => bail!(
                "workspace tape out of order: popped a {t:?} frame, {tag:?} expected \
                 (backward order must mirror forward)"
            ),
            None => bail!(
                "workspace tape empty: no {tag:?} frame (backward without a recorded \
                 forward, or a second backward over the same tape)"
            ),
        }
    }
}

/// Parameter gradients accumulated by name (manifest names), summed on
/// repeated contributions — tied parameters (`tok_emb` via both the
/// embedding and the LM head) just add twice.
#[derive(Default)]
pub struct GradStore {
    map: BTreeMap<String, Vec<f32>>,
}

impl GradStore {
    pub fn new() -> GradStore {
        GradStore::default()
    }

    /// Accumulate `g` into the named gradient (exact length match).
    pub fn add(&mut self, name: &str, g: Vec<f32>) -> Result<()> {
        match self.map.get_mut(name) {
            Some(acc) => {
                if acc.len() != g.len() {
                    bail!(
                        "gradient {name:?}: accumulating {} values into {}",
                        g.len(),
                        acc.len()
                    );
                }
                for (a, b) in acc.iter_mut().zip(&g) {
                    *a += b;
                }
                // the contribution was folded in — its buffer goes
                // back to the arena
                scratch::put_f32(g);
            }
            None => {
                self.map.insert(name.to_string(), g);
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.map.get(name).map(Vec::as_slice)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Global L2 norm over every accumulated gradient (f64 accumulation).
    pub fn global_norm(&self) -> f32 {
        let sq: f64 = self
            .map
            .values()
            .flat_map(|g| g.iter())
            .map(|&v| v as f64 * v as f64)
            .sum();
        sq.sqrt() as f32
    }

    /// Scale every gradient in place (the grad-clip application).
    pub fn scale(&mut self, s: f32) {
        for g in self.map.values_mut() {
            for v in g.iter_mut() {
                *v *= s;
            }
        }
    }

    /// Drain into the order of `names` (the flat training-state feed
    /// order); every name must have received a gradient.
    pub fn into_named_order(mut self, names: &[String]) -> Result<Vec<Vec<f32>>> {
        names
            .iter()
            .map(|n| {
                self.map
                    .remove(n)
                    .with_context(|| format!("no gradient accumulated for parameter {n:?}"))
            })
            .collect()
    }

    /// Drain into the artifact's `Role::Param` feed order.
    pub fn into_spec_order(mut self, spec: &ArtifactSpec) -> Result<Vec<Vec<f32>>> {
        spec.inputs
            .iter()
            .filter(|io| io.role == Role::Param)
            .map(|io| {
                self.map.remove(&io.name).with_context(|| {
                    format!("{}: no gradient accumulated for {:?}", spec.name, io.name)
                })
            })
            .collect()
    }
}

/// One differentiable module over row-major activations.
pub trait Layer {
    /// Tape tag / debug name.
    fn name(&self) -> &'static str;

    /// `x (rows, in)` → `(rows, out)`; records this module's frame on
    /// a recording workspace.
    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>>;

    /// `dy (rows, out)` → `dx (rows, in)`; pops this module's frame
    /// and accumulates parameter gradients.
    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>>;
}

/// A linear layer (DENSE or DYAD via [`LinearView`]) with gradient
/// names derived from its parameter prefix.
pub struct LinearLayer<'a> {
    view: LinearView<'a>,
    names: Vec<String>,
    need_dx: bool,
}

impl<'a> LinearLayer<'a> {
    pub fn new(view: LinearView<'a>, prefix: &str) -> LinearLayer<'a> {
        let names = view.grad_names(prefix);
        LinearLayer { view, names, need_dx: true }
    }

    /// A linear at the very start of a stack: nothing consumes its
    /// input gradient, so backward skips the `dx` kernels entirely
    /// (the timed ff-micro/MNIST paths stay O(param-grads only) at the
    /// first layer) and returns an empty vec.
    pub fn new_input(view: LinearView<'a>, prefix: &str) -> LinearLayer<'a> {
        let names = view.grad_names(prefix);
        LinearLayer { view, names, need_dx: false }
    }

    pub fn view(&self) -> &LinearView<'a> {
        &self.view
    }

    /// Gradient names this layer accumulates, in backward-return order.
    pub fn grad_names(&self) -> &[String] {
        &self.names
    }
}

impl Layer for LinearLayer<'_> {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        let y = self.view.forward_with_threads(x, rows, ws.threads());
        if ws.recording() {
            let cached = ws.alloc_copy(x);
            ws.push("linear", vec![cached]);
        }
        Ok(y)
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let mut frame = ws.pop("linear")?;
        let x = frame.pop().context("linear frame: missing cached input")?;
        let threads = ws.threads();
        let (gs, dx) = self.view.backward_with_threads(&x, dy, rows, self.need_dx, threads)?;
        ws.recycle(x);
        for (n, g) in self.names.iter().zip(gs) {
            grads.add(n, g)?;
        }
        if self.need_dx {
            dx.context("linear backward requested no dx")
        } else {
            Ok(Vec::new())
        }
    }
}

/// Elementwise activation (parameter-free).
pub enum Activation {
    Gelu,
    Relu,
}

impl Layer for Activation {
    fn name(&self) -> &'static str {
        "activation"
    }

    fn forward(&self, x: &[f32], _rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        // the derivative reads the pre-activation, so cache x first
        if ws.recording() {
            let cached = ws.alloc_copy(x);
            ws.push("activation", vec![cached]);
        }
        let mut y = ws.alloc_copy(x);
        match self {
            Activation::Gelu => gelu_inplace(&mut y),
            Activation::Relu => relu_inplace(&mut y),
        }
        Ok(y)
    }

    fn backward(
        &self,
        dy: &[f32],
        _rows: usize,
        ws: &mut Workspace,
        _grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let mut frame = ws.pop("activation")?;
        let a = frame.pop().context("activation frame: missing pre-activation")?;
        let mut dx = ws.alloc_copy(dy);
        match self {
            Activation::Gelu => {
                for (g, &av) in dx.iter_mut().zip(&a) {
                    *g *= gelu_grad(av);
                }
            }
            Activation::Relu => {
                for (g, &av) in dx.iter_mut().zip(&a) {
                    if av <= 0.0 {
                        *g = 0.0;
                    }
                }
            }
        }
        ws.recycle(a);
        Ok(dx)
    }
}

/// LayerNorm over the last axis (population variance, eps 1e-5),
/// caching `xhat`/`inv` for the backward.
pub struct LayerNorm<'a> {
    scale: &'a [f32],
    bias: &'a [f32],
    d: usize,
    scale_name: String,
    bias_name: String,
}

impl<'a> LayerNorm<'a> {
    /// Reads `{prefix}.scale` / `{prefix}.bias` from `p`.
    pub fn new(p: &Params<'a>, prefix: &str, d: usize) -> Result<LayerNorm<'a>> {
        Ok(LayerNorm {
            scale: p.f32(&format!("{prefix}.scale"))?,
            bias: p.f32(&format!("{prefix}.bias"))?,
            d,
            scale_name: format!("{prefix}.scale"),
            bias_name: format!("{prefix}.bias"),
        })
    }
}

impl Layer for LayerNorm<'_> {
    fn name(&self) -> &'static str {
        "layer_norm"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        if x.len() != rows * self.d {
            bail!("layer_norm: {} values for {rows} rows of {}", x.len(), self.d);
        }
        if ws.recording() {
            let (y, xhat, inv) = layer_norm_forward(x, self.d, self.scale, self.bias);
            ws.push("layer_norm", vec![xhat, inv]);
            Ok(y)
        } else {
            let mut y = ws.alloc_copy(x);
            layer_norm(&mut y, self.d, self.scale, self.bias);
            Ok(y)
        }
    }

    fn backward(
        &self,
        dy: &[f32],
        _rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let mut frame = ws.pop("layer_norm")?;
        let inv = frame.pop().context("layer_norm frame: missing inv")?;
        let xhat = frame.pop().context("layer_norm frame: missing xhat")?;
        let (dx, dscale, dbias) = layer_norm_backward(dy, &xhat, &inv, self.d, self.scale);
        ws.recycle(xhat);
        ws.recycle(inv);
        grads.add(&self.scale_name, dscale)?;
        grads.add(&self.bias_name, dbias)?;
        Ok(dx)
    }
}

/// Causal multi-head attention. Forward parallelises over (batch,
/// head) pairs; the recording path also stores the softmax rows, and
/// backward applies the softmax jacobian per row under the same
/// (batch, head) parallel schedule — `dq`/`dk`/`dv` blocks of one
/// pair are owned by one thread, so the backward is deterministic
/// like the forward.
pub struct Attention<'a> {
    wq: &'a [f32],
    wq_b: &'a [f32],
    wk: &'a [f32],
    wk_b: &'a [f32],
    wv: &'a [f32],
    wv_b: &'a [f32],
    wo: &'a [f32],
    wo_b: &'a [f32],
    prefix: String,
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
}

impl<'a> Attention<'a> {
    /// Reads `{prefix}.wq[.b]`/`wk`/`wv`/`wo` from `p`; `(b, s)` is
    /// the step's batch geometry.
    pub fn new(
        p: &Params<'a>,
        prefix: &str,
        d: usize,
        nh: usize,
        b: usize,
        s: usize,
    ) -> Result<Attention<'a>> {
        if nh == 0 || d % nh != 0 {
            bail!("attention: d_model {d} not divisible by n_heads {nh}");
        }
        let w = |n: &str| p.f32(&format!("{prefix}.{n}"));
        Ok(Attention {
            wq: w("wq")?,
            wq_b: w("wq_b")?,
            wk: w("wk")?,
            wk_b: w("wk_b")?,
            wv: w("wv")?,
            wv_b: w("wv_b")?,
            wo: w("wo")?,
            wo_b: w("wo_b")?,
            prefix: prefix.to_string(),
            b,
            s,
            nh,
            hd: d / nh,
        })
    }

    fn d(&self) -> usize {
        self.nh * self.hd
    }

    /// `(b*s, d)` row-major → `(b*nh, s, hd)`: one contiguous block
    /// per (batch, head) pair. Output drawn from the arena.
    fn to_heads(&self, m: &[f32]) -> Vec<f32> {
        let (b, s, nh, hd) = (self.b, self.s, self.nh, self.hd);
        let d = self.d();
        let mut out = scratch::take_f32(b * s * d);
        for bi in 0..b {
            for t in 0..s {
                let src = &m[(bi * s + t) * d..(bi * s + t + 1) * d];
                for h in 0..nh {
                    let dst = ((bi * nh + h) * s + t) * hd;
                    out[dst..dst + hd].copy_from_slice(&src[h * hd..(h + 1) * hd]);
                }
            }
        }
        out
    }

    /// One incremental decode step: project the new tokens' q/k/v,
    /// append this layer's K/V rows into the caller's caches, and
    /// attend against the full cached prefix.
    ///
    /// `x` is `(a, d)` — one row per **active** lane, compacted;
    /// `lanes[g]` maps compact row `g` to its cache lane; `lens[lane]`
    /// is the lane's length *including* the token being decoded (its
    /// K/V land at position `lens[lane] - 1`). Caches are
    /// `(b*nh, s, hd)` head-blocked, the layout [`Attention::to_heads`]
    /// produces.
    ///
    /// For a single position the per-head blocks of a row are already
    /// contiguous, so `(a, d)` row-major and `(a*nh, hd)` head-blocked
    /// are the same bytes — `to_heads`/`from_heads` are identities here
    /// and are skipped. Everything else replays the inference branch of
    /// [`Attention::forward`] op for op (same projections, same
    /// [`attention_decode_step`] score/softmax/axpy order), which is
    /// what makes incremental decode bitwise equal to full recompute.
    pub fn decode_step(
        &self,
        x: &[f32],
        k_cache: &mut [f32],
        v_cache: &mut [f32],
        lanes: &[usize],
        lens: &[usize],
        ws: &mut Workspace,
    ) -> Result<Vec<f32>> {
        let (s, nh, hd) = (self.s, self.nh, self.hd);
        let d = self.d();
        let a = lanes.len();
        if x.len() != a * d {
            bail!("attention decode: {} values for {a} active rows of {d}", x.len());
        }
        if k_cache.len() != self.b * s * d || v_cache.len() != self.b * s * d {
            bail!(
                "attention decode: cache holds {} values, want {} (b={} s={s} d={d})",
                k_cache.len(),
                self.b * s * d,
                self.b
            );
        }
        let threads = ws.threads();
        let q = dense_linear_with_threads(x, self.wq, Some(self.wq_b), a, d, d, threads);
        let k = dense_linear_with_threads(x, self.wk, Some(self.wk_b), a, d, d, threads);
        let v = dense_linear_with_threads(x, self.wv, Some(self.wv_b), a, d, d, threads);
        for (g, &lane) in lanes.iter().enumerate() {
            let t = lens[lane] - 1;
            if lane >= self.b || t >= s {
                bail!("attention decode: lane {lane} at position {t} out of ({}, {s})", self.b);
            }
            for h in 0..nh {
                let dst = ((lane * nh + h) * s + t) * hd;
                let src = g * d + h * hd;
                k_cache[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                v_cache[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
            }
        }
        let mut ctx = ws.alloc_zeroed(a * d);
        attention_decode_step(
            &mut ctx, &q, k_cache, v_cache, lanes, lens, nh, s, hd, threads,
        );
        ws.recycle(q);
        ws.recycle(k);
        ws.recycle(v);
        let y = dense_linear_with_threads(&ctx, self.wo, Some(self.wo_b), a, d, d, threads);
        ws.recycle(ctx);
        Ok(y)
    }

    /// Inverse of [`Attention::to_heads`]. Output drawn from the arena.
    fn from_heads(&self, m: &[f32]) -> Vec<f32> {
        let (b, s, nh, hd) = (self.b, self.s, self.nh, self.hd);
        let d = self.d();
        let mut out = scratch::take_f32(b * s * d);
        for bi in 0..b {
            for t in 0..s {
                let dst = &mut out[(bi * s + t) * d..(bi * s + t + 1) * d];
                for h in 0..nh {
                    let src = ((bi * nh + h) * s + t) * hd;
                    dst[h * hd..(h + 1) * hd].copy_from_slice(&m[src..src + hd]);
                }
            }
        }
        out
    }
}

impl Layer for Attention<'_> {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        let (b, s, nh, hd) = (self.b, self.s, self.nh, self.hd);
        let d = self.d();
        let bs = b * s;
        if rows != bs || x.len() != bs * d {
            bail!("attention: {rows} rows / {} values for b={b} s={s} d={d}", x.len());
        }
        let threads = ws.threads();
        let q = dense_linear_with_threads(x, self.wq, Some(self.wq_b), bs, d, d, threads);
        let k = dense_linear_with_threads(x, self.wk, Some(self.wk_b), bs, d, d, threads);
        let v = dense_linear_with_threads(x, self.wv, Some(self.wv_b), bs, d, d, threads);
        let qh = self.to_heads(&q);
        let kh = self.to_heads(&k);
        let vh = self.to_heads(&v);
        ws.recycle(q);
        ws.recycle(k);
        ws.recycle(v);
        let scale = 1.0 / (hd as f32).sqrt();
        let blk = s * hd;
        let merged = if ws.recording() {
            // one combined [softmax rows | context] row per (batch,
            // head), so the probabilities land on the tape without a
            // second pass over the scores
            let prow = s * s;
            let mut buf = ws.alloc_zeroed(b * nh * (prow + blk));
            parallel_rows(&mut buf, prow + blk, threads, &|bh, row| {
                let (probs, ctx) = row.split_at_mut(prow);
                let qb = &qh[bh * blk..(bh + 1) * blk];
                let kb = &kh[bh * blk..(bh + 1) * blk];
                let vb = &vh[bh * blk..(bh + 1) * blk];
                for ti in 0..s {
                    let qrow = &qb[ti * hd..(ti + 1) * hd];
                    let att = &mut probs[ti * s..ti * s + ti + 1];
                    for (tj, a) in att.iter_mut().enumerate() {
                        *a = dot(qrow, &kb[tj * hd..(tj + 1) * hd]) * scale;
                    }
                    softmax_row(att);
                    let orow = &mut ctx[ti * hd..(ti + 1) * hd];
                    for (tj, &a) in att.iter().enumerate() {
                        axpy(orow, a, &vb[tj * hd..(tj + 1) * hd]);
                    }
                }
            });
            let mut probs = ws.alloc_zeroed(b * nh * prow);
            let mut ctx = ws.alloc_zeroed(bs * d);
            for bh in 0..b * nh {
                let row = &buf[bh * (prow + blk)..(bh + 1) * (prow + blk)];
                probs[bh * prow..(bh + 1) * prow].copy_from_slice(&row[..prow]);
                ctx[bh * blk..(bh + 1) * blk].copy_from_slice(&row[prow..]);
            }
            ws.recycle(buf);
            let merged = self.from_heads(&ctx);
            ws.recycle(ctx);
            let cached_x = ws.alloc_copy(x);
            let cached_merged = ws.alloc_copy(&merged);
            ws.push(
                "attention",
                vec![cached_x, qh, kh, vh, probs, cached_merged],
            );
            merged
        } else {
            // inference: no probability storage, scratch row reused
            let mut ctx = ws.alloc_zeroed(bs * d);
            parallel_rows(&mut ctx, blk, threads, &|bh, row| {
                let qb = &qh[bh * blk..(bh + 1) * blk];
                let kb = &kh[bh * blk..(bh + 1) * blk];
                let vb = &vh[bh * blk..(bh + 1) * blk];
                let mut att = vec![0.0f32; s];
                for ti in 0..s {
                    let qrow = &qb[ti * hd..(ti + 1) * hd];
                    for (tj, a) in att.iter_mut().enumerate().take(ti + 1) {
                        *a = dot(qrow, &kb[tj * hd..(tj + 1) * hd]) * scale;
                    }
                    softmax_row(&mut att[..ti + 1]);
                    let orow = &mut row[ti * hd..(ti + 1) * hd];
                    for tj in 0..=ti {
                        axpy(orow, att[tj], &vb[tj * hd..(tj + 1) * hd]);
                    }
                }
            });
            let merged = self.from_heads(&ctx);
            ws.recycle(ctx);
            ws.recycle(qh);
            ws.recycle(kh);
            ws.recycle(vh);
            merged
        };
        let y = dense_linear_with_threads(&merged, self.wo, Some(self.wo_b), bs, d, d, threads);
        ws.recycle(merged);
        Ok(y)
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let (b, s, nh, hd) = (self.b, self.s, self.nh, self.hd);
        let d = self.d();
        let bs = b * s;
        if rows != bs || dy.len() != bs * d {
            bail!("attention backward: {rows} rows / {} values", dy.len());
        }
        let mut frame = ws.pop("attention")?;
        let merged = frame.pop().context("attention frame: merged")?;
        let probs = frame.pop().context("attention frame: probs")?;
        let vh = frame.pop().context("attention frame: vh")?;
        let kh = frame.pop().context("attention frame: kh")?;
        let qh = frame.pop().context("attention frame: qh")?;
        let x = frame.pop().context("attention frame: x")?;
        let threads = ws.threads();

        // output projection: dW_o = dy^T @ merged, d_merged = dy @ W_o
        // attention projections are not swap sites: always f32
        let wo_view = LinearView::Dense {
            w: self.wo,
            b: self.wo_b,
            f_in: d,
            f_out: d,
            precision: Precision::F32,
        };
        let (mut g_wo, dmerged) = wo_view.backward_with_threads(&merged, dy, bs, true, threads)?;
        ws.recycle(merged);
        grads.add(&format!("{}.wo_b", self.prefix), g_wo.pop().context("wo db")?)?;
        grads.add(&format!("{}.wo", self.prefix), g_wo.pop().context("wo dw")?)?;
        let dmerged = dmerged.context("wo backward: no dx")?;
        let dctx = self.to_heads(&dmerged);
        ws.recycle(dmerged);

        // per (batch, head): softmax-jacobian backward into one
        // combined [dq | dk | dv] row, owned by one thread
        let scale = 1.0 / (hd as f32).sqrt();
        let blk = s * hd;
        let mut dbuf = ws.alloc_zeroed(b * nh * 3 * blk);
        parallel_rows(&mut dbuf, 3 * blk, threads, &|bh, row| {
            let (dqb, rest) = row.split_at_mut(blk);
            let (dkb, dvb) = rest.split_at_mut(blk);
            let qb = &qh[bh * blk..(bh + 1) * blk];
            let kb = &kh[bh * blk..(bh + 1) * blk];
            let vb = &vh[bh * blk..(bh + 1) * blk];
            let pb = &probs[bh * s * s..(bh + 1) * s * s];
            let dcb = &dctx[bh * blk..(bh + 1) * blk];
            let mut datt = vec![0.0f32; s];
            let mut dscore = vec![0.0f32; s];
            for ti in 0..s {
                let pr = &pb[ti * s..ti * s + ti + 1];
                let dc = &dcb[ti * hd..(ti + 1) * hd];
                for (tj, da) in datt.iter_mut().enumerate().take(ti + 1) {
                    // dv_j += att_ij * dctx_i ; datt_ij = dctx_i · v_j
                    axpy(&mut dvb[tj * hd..(tj + 1) * hd], pr[tj], dc);
                    *da = dot(&vb[tj * hd..(tj + 1) * hd], dc);
                }
                softmax_backward_row(pr, &datt[..ti + 1], &mut dscore[..ti + 1]);
                let qrow = &qb[ti * hd..(ti + 1) * hd];
                let dqrow = &mut dqb[ti * hd..(ti + 1) * hd];
                for tj in 0..=ti {
                    let w = dscore[tj] * scale;
                    // dq_i += w * k_j ; dk_j += w * q_i
                    axpy(dqrow, w, &kb[tj * hd..(tj + 1) * hd]);
                    axpy(&mut dkb[tj * hd..(tj + 1) * hd], w, qrow);
                }
            }
        });
        let mut dqh = ws.alloc_zeroed(bs * d);
        let mut dkh = ws.alloc_zeroed(bs * d);
        let mut dvh = ws.alloc_zeroed(bs * d);
        for bh in 0..b * nh {
            let row = &dbuf[bh * 3 * blk..(bh + 1) * 3 * blk];
            dqh[bh * blk..(bh + 1) * blk].copy_from_slice(&row[..blk]);
            dkh[bh * blk..(bh + 1) * blk].copy_from_slice(&row[blk..2 * blk]);
            dvh[bh * blk..(bh + 1) * blk].copy_from_slice(&row[2 * blk..]);
        }
        ws.recycle(dbuf);
        ws.recycle(dctx);
        ws.recycle(qh);
        ws.recycle(kh);
        ws.recycle(vh);
        ws.recycle(probs);

        // q/k/v projections: accumulate dW/db and sum the three dx paths
        let mut dx = ws.alloc_zeroed(bs * d);
        for (w, wb, nm, dh) in [
            (self.wq, self.wq_b, "wq", dqh),
            (self.wk, self.wk_b, "wk", dkh),
            (self.wv, self.wv_b, "wv", dvh),
        ] {
            let dm = self.from_heads(&dh);
            ws.recycle(dh);
            let view = LinearView::Dense {
                w,
                b: wb,
                f_in: d,
                f_out: d,
                precision: Precision::F32,
            };
            let (mut gs, dxp) = view.backward_with_threads(&x, &dm, bs, true, threads)?;
            ws.recycle(dm);
            grads.add(&format!("{}.{nm}_b", self.prefix), gs.pop().context("proj db")?)?;
            grads.add(&format!("{}.{nm}", self.prefix), gs.pop().context("proj dw")?)?;
            let dxp = dxp.context("proj backward: no dx")?;
            for (o, v) in dx.iter_mut().zip(&dxp) {
                *o += v;
            }
            ws.recycle(dxp);
        }
        ws.recycle(x);
        Ok(dx)
    }
}

/// The paper's swap site as a module: fc1 → GELU → fc2, both linears
/// dispatching DENSE/DYAD through [`LinearLayer`].
pub struct FfBlock<'a> {
    fc1: LinearLayer<'a>,
    act: Activation,
    fc2: LinearLayer<'a>,
}

impl<'a> FfBlock<'a> {
    pub fn new(
        fc1: LinearView<'a>,
        fc1_prefix: &str,
        fc2: LinearView<'a>,
        fc2_prefix: &str,
    ) -> FfBlock<'a> {
        FfBlock {
            fc1: LinearLayer::new(fc1, fc1_prefix),
            act: Activation::Gelu,
            fc2: LinearLayer::new(fc2, fc2_prefix),
        }
    }

    /// An ff block at the very start of a stack (the timed ff-micro
    /// programs): fc1's input gradient is skipped.
    pub fn new_input(
        fc1: LinearView<'a>,
        fc1_prefix: &str,
        fc2: LinearView<'a>,
        fc2_prefix: &str,
    ) -> FfBlock<'a> {
        FfBlock {
            fc1: LinearLayer::new_input(fc1, fc1_prefix),
            act: Activation::Gelu,
            fc2: LinearLayer::new(fc2, fc2_prefix),
        }
    }

    /// Gradient names of both linears, fc1 first (the catalog's
    /// `ff_param_specs` feed order).
    pub fn grad_names(&self) -> Vec<String> {
        let mut names = self.fc1.grad_names().to_vec();
        names.extend_from_slice(self.fc2.grad_names());
        names
    }
}

impl Layer for FfBlock<'_> {
    fn name(&self) -> &'static str {
        "ff_block"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        let h = self.fc1.forward(x, rows, ws)?;
        let h = self.act.forward(&h, rows, ws)?;
        self.fc2.forward(&h, rows, ws)
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let dh = self.fc2.backward(dy, rows, ws, grads)?;
        let dh = self.act.backward(&dh, rows, ws, grads)?;
        self.fc1.backward(&dh, rows, ws, grads)
    }
}

/// A stack of layers run in order (MNIST MLP, ad-hoc compositions).
pub struct Sequential<'a> {
    layers: Vec<Box<dyn Layer + 'a>>,
}

impl<'a> Sequential<'a> {
    pub fn new(layers: Vec<Box<dyn Layer + 'a>>) -> Sequential<'a> {
        Sequential { layers }
    }
}

impl Layer for Sequential<'_> {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        let mut cur = ws.alloc_copy(x);
        for l in &self.layers {
            let next = l.forward(&cur, rows, ws)?;
            ws.recycle(std::mem::replace(&mut cur, next));
        }
        Ok(cur)
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let mut cur = ws.alloc_copy(dy);
        for l in self.layers.iter().rev() {
            let next = l.backward(&cur, rows, ws, grads)?;
            ws.recycle(std::mem::replace(&mut cur, next));
        }
        Ok(cur)
    }
}

/// The tied LM head: `logits = h @ tok_emb^T` (no bias). Backward
/// adds the head's contribution to the shared `tok_emb` gradient —
/// the embedding backward adds the other half.
pub struct TiedLmHead<'a> {
    emb: &'a [f32],
    vocab: usize,
    d: usize,
}

impl<'a> TiedLmHead<'a> {
    pub fn new(p: &Params<'a>, vocab: usize, d: usize) -> Result<TiedLmHead<'a>> {
        let emb = p.f32("tok_emb")?;
        if emb.len() != vocab * d {
            bail!("tok_emb: {} values for ({vocab}, {d})", emb.len());
        }
        Ok(TiedLmHead { emb, vocab, d })
    }
}

impl Layer for TiedLmHead<'_> {
    fn name(&self) -> &'static str {
        "tied_lm_head"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        let logits = matmul_bt_with_threads(x, self.emb, rows, self.d, self.vocab, ws.threads());
        if ws.recording() {
            let cached = ws.alloc_copy(x);
            ws.push("tied_lm_head", vec![cached]);
        }
        Ok(logits)
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let mut frame = ws.pop("tied_lm_head")?;
        let h = frame.pop().context("tied_lm_head frame: hidden")?;
        let threads = ws.threads();
        // d_emb = dlogits^T @ h ; dh = dlogits @ emb
        let dyt = transpose(dy, rows, self.vocab);
        let demb = matmul_fast_with_threads(&dyt, &h, self.vocab, rows, self.d, threads);
        ws.recycle(dyt);
        ws.recycle(h);
        grads.add("tok_emb", demb)?;
        Ok(matmul_fast_with_threads(dy, self.emb, rows, self.vocab, self.d, threads))
    }
}

/// Token + learned-position embedding. Its input is the token ids, so
/// it sits outside the float [`Layer`] chain: `forward` starts a step,
/// `backward` terminates it (no upstream dx).
pub struct Embedding<'a> {
    tok: &'a [f32],
    pos: &'a [f32],
    vocab: usize,
    seq: usize,
    d: usize,
}

impl<'a> Embedding<'a> {
    pub fn new(p: &Params<'a>, vocab: usize, seq: usize, d: usize) -> Result<Embedding<'a>> {
        Ok(Embedding { tok: p.f32("tok_emb")?, pos: p.f32("pos_emb")?, vocab, seq, d })
    }

    /// `(b, s)` int32 tokens → `(b*s, d)` rows:
    /// `tok_emb[token] + pos_emb[position]`.
    pub fn forward(&self, tokens: &[i32], b: usize, s: usize) -> Result<Vec<f32>> {
        let d = self.d;
        if tokens.len() != b * s {
            bail!("tokens len {} != {b}x{s}", tokens.len());
        }
        if s > self.seq {
            bail!("sequence length {s} exceeds arch seq {}", self.seq);
        }
        let mut x = scratch::take_f32(b * s * d);
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= self.vocab {
                bail!("token id {tok} out of vocab {}", self.vocab);
            }
            let row = &mut x[t * d..(t + 1) * d];
            let e = &self.tok[tok * d..(tok + 1) * d];
            let p = &self.pos[(t % s) * d..(t % s + 1) * d];
            for (j, r) in row.iter_mut().enumerate() {
                *r = e[j] + p[j];
            }
        }
        Ok(x)
    }

    /// Scatter-add `dx` into the `tok_emb` / `pos_emb` gradients.
    pub fn backward(
        &self,
        dx: &[f32],
        tokens: &[i32],
        s: usize,
        grads: &mut GradStore,
    ) -> Result<()> {
        let d = self.d;
        if dx.len() != tokens.len() * d {
            bail!("embedding backward: {} values for {} tokens", dx.len(), tokens.len());
        }
        let mut dtok = scratch::take_f32(self.vocab * d);
        let mut dpos = scratch::take_f32(self.seq * d);
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            let row = &dx[t * d..(t + 1) * d];
            axpy(&mut dtok[tok * d..(tok + 1) * d], 1.0, row);
            axpy(&mut dpos[(t % s) * d..(t % s + 1) * d], 1.0, row);
        }
        grads.add("tok_emb", dtok)?;
        grads.add("pos_emb", dpos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect()
    }

    /// A tiny attention module over named flat params.
    fn attn_fixture() -> (Vec<String>, Vec<Vec<f32>>, usize, usize, usize, usize) {
        let (b, s, nh, d) = (2usize, 4usize, 2usize, 6usize);
        let mut rng = Rng::new(51);
        let mut names = Vec::new();
        let mut vals = Vec::new();
        for m in ["wq", "wk", "wv", "wo"] {
            names.push(format!("attn.{m}"));
            vals.push(rand_vec(&mut rng, d * d));
            names.push(format!("attn.{m}_b"));
            vals.push(rand_vec(&mut rng, d));
        }
        (names, vals, b, s, nh, d)
    }

    /// Finite-difference gradcheck of the attention backward through a
    /// sum(y * ct) loss: every projection weight/bias plus the input.
    #[test]
    fn attention_backward_gradcheck() {
        let (names, vals, b, s, nh, d) = attn_fixture();
        let bs = b * s;
        let mut rng = Rng::new(7);
        let x = rand_vec(&mut rng, bs * d);
        let ct = rand_vec(&mut rng, bs * d);
        let loss = |vals: &[Vec<f32>], x: &[f32]| -> f32 {
            let p = Params::from_named(&names, vals);
            let attn = Attention::new(&p, "attn", d, nh, b, s).unwrap();
            let y = attn.forward(x, bs, &mut Workspace::inference()).unwrap();
            y.iter().zip(&ct).map(|(a, c)| a * c).sum()
        };
        let p = Params::from_named(&names, &vals);
        let attn = Attention::new(&p, "attn", d, nh, b, s).unwrap();
        let mut ws = Workspace::training_with_threads(2);
        let y = attn.forward(&x, bs, &mut ws).unwrap();
        // recording and non-recording forwards agree exactly
        let y2 = attn.forward(&x, bs, &mut Workspace::inference()).unwrap();
        assert_eq!(y, y2, "recording forward changed values");
        let mut grads = GradStore::new();
        let dx = attn.backward(&ct, bs, &mut ws, &mut grads).unwrap();
        assert_eq!(ws.depth(), 0);
        let h = 1e-2f32;
        let check = |an: f32, fd: f32, what: &str| {
            assert!(
                (an - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "{what}: analytic {an} vs fd {fd}"
            );
        };
        for (pi, name) in names.iter().enumerate() {
            let g = grads.get(name).unwrap_or_else(|| panic!("no grad {name}"));
            let n = vals[pi].len();
            for idx in [0usize, n / 2, n - 1] {
                let mut vp = vals.clone();
                vp[pi][idx] += h;
                let mut vm = vals.clone();
                vm[pi][idx] -= h;
                let fd = (loss(&vp, &x) - loss(&vm, &x)) / (2.0 * h);
                check(g[idx], fd, &format!("{name}[{idx}]"));
            }
        }
        for idx in [0usize, bs * d / 2, bs * d - 1] {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let fd = (loss(&vals, &xp) - loss(&vals, &xm)) / (2.0 * h);
            check(dx[idx], fd, &format!("dx[{idx}]"));
        }
    }

    /// Attention forward + backward are bitwise identical across
    /// thread counts (PR 2's determinism contract, extended to the new
    /// backward).
    #[test]
    fn attention_thread_count_bitwise_deterministic() {
        let (names, vals, b, s, nh, d) = attn_fixture();
        let bs = b * s;
        let mut rng = Rng::new(9);
        let x = rand_vec(&mut rng, bs * d);
        let dy = rand_vec(&mut rng, bs * d);
        let run = |threads: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let p = Params::from_named(&names, &vals);
            let attn = Attention::new(&p, "attn", d, nh, b, s).unwrap();
            let mut ws = Workspace::training_with_threads(threads);
            let y = attn.forward(&x, bs, &mut ws).unwrap();
            let mut grads = GradStore::new();
            let dx = attn.backward(&dy, bs, &mut ws, &mut grads).unwrap();
            let gq = grads.get("attn.wq").unwrap().to_vec();
            (y, dx, gq)
        };
        let (y1, dx1, g1) = run(1);
        for threads in [2, 3, 8] {
            let (yn, dxn, gn) = run(threads);
            assert_eq!(y1, yn, "fwd threads={threads} changed bits");
            assert_eq!(dx1, dxn, "dx threads={threads} changed bits");
            assert_eq!(g1, gn, "dwq threads={threads} changed bits");
        }
    }

    /// The tape is tagged LIFO: popping out of order or past the end
    /// fails with an actionable message instead of silently reading
    /// the wrong frame.
    #[test]
    fn workspace_tape_misuse_fails_loudly() {
        let mut ws = Workspace::training_with_threads(1);
        ws.push("layer_norm", vec![vec![1.0]]);
        let err = format!("{:#}", ws.pop("attention").unwrap_err());
        assert!(err.contains("layer_norm") && err.contains("attention"), "{err}");
        // the mismatched pop consumed the frame; the tape is now empty
        let err = format!("{:#}", ws.pop("layer_norm").unwrap_err());
        assert!(err.contains("empty"), "{err}");
        // a non-recording workspace never records
        let mut ws = Workspace::inference();
        ws.push("linear", vec![vec![1.0]]);
        assert_eq!(ws.depth(), 0);
    }

    /// The workspace arena really reuses storage: recycling a buffer
    /// and allocating the same size again returns the *same*
    /// allocation (pointer identity), and the recycled buffer comes
    /// back zero-filled / copied clean.
    #[test]
    fn workspace_arena_reuses_buffers_by_pointer_identity() {
        let ws = Workspace::inference_with_threads(1);
        // drain lingering free-list entries of this size class first
        // so the identity check below can't be satisfied by an older
        // buffer: take until a distinctive fresh one comes back
        let mut v = ws.alloc_zeroed(4096);
        v[7] = 3.5;
        let ptr = v.as_ptr();
        ws.recycle(v);
        let v2 = ws.alloc_zeroed(4096);
        assert_eq!(v2.as_ptr(), ptr, "arena did not reuse the buffer");
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer not zeroed");
        ws.recycle(v2);
        let src: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let v3 = ws.alloc_copy(&src);
        assert_eq!(v3.as_ptr(), ptr, "alloc_copy bypassed the arena");
        assert_eq!(v3, src);
        ws.recycle(v3);
    }

    #[test]
    fn grad_store_accumulates_and_orders() {
        let mut g = GradStore::new();
        g.add("a", vec![1.0, 2.0]).unwrap();
        g.add("a", vec![0.5, -1.0]).unwrap();
        g.add("b", vec![3.0]).unwrap();
        assert_eq!(g.get("a").unwrap(), &[1.5, 1.0]);
        assert_eq!(g.len(), 2);
        // |(1.5, 1, 3)| = sqrt(1.5^2 + 1 + 9)
        let want = (1.5f64 * 1.5 + 1.0 + 9.0).sqrt() as f32;
        assert!((g.global_norm() - want).abs() < 1e-6);
        g.scale(2.0);
        assert_eq!(g.get("b").unwrap(), &[6.0]);
        // length mismatch fails
        let err = format!("{:#}", g.add("a", vec![1.0]).unwrap_err());
        assert!(err.contains('a'), "{err}");
        // ordering by name list; missing names are an error
        let names: Vec<String> = vec!["b".into(), "a".into()];
        let ordered = g.into_named_order(&names).unwrap();
        assert_eq!(ordered[0], vec![6.0]);
        let mut g = GradStore::new();
        g.add("a", vec![1.0]).unwrap();
        let err = format!(
            "{:#}",
            g.into_named_order(&["missing".to_string()]).unwrap_err()
        );
        assert!(err.contains("missing"), "{err}");
    }

    /// Embedding forward/backward: scatter-add matches a dense
    /// finite-difference through sum(x * ct).
    #[test]
    fn embedding_backward_gradcheck() {
        let (vocab, seq, d, b, s) = (7usize, 5usize, 4usize, 2usize, 3usize);
        let mut rng = Rng::new(3);
        let names: Vec<String> = vec!["tok_emb".into(), "pos_emb".into()];
        let vals = vec![rand_vec(&mut rng, vocab * d), rand_vec(&mut rng, seq * d)];
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(vocab) as i32).collect();
        let ct = rand_vec(&mut rng, b * s * d);
        let loss = |vals: &[Vec<f32>]| -> f32 {
            let p = Params::from_named(&names, vals);
            let e = Embedding::new(&p, vocab, seq, d).unwrap();
            let x = e.forward(&tokens, b, s).unwrap();
            x.iter().zip(&ct).map(|(a, c)| a * c).sum()
        };
        let p = Params::from_named(&names, &vals);
        let e = Embedding::new(&p, vocab, seq, d).unwrap();
        let mut grads = GradStore::new();
        e.backward(&ct, &tokens, s, &mut grads).unwrap();
        let h = 1e-2f32;
        for (pi, name) in names.iter().enumerate() {
            let g = grads.get(name).unwrap();
            let n = vals[pi].len();
            for idx in [0usize, n / 2, n - 1] {
                let mut vp = vals.clone();
                vp[pi][idx] += h;
                let mut vm = vals.clone();
                vm[pi][idx] -= h;
                let fd = (loss(&vp) - loss(&vm)) / (2.0 * h);
                assert!(
                    (g[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()),
                    "{name}[{idx}]: {} vs fd {fd}",
                    g[idx]
                );
            }
        }
        // out-of-vocab tokens fail actionably
        let p = Params::from_named(&names, &vals);
        let e = Embedding::new(&p, vocab, seq, d).unwrap();
        let bad = vec![vocab as i32; b * s];
        assert!(e.forward(&bad, b, s).is_err());
    }

    /// TiedLmHead backward: both the hidden gradient and the embedding
    /// contribution match finite differences.
    #[test]
    fn tied_head_backward_gradcheck() {
        let (vocab, d, rows) = (6usize, 5usize, 3usize);
        let mut rng = Rng::new(21);
        let names: Vec<String> = vec!["tok_emb".into()];
        let vals = vec![rand_vec(&mut rng, vocab * d)];
        let hiddens = rand_vec(&mut rng, rows * d);
        let ct = rand_vec(&mut rng, rows * vocab);
        let loss = |vals: &[Vec<f32>], hx: &[f32]| -> f32 {
            let p = Params::from_named(&names, vals);
            let head = TiedLmHead::new(&p, vocab, d).unwrap();
            let y = head.forward(hx, rows, &mut Workspace::inference()).unwrap();
            y.iter().zip(&ct).map(|(a, c)| a * c).sum()
        };
        let p = Params::from_named(&names, &vals);
        let head = TiedLmHead::new(&p, vocab, d).unwrap();
        let mut ws = Workspace::training_with_threads(1);
        let _ = head.forward(&hiddens, rows, &mut ws).unwrap();
        let mut grads = GradStore::new();
        let dh = head.backward(&ct, rows, &mut ws, &mut grads).unwrap();
        let h = 1e-2f32;
        let g = grads.get("tok_emb").unwrap();
        for idx in [0usize, vocab * d - 1] {
            let mut vp = vals.clone();
            vp[0][idx] += h;
            let mut vm = vals.clone();
            vm[0][idx] -= h;
            let fd = (loss(&vp, &hiddens) - loss(&vm, &hiddens)) / (2.0 * h);
            assert!((g[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()));
        }
        for idx in [0usize, rows * d - 1] {
            let mut hp = hiddens.clone();
            hp[idx] += h;
            let mut hm = hiddens.clone();
            hm[idx] -= h;
            let fd = (loss(&vals, &hp) - loss(&vals, &hm)) / (2.0 * h);
            assert!((dh[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()));
        }
    }
}

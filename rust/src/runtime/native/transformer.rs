//! Native decoder-only transformer — the pure-Rust mirror of
//! `python/compile/model.py` (pre-LN, tied embeddings, learned
//! positions, tanh-GELU ff, optional Pythia parallel residual), wired
//! as a composition of the [`super::layers`] modules.
//!
//! Inference (`score`, `features`, `next_logits`, `eval_loss`) runs
//! the same modules over a non-recording [`Workspace`] (no tape, no
//! extra allocations on the hot path). Incremental decoding
//! ([`DecodeState`] + [`Lm::decode_step_with_threads`]) runs the same
//! stack one token at a time against resident K/V caches — O(1) work
//! per token in the prefix length, bitwise identical to the
//! full-recompute `next_logits` loop. Training
//! ([`Lm::loss_and_grads`] / [`train_microbatch`]) records each
//! module's frame on the tape and backpropagates through the whole
//! decoder: softmax-jacobian attention backward, layer-norm backward,
//! structured DYAD kernels in the ff swap site, scatter-add tied
//! embedding gradients — then global-norm grad clip + bias-corrected
//! Adam, exactly `model.py::make_train_step`.

use anyhow::{bail, Context, Result};

use crate::dyad::kernel::{axpy, matmul_bt_with_threads, num_threads, scratch};
use crate::runtime::artifact::ArchCfg;
use crate::runtime::catalog::ADAM;

use super::layers::{
    Attention, Embedding, FfBlock, GradStore, Layer, LayerNorm, TiedLmHead, Workspace,
};
use super::ops::{log_softmax_row, softmax_xent_row};
use super::params::Params;
use super::VariantSpec;

pub struct Lm<'a> {
    pub arch: &'a ArchCfg,
    pub var: &'a VariantSpec,
    pub p: Params<'a>,
}

/// One pre-LN decoder block: the residual wiring over
/// `ln1 → attention` and `ln2 → ff`, in both the sequential (OPT) and
/// parallel (Pythia) arrangements. Forward pushes the sub-module
/// frames in a fixed order (ln1, attn, ln2, ff); backward pops them in
/// reverse.
pub struct DecoderLayer<'a> {
    ln1: LayerNorm<'a>,
    attn: Attention<'a>,
    ln2: LayerNorm<'a>,
    ff: FfBlock<'a>,
    parallel_residual: bool,
}

impl Layer for DecoderLayer<'_> {
    fn name(&self) -> &'static str {
        "decoder_layer"
    }

    fn forward(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Result<Vec<f32>> {
        if self.parallel_residual {
            // y = x + attn(ln1(x)) + ff(ln2(x))
            let h1 = self.ln1.forward(x, rows, ws)?;
            let att = self.attn.forward(&h1, rows, ws)?;
            ws.recycle(h1);
            let h2 = self.ln2.forward(x, rows, ws)?;
            let f = self.ff.forward(&h2, rows, ws)?;
            ws.recycle(h2);
            let mut y = ws.alloc_copy(x);
            for ((o, a), fv) in y.iter_mut().zip(&att).zip(&f) {
                *o += a + fv;
            }
            ws.recycle(att);
            ws.recycle(f);
            Ok(y)
        } else {
            // x1 = x + attn(ln1(x)); y = x1 + ff(ln2(x1))
            let h1 = self.ln1.forward(x, rows, ws)?;
            let att = self.attn.forward(&h1, rows, ws)?;
            ws.recycle(h1);
            let mut x1 = ws.alloc_copy(x);
            for (o, a) in x1.iter_mut().zip(&att) {
                *o += a;
            }
            ws.recycle(att);
            let h2 = self.ln2.forward(&x1, rows, ws)?;
            let f = self.ff.forward(&h2, rows, ws)?;
            ws.recycle(h2);
            for (o, fv) in x1.iter_mut().zip(&f) {
                *o += fv;
            }
            ws.recycle(f);
            Ok(x1)
        }
    }

    fn backward(
        &self,
        dy: &[f32],
        rows: usize,
        ws: &mut Workspace,
        grads: &mut GradStore,
    ) -> Result<Vec<f32>> {
        if self.parallel_residual {
            // dx = dy + ln2ᵀ(ffᵀ(dy)) + ln1ᵀ(attnᵀ(dy))
            let dh2 = self.ff.backward(dy, rows, ws, grads)?;
            let dxf = self.ln2.backward(&dh2, rows, ws, grads)?;
            ws.recycle(dh2);
            let dh1 = self.attn.backward(dy, rows, ws, grads)?;
            let dxa = self.ln1.backward(&dh1, rows, ws, grads)?;
            ws.recycle(dh1);
            let mut dx = ws.alloc_copy(dy);
            for ((o, a), f) in dx.iter_mut().zip(&dxa).zip(&dxf) {
                *o += a + f;
            }
            ws.recycle(dxa);
            ws.recycle(dxf);
            Ok(dx)
        } else {
            // dx1 = dy + ln2ᵀ(ffᵀ(dy)); dx = dx1 + ln1ᵀ(attnᵀ(dx1))
            let dh2 = self.ff.backward(dy, rows, ws, grads)?;
            let dxf = self.ln2.backward(&dh2, rows, ws, grads)?;
            ws.recycle(dh2);
            let mut dx1 = ws.alloc_copy(dy);
            for (o, f) in dx1.iter_mut().zip(&dxf) {
                *o += f;
            }
            ws.recycle(dxf);
            let dh1 = self.attn.backward(&dx1, rows, ws, grads)?;
            let dxa = self.ln1.backward(&dh1, rows, ws, grads)?;
            ws.recycle(dh1);
            for (o, a) in dx1.iter_mut().zip(&dxa) {
                *o += a;
            }
            ws.recycle(dxa);
            Ok(dx1)
        }
    }
}

/// Per-lane K/V cache for incremental decoding: one `(b*nh, s, hd)`
/// head-blocked K and V buffer per layer (`n_layers · b · s · d · 2`
/// floats total), drawn from the scratch recycler and returned to it
/// on drop, plus the per-lane position counters.
///
/// **Cache invariant:** for every lane, rows `[0, lens[lane])` of each
/// `(lane, head)` block hold the K/V of the lane's prefix in position
/// order and are bitwise identical to what a full-batch forward over
/// that prefix would produce; rows at `lens[lane]` and beyond are
/// stale and are never read. Resetting a lane only zeroes its length —
/// no buffer is cleared or reallocated. Positions are **absolute**
/// (learned positional embeddings), so the buffers must not rotate:
/// when a lane reaches capacity `s`, the caller resets it and re-feeds
/// the slid window token by token (exactly reproducing the legacy
/// path's recompute over the slid window) instead of wrapping around.
pub struct DecodeState {
    n_layers: usize,
    b: usize,
    s: usize,
    d: usize,
    /// Per-layer K / V caches, each `b * s * d` floats.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Valid positions per lane.
    lens: Vec<usize>,
    /// Reusable compact active-lane map (scratch for the step).
    lane_map: Vec<usize>,
}

impl DecodeState {
    /// Fresh caches for `b` lanes of `arch` geometry, all lanes empty.
    pub fn new(arch: &ArchCfg, b: usize) -> DecodeState {
        let n = b * arch.seq * arch.d_model;
        DecodeState {
            n_layers: arch.n_layers,
            b,
            s: arch.seq,
            d: arch.d_model,
            k: (0..arch.n_layers).map(|_| scratch::take_f32(n)).collect(),
            v: (0..arch.n_layers).map(|_| scratch::take_f32(n)).collect(),
            lens: vec![0; b],
            lane_map: Vec::with_capacity(b),
        }
    }

    /// Number of cache lanes.
    pub fn lanes(&self) -> usize {
        self.b
    }

    /// Cache capacity per lane (the arch's context length `s`).
    pub fn capacity(&self) -> usize {
        self.s
    }

    /// Cached positions in `lane`.
    pub fn len(&self, lane: usize) -> usize {
        self.lens[lane]
    }

    pub fn is_empty(&self, lane: usize) -> bool {
        self.lens[lane] == 0
    }

    /// Free a lane for a new occupant: its length drops to zero and
    /// the stale rows are simply never read again (see the cache
    /// invariant above).
    pub fn reset_lane(&mut self, lane: usize) {
        self.lens[lane] = 0;
    }

    /// Resident cache memory in floats: `n_layers · b · s · d · 2`.
    pub fn mem_floats(&self) -> usize {
        self.n_layers * self.b * self.s * self.d * 2
    }
}

impl Drop for DecodeState {
    fn drop(&mut self) {
        for buf in self.k.drain(..).chain(self.v.drain(..)) {
            scratch::put_f32(buf);
        }
    }
}

impl DecoderLayer<'_> {
    /// [`DecoderLayer`] forward for one incremental decode step: the
    /// exact residual wiring of [`Layer::forward`] (both arrangements,
    /// including the single-expression parallel-residual add) with the
    /// attention replaced by [`Attention::decode_step`] against this
    /// layer's K/V caches. `x` is `(a, d)` compact active-lane rows.
    fn decode_step(
        &self,
        x: &[f32],
        k_cache: &mut [f32],
        v_cache: &mut [f32],
        lanes: &[usize],
        lens: &[usize],
        ws: &mut Workspace,
    ) -> Result<Vec<f32>> {
        let rows = lanes.len();
        if self.parallel_residual {
            // y = x + attn(ln1(x)) + ff(ln2(x))
            let h1 = self.ln1.forward(x, rows, ws)?;
            let att = self.attn.decode_step(&h1, k_cache, v_cache, lanes, lens, ws)?;
            ws.recycle(h1);
            let h2 = self.ln2.forward(x, rows, ws)?;
            let f = self.ff.forward(&h2, rows, ws)?;
            ws.recycle(h2);
            let mut y = ws.alloc_copy(x);
            for ((o, a), fv) in y.iter_mut().zip(&att).zip(&f) {
                *o += a + fv;
            }
            ws.recycle(att);
            ws.recycle(f);
            Ok(y)
        } else {
            // x1 = x + attn(ln1(x)); y = x1 + ff(ln2(x1))
            let h1 = self.ln1.forward(x, rows, ws)?;
            let att = self.attn.decode_step(&h1, k_cache, v_cache, lanes, lens, ws)?;
            ws.recycle(h1);
            let mut x1 = ws.alloc_copy(x);
            for (o, a) in x1.iter_mut().zip(&att) {
                *o += a;
            }
            ws.recycle(att);
            let h2 = self.ln2.forward(&x1, rows, ws)?;
            let f = self.ff.forward(&h2, rows, ws)?;
            ws.recycle(h2);
            for (o, fv) in x1.iter_mut().zip(&f) {
                *o += fv;
            }
            ws.recycle(f);
            Ok(x1)
        }
    }
}

impl<'a> Lm<'a> {
    fn embedding(&self) -> Result<Embedding<'a>> {
        Embedding::new(&self.p, self.arch.vocab, self.arch.seq, self.arch.d_model)
    }

    /// Wire decoder block `l` from the layer modules for a `(b, s)`
    /// step geometry.
    pub fn decoder_layer(&self, l: usize, b: usize, s: usize) -> Result<DecoderLayer<'a>> {
        let arch = self.arch;
        let (d, ff) = (arch.d_model, arch.d_ff);
        let pref = format!("layer{l}");
        Ok(DecoderLayer {
            ln1: LayerNorm::new(&self.p, &format!("{pref}.ln1"), d)?,
            attn: Attention::new(&self.p, &format!("{pref}.attn"), d, arch.n_heads, b, s)?,
            ln2: LayerNorm::new(&self.p, &format!("{pref}.ln2"), d)?,
            ff: FfBlock::new(
                self.var
                    .linear_view(&self.p, &format!("{pref}.ff.fc1"), d, ff, l)?,
                &format!("{pref}.ff.fc1"),
                self.var
                    .linear_view(&self.p, &format!("{pref}.ff.fc2"), ff, d, l)?,
                &format!("{pref}.ff.fc2"),
            ),
            parallel_residual: arch.parallel_residual,
        })
    }

    fn final_ln(&self) -> Result<LayerNorm<'a>> {
        LayerNorm::new(&self.p, "final_ln", self.arch.d_model)
    }

    fn head(&self) -> Result<TiedLmHead<'a>> {
        TiedLmHead::new(&self.p, self.arch.vocab, self.arch.d_model)
    }

    /// `(b, s)` int32 tokens -> `(b*s, d)` final hidden states
    /// (inference: non-recording workspace, [`num_threads`] workers).
    pub fn hidden(&self, tokens: &[i32], b: usize, s: usize) -> Result<Vec<f32>> {
        self.hidden_with_threads(tokens, b, s, num_threads())
    }

    /// [`Lm::hidden`] on an explicit worker count — serve workers and
    /// threads-aware backends pass their own pool size here instead of
    /// silently falling back to the process default.
    pub fn hidden_with_threads(
        &self,
        tokens: &[i32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let mut ws = Workspace::inference_with_threads(threads);
        self.hidden_ws(tokens, b, s, &mut ws)
    }

    fn hidden_ws(
        &self,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
    ) -> Result<Vec<f32>> {
        let rows = b * s;
        let mut x = self.embedding()?.forward(tokens, b, s)?;
        for l in 0..self.arch.n_layers {
            let next = self.decoder_layer(l, b, s)?.forward(&x, rows, ws)?;
            ws.recycle(std::mem::replace(&mut x, next));
        }
        let h = self.final_ln()?.forward(&x, rows, ws)?;
        ws.recycle(x);
        Ok(h)
    }

    /// Tied-head logits for every position: `(rows, vocab)`.
    fn logits(&self, hidden: &[f32], rows: usize, threads: usize) -> Result<Vec<f32>> {
        let tok_emb = self.p.f32("tok_emb")?;
        Ok(matmul_bt_with_threads(
            hidden,
            tok_emb,
            rows,
            self.arch.d_model,
            self.arch.vocab,
            threads,
        ))
    }

    /// Mean next-token cross-entropy + full parameter gradients for
    /// one `(b, s)` token microbatch — the whole decoder on the tape.
    pub fn loss_and_grads(&self, tokens: &[i32], b: usize, s: usize) -> Result<(f32, GradStore)> {
        self.loss_and_grads_with_threads(tokens, b, s, num_threads())
    }

    pub fn loss_and_grads_with_threads(
        &self,
        tokens: &[i32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<(f32, GradStore)> {
        if s < 2 {
            bail!("train step needs s >= 2 (next-token loss), got {s}");
        }
        let mut ws = Workspace::training_with_threads(threads);
        let rows = b * s;
        let vocab = self.arch.vocab;
        let emb = self.embedding()?;
        let layers: Vec<DecoderLayer<'a>> = (0..self.arch.n_layers)
            .map(|l| self.decoder_layer(l, b, s))
            .collect::<Result<_>>()?;
        let final_ln = self.final_ln()?;
        let head = self.head()?;

        // forward
        let mut x = emb.forward(tokens, b, s)?;
        for l in &layers {
            let next = l.forward(&x, rows, &mut ws)?;
            ws.recycle(std::mem::replace(&mut x, next));
        }
        let h = final_ln.forward(&x, rows, &mut ws)?;
        ws.recycle(x);
        let logits = head.forward(&h, rows, &mut ws)?;
        ws.recycle(h);

        // loss = mean over b*(s-1) next-token predictions
        // (model.py::loss_fn); rows at t = s-1 predict nothing
        let n_pred = (b * (s - 1)) as f32;
        let mut dlogits = ws.alloc_zeroed(rows * vocab);
        let mut logp = scratch::take_f32(vocab);
        let mut loss = 0.0f64;
        for bi in 0..b {
            for t in 0..s - 1 {
                let r = bi * s + t;
                let tgt = tokens[bi * s + t + 1] as usize;
                loss += softmax_xent_row(
                    &logits[r * vocab..(r + 1) * vocab],
                    tgt,
                    1.0 / n_pred,
                    &mut dlogits[r * vocab..(r + 1) * vocab],
                    &mut logp,
                ) as f64;
            }
        }
        let loss = (loss / n_pred as f64) as f32;
        scratch::put_f32(logp);
        ws.recycle(logits);

        // backward
        let mut grads = GradStore::new();
        let dh = head.backward(&dlogits, rows, &mut ws, &mut grads)?;
        ws.recycle(dlogits);
        let mut dx = final_ln.backward(&dh, rows, &mut ws, &mut grads)?;
        ws.recycle(dh);
        for l in layers.iter().rev() {
            let next = l.backward(&dx, rows, &mut ws, &mut grads)?;
            ws.recycle(std::mem::replace(&mut dx, next));
        }
        emb.backward(&dx, tokens, s, &mut grads)?;
        ws.recycle(dx);
        debug_assert_eq!(ws.depth(), 0, "unconsumed tape frames");
        Ok((loss, grads))
    }

    /// `score` artifact: masked summed token log-prob + token counts.
    pub fn score(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.score_with_threads(tokens, mask, b, s, num_threads())
    }

    /// [`Lm::score`] on an explicit worker count (the serve workers'
    /// per-worker pool size).
    pub fn score_with_threads(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let h = self.hidden_with_threads(tokens, b, s, threads)?;
        let vocab = self.arch.vocab;
        let logits = self.logits(&h, b * s, threads)?;
        scratch::put_f32(h);
        let mut sums = vec![0.0f32; b];
        let mut counts = vec![0.0f32; b];
        let mut logp = scratch::take_f32(vocab);
        for bi in 0..b {
            for t in 0..s - 1 {
                let m = mask[bi * s + t + 1];
                if m == 0.0 {
                    continue;
                }
                let row = &logits[(bi * s + t) * vocab..(bi * s + t + 1) * vocab];
                log_softmax_row(row, &mut logp);
                let tgt = tokens[bi * s + t + 1] as usize;
                sums[bi] += logp[tgt] * m;
                counts[bi] += m;
            }
        }
        scratch::put_f32(logp);
        scratch::put_f32(logits);
        Ok((sums, counts))
    }

    /// `eval_loss` artifact: mean next-token cross-entropy.
    pub fn eval_loss(&self, tokens: &[i32], b: usize, s: usize) -> Result<f32> {
        self.eval_loss_with_threads(tokens, b, s, num_threads())
    }

    /// [`Lm::eval_loss`] on an explicit worker count.
    pub fn eval_loss_with_threads(
        &self,
        tokens: &[i32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<f32> {
        let h = self.hidden_with_threads(tokens, b, s, threads)?;
        let vocab = self.arch.vocab;
        let logits = self.logits(&h, b * s, threads)?;
        scratch::put_f32(h);
        let mut total = 0.0f64;
        let mut logp = scratch::take_f32(vocab);
        for bi in 0..b {
            for t in 0..s - 1 {
                let row = &logits[(bi * s + t) * vocab..(bi * s + t + 1) * vocab];
                log_softmax_row(row, &mut logp);
                total -= logp[tokens[bi * s + t + 1] as usize] as f64;
            }
        }
        scratch::put_f32(logp);
        scratch::put_f32(logits);
        Ok((total / (b * (s - 1)) as f64) as f32)
    }

    /// `features` artifact: masked mean-pooled hidden states `(b, d)`.
    pub fn features(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> Result<Vec<f32>> {
        self.features_with_threads(tokens, mask, b, s, num_threads())
    }

    /// [`Lm::features`] on an explicit worker count.
    pub fn features_with_threads(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let d = self.arch.d_model;
        let h = self.hidden_with_threads(tokens, b, s, threads)?;
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            let orow = &mut out[bi * d..(bi + 1) * d];
            let mut msum = 0.0f32;
            for t in 0..s {
                let m = mask[bi * s + t];
                if m != 0.0 {
                    axpy(orow, m, &h[(bi * s + t) * d..(bi * s + t + 1) * d]);
                    msum += m;
                }
            }
            let denom = msum.max(1.0);
            for v in orow.iter_mut() {
                *v /= denom;
            }
        }
        scratch::put_f32(h);
        Ok(out)
    }

    /// `next_logits` artifact: logits at each sequence's last real
    /// position, `(b, vocab)`.
    pub fn next_logits(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        b: usize,
        s: usize,
    ) -> Result<Vec<f32>> {
        self.next_logits_with_threads(tokens, lengths, b, s, num_threads())
    }

    /// [`Lm::next_logits`] on an explicit worker count.
    pub fn next_logits_with_threads(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        b: usize,
        s: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let d = self.arch.d_model;
        let h = self.hidden_with_threads(tokens, b, s, threads)?;
        let mut last = scratch::take_f32(b * d);
        for bi in 0..b {
            let idx = (lengths[bi].max(1) - 1).min(s as i32 - 1) as usize;
            last[bi * d..(bi + 1) * d]
                .copy_from_slice(&h[(bi * s + idx) * d..(bi * s + idx + 1) * d]);
        }
        scratch::put_f32(h);
        let logits = self.logits(&last, b, threads)?;
        scratch::put_f32(last);
        Ok(logits)
    }

    /// One incremental decode step: feed one token per **active** lane
    /// (`tokens[lane] < 0` marks a lane inactive), append its K/V to
    /// `st`, and write the next-token logits row for every active lane
    /// into `logits_out` (`(st.lanes(), vocab)`; inactive rows are
    /// zeroed).
    ///
    /// Active lanes are compacted before the layer stack, so a step
    /// with `a` active lanes pays for `a` rows of compute — idle lanes
    /// cost nothing. Bitwise identical to running
    /// [`Lm::next_logits_with_threads`] over the lane's full prefix
    /// (the parity tests pin this per variant and thread count): the
    /// embeddings, projections, layer norms and ff are all per-row
    /// kernels, and cached K/V rows reproduce the batch forward's by
    /// causal induction.
    ///
    /// Errors if a lane is already at capacity (`len == s`): positions
    /// are absolute, so the caller must [`DecodeState::reset_lane`] and
    /// re-feed the slid window instead.
    pub fn decode_step_with_threads(
        &self,
        st: &mut DecodeState,
        tokens: &[i32],
        logits_out: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        let arch = self.arch;
        let (d, vocab) = (arch.d_model, arch.vocab);
        if st.n_layers != arch.n_layers || st.s != arch.seq || st.d != d {
            bail!(
                "decode cache geometry ({}, {}, {}) does not match arch ({}, {}, {d})",
                st.n_layers,
                st.s,
                st.d,
                arch.n_layers,
                arch.seq
            );
        }
        if tokens.len() != st.b {
            bail!("decode step: {} token ids for {} lanes", tokens.len(), st.b);
        }
        if logits_out.len() != st.b * vocab {
            bail!(
                "decode step: logits buffer holds {} values, want {} ({} lanes x {vocab})",
                logits_out.len(),
                st.b * vocab,
                st.b
            );
        }
        logits_out.fill(0.0);
        let mut lanes = std::mem::take(&mut st.lane_map);
        lanes.clear();
        for (lane, &tok) in tokens.iter().enumerate() {
            if tok < 0 {
                continue;
            }
            if tok as usize >= vocab {
                st.lane_map = lanes;
                bail!("decode step: token id {tok} out of vocab {vocab}");
            }
            if st.lens[lane] >= st.s {
                st.lane_map = lanes;
                bail!(
                    "decode step: lane {lane} is at capacity {} — reset the lane and \
                     re-feed the slid window",
                    st.s
                );
            }
            lanes.push(lane);
        }
        if lanes.is_empty() {
            st.lane_map = lanes;
            return Ok(());
        }
        let a = lanes.len();
        let mut ws = Workspace::inference_with_threads(threads);

        // embedding: tok_emb[token] + pos_emb[position], elementwise —
        // the same expression `Embedding::forward` evaluates for the
        // batch path at this absolute position
        let tok_emb = self.p.f32("tok_emb")?;
        let pos_emb = self.p.f32("pos_emb")?;
        let mut x = ws.alloc_zeroed(a * d);
        for (g, &lane) in lanes.iter().enumerate() {
            let tok = tokens[lane] as usize;
            let pos = st.lens[lane];
            let row = &mut x[g * d..(g + 1) * d];
            let e = &tok_emb[tok * d..(tok + 1) * d];
            let p = &pos_emb[pos * d..(pos + 1) * d];
            for (j, r) in row.iter_mut().enumerate() {
                *r = e[j] + p[j];
            }
        }
        // from here on the new token is part of every lane's prefix
        for &lane in &lanes {
            st.lens[lane] += 1;
        }

        for l in 0..arch.n_layers {
            let layer = self.decoder_layer(l, st.b, st.s)?;
            let next =
                layer.decode_step(&x, &mut st.k[l], &mut st.v[l], &lanes, &st.lens, &mut ws)?;
            ws.recycle(std::mem::replace(&mut x, next));
        }
        let h = self.final_ln()?.forward(&x, a, &mut ws)?;
        ws.recycle(x);
        let logits = self.logits(&h, a, threads)?;
        scratch::put_f32(h);
        for (g, &lane) in lanes.iter().enumerate() {
            logits_out[lane * vocab..(lane + 1) * vocab]
                .copy_from_slice(&logits[g * vocab..(g + 1) * vocab]);
        }
        scratch::put_f32(logits);
        st.lane_map = lanes;
        Ok(())
    }
}

/// One full LM optimizer step over flat named training state
/// (`names[i]` owns `params[i]`/`m[i]`/`v[i]`): forward + backward
/// through the whole decoder, global-norm gradient clipping
/// (`min(1, clip/(|g|+1e-12))`, `model.py::make_train_step`), one
/// bias-corrected Adam update in place. Returns the microbatch loss.
///
/// Shared by the `train_step` artifact executor, the
/// `native_train_sweep` bench and the tests, so the training-step
/// semantics live in exactly one place.
pub fn train_microbatch(
    arch: &ArchCfg,
    var: &VariantSpec,
    names: &[String],
    params: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    tokens: &[i32],
    b: usize,
    s: usize,
    step: &mut f32,
    lr: f32,
    threads: usize,
) -> Result<f32> {
    let (loss, mut grads) = {
        let p = Params::from_named(names, &*params);
        let lm = Lm { arch, var, p };
        lm.loss_and_grads_with_threads(tokens, b, s, threads)?
    };
    let gnorm = grads.global_norm();
    let clip = ADAM.grad_clip as f32;
    let scale = (clip / (gnorm + 1e-12)).min(1.0);
    if scale < 1.0 {
        grads.scale(scale);
    }
    let gvecs = grads
        .into_named_order(names)
        .context("assemble LM gradients in feed order")?;
    *step += 1.0;
    super::adam_update(params, m, v, &gvecs, *step, lr);
    // the applied gradients go back to the arena: the next microbatch
    // re-takes these exact buffers, keeping the steady state
    // allocation-free
    for g in gvecs {
        scratch::put_f32(g);
    }
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::catalog::{self, model_param_specs};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn tiny_arch(parallel: bool) -> ArchCfg {
        ArchCfg {
            vocab: 13,
            d_model: 8,
            d_ff: 16,
            n_layers: 2,
            n_heads: 2,
            seq: 6,
            parallel_residual: parallel,
        }
    }

    /// names + randomly initialised flat params for (arch, variant).
    fn tiny_state(
        arch: &ArchCfg,
        vname: &str,
        seed: u64,
    ) -> (Vec<String>, Vec<Vec<f32>>, VariantSpec) {
        let variants = catalog::variants();
        let vcfg = &variants[vname];
        let specs = model_param_specs(arch, vcfg);
        let mut rng = Rng::new(seed);
        let names: Vec<String> = specs.iter().map(|(n, _, _)| n.clone()).collect();
        let params: Vec<Vec<f32>> = specs
            .iter()
            .map(|(_, sh, init)| Tensor::init(sh, init, &mut rng).as_f32().unwrap().to_vec())
            .collect();
        (names, params, VariantSpec::resolve(vcfg).unwrap())
    }

    /// End-to-end gradcheck of the full decoder loss: a sampled entry
    /// of *every* parameter tensor against central finite differences,
    /// DYAD variant, both residual modes.
    #[test]
    fn tiny_transformer_full_step_gradcheck() {
        for parallel in [false, true] {
            let arch = tiny_arch(parallel);
            let (names, params, var) = tiny_state(&arch, "dyad_it", 77);
            let (b, s) = (2usize, 5usize);
            let mut rng = Rng::new(5);
            let tokens: Vec<i32> =
                (0..b * s).map(|_| rng.below(arch.vocab) as i32).collect();
            let loss_of = |params: &[Vec<f32>]| -> f32 {
                let p = Params::from_named(&names, params);
                let lm = Lm { arch: &arch, var: &var, p };
                lm.loss_and_grads_with_threads(&tokens, b, s, 2).unwrap().0
            };
            let p = Params::from_named(&names, &params);
            let lm = Lm { arch: &arch, var: &var, p };
            let (loss, grads) =
                lm.loss_and_grads_with_threads(&tokens, b, s, 2).unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            let h = 1e-2f32;
            for (pi, name) in names.iter().enumerate() {
                let g = grads
                    .get(name)
                    .unwrap_or_else(|| panic!("no grad for {name}"));
                assert_eq!(g.len(), params[pi].len(), "{name}");
                let idx = (pi * 37) % params[pi].len();
                let mut pp = params.clone();
                pp[pi][idx] += h;
                let mut pm = params.clone();
                pm[pi][idx] -= h;
                let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * h);
                let an = g[idx];
                assert!(
                    (an - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                    "parallel={parallel} {name}[{idx}]: analytic {an} vs fd {fd}"
                );
            }
        }
    }

    /// The full-step backward is bitwise thread-deterministic (the
    /// determinism contract of the PR 2 kernels extends through the
    /// whole layer stack).
    #[test]
    fn full_step_backward_thread_determinism() {
        let arch = tiny_arch(false);
        let (names, params, var) = tiny_state(&arch, "dyad_it", 31);
        let (b, s) = (2usize, 6usize);
        let mut rng = Rng::new(8);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(arch.vocab) as i32).collect();
        let run = |threads: usize| -> (f32, Vec<Vec<f32>>) {
            let p = Params::from_named(&names, &params);
            let lm = Lm { arch: &arch, var: &var, p };
            let (loss, grads) =
                lm.loss_and_grads_with_threads(&tokens, b, s, threads).unwrap();
            (loss, grads.into_named_order(&names).unwrap())
        };
        let (l1, g1) = run(1);
        for threads in [2, 3, 8] {
            let (ln, gn) = run(threads);
            assert_eq!(l1, ln, "loss changed bits at threads={threads}");
            for ((a, b_), name) in g1.iter().zip(&gn).zip(&names) {
                assert_eq!(a, b_, "{name} changed bits at threads={threads}");
            }
        }
    }

    /// The fused -CAT schedule trains end to end: the same full-model
    /// gradcheck as `tiny_transformer_full_step_gradcheck`, but with
    /// every swap-site linear running `dyad_fused_cat` /
    /// `dyad_cat_backward_{dx,dw}` through the it_cat variant.
    #[test]
    fn tiny_transformer_it_cat_gradcheck() {
        let arch = tiny_arch(false);
        let (names, params, var) = tiny_state(&arch, "dyad_it_cat", 77);
        let (b, s) = (2usize, 5usize);
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(arch.vocab) as i32).collect();
        let loss_of = |params: &[Vec<f32>]| -> f32 {
            let p = Params::from_named(&names, params);
            let lm = Lm { arch: &arch, var: &var, p };
            lm.loss_and_grads_with_threads(&tokens, b, s, 2).unwrap().0
        };
        let p = Params::from_named(&names, &params);
        let lm = Lm { arch: &arch, var: &var, p };
        let (loss, grads) = lm.loss_and_grads_with_threads(&tokens, b, s, 2).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let h = 1e-2f32;
        for (pi, name) in names.iter().enumerate() {
            let g = grads.get(name).unwrap_or_else(|| panic!("no grad for {name}"));
            let idx = (pi * 37) % params[pi].len();
            let mut pp = params.clone();
            pp[pi][idx] += h;
            let mut pm = params.clone();
            pm[pi][idx] -= h;
            let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * h);
            let an = g[idx];
            assert!(
                (an - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "it_cat {name}[{idx}]: analytic {an} vs fd {fd}"
            );
        }
    }

    /// Quantized weight streams keep the model usable: a tiny-arch
    /// random-init eval loss under bf16/i8 stays within tolerance of
    /// the f32 loss (the CI quality gate for `--precision`).
    #[test]
    fn precision_quality_gate() {
        let arch = tiny_arch(false);
        let (names, params, var) = tiny_state(&arch, "dyad_it", 19);
        let (b, s) = (2usize, 6usize);
        let mut rng = Rng::new(12);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(arch.vocab) as i32).collect();
        let loss_at = |precision: crate::tensor::Precision| -> f32 {
            let mut var = var.clone();
            var.precision = precision;
            let p = Params::from_named(&names, &params);
            let lm = Lm { arch: &arch, var: &var, p };
            lm.eval_loss(&tokens, b, s).unwrap()
        };
        let f32_loss = loss_at(crate::tensor::Precision::F32);
        let bf16_loss = loss_at(crate::tensor::Precision::Bf16);
        let i8_loss = loss_at(crate::tensor::Precision::I8);
        assert!(f32_loss.is_finite() && bf16_loss.is_finite() && i8_loss.is_finite());
        assert!(
            (bf16_loss - f32_loss).abs() < 0.05,
            "bf16 eval_loss {bf16_loss} drifted from f32 {f32_loss}"
        );
        assert!(
            (i8_loss - f32_loss).abs() < 0.15,
            "i8 eval_loss {i8_loss} drifted from f32 {f32_loss}"
        );
    }

    /// Full-recompute logits for one lane's prefix — the oracle the
    /// incremental decode path is pinned against. b=1 is bitwise
    /// equivalent to any padded batch row: every kernel in the stack
    /// is per-row deterministic, so a row's logits depend only on its
    /// own tokens.
    fn oracle_row(lm: &Lm, prefix: &[i32], s: usize, threads: usize) -> Vec<f32> {
        let w = if prefix.len() > s { &prefix[prefix.len() - s..] } else { prefix };
        let mut toks = vec![0i32; s];
        toks[..w.len()].copy_from_slice(w);
        lm.next_logits_with_threads(&toks, &[w.len() as i32], 1, s, threads).unwrap()
    }

    /// The tentpole parity proof: incremental KV-cache decoding is
    /// **bitwise** identical to full-context recompute, for all three
    /// serving variants, thread counts {1, 2, 8}, both residual
    /// modes, with staggered multi-lane admission (lane `l` joins at
    /// step `l`, exercising idle `-1` lanes and compaction).
    #[test]
    fn decode_step_matches_full_recompute_bitwise() {
        for parallel in [false, true] {
            for vname in ["dense", "dyad_it", "dyad_it_cat"] {
                for threads in [1usize, 2, 8] {
                    let arch = tiny_arch(parallel);
                    let (names, params, var) = tiny_state(&arch, vname, 11);
                    let p = Params::from_named(&names, &params);
                    let lm = Lm { arch: &arch, var: &var, p };
                    let prompts: [&[i32]; 3] =
                        [&[1, 2, 3, 4, 5], &[6], &[7, 8, 9, 10]];
                    let mut st = DecodeState::new(&arch, prompts.len());
                    let vocab = arch.vocab;
                    let mut logits = vec![0.0f32; prompts.len() * vocab];
                    let steps =
                        prompts.iter().enumerate().map(|(l, p)| l + p.len()).max().unwrap();
                    for step in 0..steps {
                        let tokens: Vec<i32> = prompts
                            .iter()
                            .enumerate()
                            .map(|(l, p)| {
                                // lane l admitted at step l
                                if step >= l && step - l < p.len() {
                                    p[step - l]
                                } else {
                                    -1
                                }
                            })
                            .collect();
                        lm.decode_step_with_threads(&mut st, &tokens, &mut logits, threads)
                            .unwrap();
                        for (l, prompt) in prompts.iter().enumerate() {
                            if tokens[l] < 0 {
                                continue;
                            }
                            let fed = &prompt[..step - l + 1];
                            let want = oracle_row(&lm, fed, arch.seq, threads);
                            assert_eq!(
                                &logits[l * vocab..(l + 1) * vocab],
                                &want[..],
                                "parallel={parallel} {vname} threads={threads} \
                                 lane={l} prefix_len={}",
                                fed.len()
                            );
                        }
                    }
                    assert_eq!(st.len(0), prompts[0].len());
                }
            }
        }
    }

    /// A lane at capacity refuses further tokens (positions are
    /// absolute), and the documented recovery — reset the lane and
    /// re-feed the slid window — lands bitwise on the full-recompute
    /// path's own slid-window logits.
    #[test]
    fn decode_capacity_resets_and_window_slide_matches_oracle() {
        let arch = tiny_arch(false);
        let s = arch.seq;
        let (names, params, var) = tiny_state(&arch, "dyad_it", 23);
        let p = Params::from_named(&names, &params);
        let lm = Lm { arch: &arch, var: &var, p };
        let full: Vec<i32> = (0..=s as i32).collect(); // one past capacity
        let mut st = DecodeState::new(&arch, 1);
        let mut logits = vec![0.0f32; arch.vocab];
        for &t in &full[..s] {
            lm.decode_step_with_threads(&mut st, &[t], &mut logits, 2).unwrap();
        }
        assert_eq!(st.len(0), s);
        let err = lm
            .decode_step_with_threads(&mut st, &[full[s]], &mut logits, 2)
            .unwrap_err();
        assert!(format!("{err:#}").contains("capacity"), "{err:#}");
        // slide: drop the oldest token, re-feed the rest plus the new one
        st.reset_lane(0);
        assert!(st.is_empty(0));
        for &t in &full[1..] {
            lm.decode_step_with_threads(&mut st, &[t], &mut logits, 2).unwrap();
        }
        let want = oracle_row(&lm, &full[1..], s, 2);
        assert_eq!(&logits[..], &want[..], "slid window diverged from oracle");
    }

    /// Decode input validation: out-of-vocab tokens and geometry
    /// mismatches fail loudly, an all-idle step is a cheap no-op, and
    /// a failed step leaves the state usable.
    #[test]
    fn decode_step_rejects_bad_inputs() {
        let arch = tiny_arch(false);
        let (names, params, var) = tiny_state(&arch, "dense", 9);
        let p = Params::from_named(&names, &params);
        let lm = Lm { arch: &arch, var: &var, p };
        let mut st = DecodeState::new(&arch, 2);
        let vocab = arch.vocab;
        let mut logits = vec![0.0f32; 2 * vocab];
        assert!(lm
            .decode_step_with_threads(&mut st, &[vocab as i32, -1], &mut logits, 1)
            .is_err());
        assert!(lm
            .decode_step_with_threads(&mut st, &[1], &mut logits, 1)
            .is_err());
        assert!(lm
            .decode_step_with_threads(&mut st, &[1, 2], &mut logits[..vocab], 1)
            .is_err());
        // all lanes idle: Ok, logits zeroed, lengths untouched
        logits.fill(3.0);
        lm.decode_step_with_threads(&mut st, &[-1, -1], &mut logits, 1).unwrap();
        assert!(logits.iter().all(|&x| x == 0.0));
        assert!(st.is_empty(0) && st.is_empty(1));
        // the failed steps above left the state consistent: a valid
        // step still matches the oracle
        lm.decode_step_with_threads(&mut st, &[3, -1], &mut logits, 1).unwrap();
        let want = oracle_row(&lm, &[3], arch.seq, 1);
        assert_eq!(&logits[..vocab], &want[..]);
        assert_eq!(st.len(0), 1);
        assert_eq!(st.mem_floats(), arch.n_layers * 2 * arch.seq * arch.d_model * 2);
    }

    /// A few grad-clipped Adam steps on a repeated tiny batch reduce
    /// the loss — train_microbatch end to end, dense and DYAD
    /// (including the fused -CAT schedule).
    #[test]
    fn train_microbatch_overfits_repeated_batch() {
        for vname in ["dense", "dyad_it", "dyad_it_cat"] {
            let arch = tiny_arch(false);
            let (names, mut params, var) = tiny_state(&arch, vname, 3);
            let mut m: Vec<Vec<f32>> =
                params.iter().map(|p| vec![0.0; p.len()]).collect();
            let mut v: Vec<Vec<f32>> =
                params.iter().map(|p| vec![0.0; p.len()]).collect();
            let (b, s) = (2usize, 6usize);
            let mut rng = Rng::new(4);
            let tokens: Vec<i32> =
                (0..b * s).map(|_| rng.below(arch.vocab) as i32).collect();
            let mut step = 0.0f32;
            let mut losses = Vec::new();
            for _ in 0..30 {
                losses.push(
                    train_microbatch(
                        &arch, &var, &names, &mut params, &mut m, &mut v, &tokens, b, s,
                        &mut step, 1e-2, 2,
                    )
                    .unwrap(),
                );
            }
            assert_eq!(step, 30.0);
            assert!(losses.iter().all(|l| l.is_finite()));
            let (first, last) = (losses[0], *losses.last().unwrap());
            assert!(
                last < first - 0.5,
                "{vname}: no learning (first {first}, last {last})"
            );
        }
    }
}

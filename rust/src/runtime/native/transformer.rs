//! Native decoder-only transformer forward — the pure-Rust mirror of
//! `python/compile/model.py` (pre-LN, tied embeddings, learned
//! positions, tanh-GELU ff, optional Pythia parallel residual).
//!
//! Inference only: `score`, `features`, `next_logits` and `eval_loss`
//! run here; transformer *training* stays on the XLA backend (native
//! transformer backprop is a ROADMAP item). Attention parallelises
//! over (batch, head) pairs; linears ride on `dyad::kernel`.

use anyhow::{bail, Result};

use crate::dyad::kernel::{axpy, dense_linear, dot, matmul_bt, num_threads, parallel_rows};
use crate::runtime::artifact::ArchCfg;

use super::ops::{gelu_inplace, layer_norm, log_softmax_row, softmax_row};
use super::params::Params;
use super::VariantSpec;

pub struct Lm<'a> {
    pub arch: &'a ArchCfg,
    pub var: &'a VariantSpec,
    pub p: Params<'a>,
}

impl Lm<'_> {
    /// `(b, s)` int32 tokens -> `(b*s, d)` final hidden states.
    pub fn hidden(&self, tokens: &[i32], b: usize, s: usize) -> Result<Vec<f32>> {
        let arch = self.arch;
        let d = arch.d_model;
        if tokens.len() != b * s {
            bail!("tokens len {} != {b}x{s}", tokens.len());
        }
        if s > arch.seq {
            bail!("sequence length {s} exceeds arch seq {}", arch.seq);
        }
        let tok_emb = self.p.f32("tok_emb")?;
        let pos_emb = self.p.f32("pos_emb")?;
        let mut x = vec![0.0f32; b * s * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= arch.vocab {
                bail!("token id {tok} out of vocab {}", arch.vocab);
            }
            let row = &mut x[t * d..(t + 1) * d];
            let e = &tok_emb[tok * d..(tok + 1) * d];
            let p = &pos_emb[(t % s) * d..(t % s + 1) * d];
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for l in 0..arch.n_layers {
            let pref = format!("layer{l}");
            if arch.parallel_residual {
                let mut h1 = x.clone();
                layer_norm(
                    &mut h1,
                    d,
                    self.p.f32(&format!("{pref}.ln1.scale"))?,
                    self.p.f32(&format!("{pref}.ln1.bias"))?,
                );
                let mut h2 = x.clone();
                layer_norm(
                    &mut h2,
                    d,
                    self.p.f32(&format!("{pref}.ln2.scale"))?,
                    self.p.f32(&format!("{pref}.ln2.bias"))?,
                );
                let att = self.attention(&h1, &format!("{pref}.attn"), b, s)?;
                let ff = self.ff(&h2, &pref, l, b * s)?;
                for i in 0..x.len() {
                    x[i] += att[i] + ff[i];
                }
            } else {
                let mut h = x.clone();
                layer_norm(
                    &mut h,
                    d,
                    self.p.f32(&format!("{pref}.ln1.scale"))?,
                    self.p.f32(&format!("{pref}.ln1.bias"))?,
                );
                let att = self.attention(&h, &format!("{pref}.attn"), b, s)?;
                for i in 0..x.len() {
                    x[i] += att[i];
                }
                let mut h = x.clone();
                layer_norm(
                    &mut h,
                    d,
                    self.p.f32(&format!("{pref}.ln2.scale"))?,
                    self.p.f32(&format!("{pref}.ln2.bias"))?,
                );
                let ff = self.ff(&h, &pref, l, b * s)?;
                for i in 0..x.len() {
                    x[i] += ff[i];
                }
            }
        }
        layer_norm(
            &mut x,
            d,
            self.p.f32("final_ln.scale")?,
            self.p.f32("final_ln.bias")?,
        );
        Ok(x)
    }

    /// Causal multi-head attention on `(b*s, d)` rows.
    fn attention(&self, x: &[f32], prefix: &str, b: usize, s: usize) -> Result<Vec<f32>> {
        let arch = self.arch;
        let (d, nh) = (arch.d_model, arch.n_heads);
        let hd = arch.head_dim();
        let bs = b * s;
        let proj = |name: &str| -> Result<Vec<f32>> {
            let w = self.p.f32(&format!("{prefix}.{name}"))?;
            let bias = self.p.f32(&format!("{prefix}.{name}_b"))?;
            Ok(dense_linear(x, w, Some(bias), bs, d, d))
        };
        let q = proj("wq")?;
        let k = proj("wk")?;
        let v = proj("wv")?;
        // reorder (bs, d) -> (b*nh, s, hd) so each (batch, head) pair is
        // one contiguous task
        let to_heads = |m: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; bs * d];
            for bi in 0..b {
                for t in 0..s {
                    let src = &m[(bi * s + t) * d..(bi * s + t + 1) * d];
                    for h in 0..nh {
                        let dst = ((bi * nh + h) * s + t) * hd;
                        out[dst..dst + hd].copy_from_slice(&src[h * hd..(h + 1) * hd]);
                    }
                }
            }
            out
        };
        let qh = to_heads(&q);
        let kh = to_heads(&k);
        let vh = to_heads(&v);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut ctx = vec![0.0f32; bs * d];
        // one row per (batch, head): the full s x hd context block
        parallel_rows(&mut ctx, s * hd, num_threads(), &|bh, row| {
            let qb = &qh[bh * s * hd..(bh + 1) * s * hd];
            let kb = &kh[bh * s * hd..(bh + 1) * s * hd];
            let vb = &vh[bh * s * hd..(bh + 1) * s * hd];
            let mut att = vec![0.0f32; s];
            for ti in 0..s {
                let qrow = &qb[ti * hd..(ti + 1) * hd];
                for (tj, a) in att.iter_mut().enumerate().take(ti + 1) {
                    *a = dot(qrow, &kb[tj * hd..(tj + 1) * hd]) * scale;
                }
                softmax_row(&mut att[..ti + 1]);
                let orow = &mut row[ti * hd..(ti + 1) * hd];
                for tj in 0..=ti {
                    axpy(orow, att[tj], &vb[tj * hd..(tj + 1) * hd]);
                }
            }
        });
        // back to (bs, d) then the output projection
        let mut merged = vec![0.0f32; bs * d];
        for bi in 0..b {
            for t in 0..s {
                let dst = &mut merged[(bi * s + t) * d..(bi * s + t + 1) * d];
                for h in 0..nh {
                    let src = ((bi * nh + h) * s + t) * hd;
                    dst[h * hd..(h + 1) * hd].copy_from_slice(&ctx[src..src + hd]);
                }
            }
        }
        let wo = self.p.f32(&format!("{prefix}.wo"))?;
        let wo_b = self.p.f32(&format!("{prefix}.wo_b"))?;
        Ok(dense_linear(&merged, wo, Some(wo_b), bs, d, d))
    }

    /// The paper's swap site: fc1 -> GELU -> fc2 on `(t, d)` rows.
    fn ff(&self, x: &[f32], layer_prefix: &str, layer: usize, t: usize) -> Result<Vec<f32>> {
        let (d, ff) = (self.arch.d_model, self.arch.d_ff);
        let fc1 = self
            .var
            .linear_view(&self.p, &format!("{layer_prefix}.ff.fc1"), d, ff, layer)?;
        let fc2 = self
            .var
            .linear_view(&self.p, &format!("{layer_prefix}.ff.fc2"), ff, d, layer)?;
        let mut h = fc1.forward(x, t);
        gelu_inplace(&mut h);
        Ok(fc2.forward(&h, t))
    }

    /// Tied-head logits for every position: `(b*s, vocab)`.
    fn logits(&self, hidden: &[f32], rows: usize) -> Result<Vec<f32>> {
        let tok_emb = self.p.f32("tok_emb")?;
        Ok(matmul_bt(hidden, tok_emb, rows, self.arch.d_model, self.arch.vocab))
    }

    /// `score` artifact: masked summed token log-prob + token counts.
    pub fn score(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let h = self.hidden(tokens, b, s)?;
        let vocab = self.arch.vocab;
        let logits = self.logits(&h, b * s)?;
        let mut sums = vec![0.0f32; b];
        let mut counts = vec![0.0f32; b];
        let mut logp = vec![0.0f32; vocab];
        for bi in 0..b {
            for t in 0..s - 1 {
                let m = mask[bi * s + t + 1];
                if m == 0.0 {
                    continue;
                }
                let row = &logits[(bi * s + t) * vocab..(bi * s + t + 1) * vocab];
                log_softmax_row(row, &mut logp);
                let tgt = tokens[bi * s + t + 1] as usize;
                sums[bi] += logp[tgt] * m;
                counts[bi] += m;
            }
        }
        Ok((sums, counts))
    }

    /// `eval_loss` artifact: mean next-token cross-entropy.
    pub fn eval_loss(&self, tokens: &[i32], b: usize, s: usize) -> Result<f32> {
        let h = self.hidden(tokens, b, s)?;
        let vocab = self.arch.vocab;
        let logits = self.logits(&h, b * s)?;
        let mut total = 0.0f64;
        let mut logp = vec![0.0f32; vocab];
        for bi in 0..b {
            for t in 0..s - 1 {
                let row = &logits[(bi * s + t) * vocab..(bi * s + t + 1) * vocab];
                log_softmax_row(row, &mut logp);
                total -= logp[tokens[bi * s + t + 1] as usize] as f64;
            }
        }
        Ok((total / (b * (s - 1)) as f64) as f32)
    }

    /// `features` artifact: masked mean-pooled hidden states `(b, d)`.
    pub fn features(
        &self,
        tokens: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
    ) -> Result<Vec<f32>> {
        let d = self.arch.d_model;
        let h = self.hidden(tokens, b, s)?;
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            let orow = &mut out[bi * d..(bi + 1) * d];
            let mut msum = 0.0f32;
            for t in 0..s {
                let m = mask[bi * s + t];
                if m != 0.0 {
                    axpy(orow, m, &h[(bi * s + t) * d..(bi * s + t + 1) * d]);
                    msum += m;
                }
            }
            let denom = msum.max(1.0);
            for v in orow.iter_mut() {
                *v /= denom;
            }
        }
        Ok(out)
    }

    /// `next_logits` artifact: logits at each sequence's last real
    /// position, `(b, vocab)`.
    pub fn next_logits(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        b: usize,
        s: usize,
    ) -> Result<Vec<f32>> {
        let d = self.arch.d_model;
        let h = self.hidden(tokens, b, s)?;
        let mut last = vec![0.0f32; b * d];
        for bi in 0..b {
            let idx = (lengths[bi].max(1) - 1).min(s as i32 - 1) as usize;
            last[bi * d..(bi + 1) * d]
                .copy_from_slice(&h[(bi * s + idx) * d..(bi * s + idx + 1) * d]);
        }
        self.logits(&last, b)
    }
}

//! The native CPU backend: pure-Rust execution of the artifact
//! catalog, no PJRT, no files on disk.
//!
//! `NativeBackend` serves the same manifest inventory as `make
//! artifacts` (see `runtime::catalog`); `load` resolves each artifact
//! kind to a typed program at load time and `run` executes it on host
//! tensors via `dyad::kernel`'s parallel blocked matmuls and the fused
//! DYAD forward.
//!
//! The native backend executes the **full** inventory: transformer
//! inference (`score`, `features`, `next_logits`, `eval_loss`),
//! transformer **training** (`train_step` — layer-module autodiff with
//! in-loop grad-clipped Adam, see [`layers`] and
//! [`transformer::train_microbatch`]), the complete MNIST probe
//! (`mnist_train`, `mnist_accuracy`, `mnist_hidden_fwd`) and the
//! ff-micro timing programs (`ff_fwd`, `ff_fwdbwd`). `repro train` /
//! `quality` run end to end on `--backend native` with no XLA
//! artifacts.

mod ff;
pub mod layers;
mod linear;
mod mlp;
pub mod ops;
mod params;
pub mod transformer;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::dyad::Variant;
use crate::tensor::{DType, Precision, Tensor};

use super::artifact::{ArchCfg, ArtifactSpec, Manifest, Role, VariantCfg};
use super::backend::{
    note_legacy_staging, validate_bound_inputs, validate_inputs, validate_outputs, Backend,
    Executable,
};
use super::catalog::{self, ADAM, MNIST_IN};
use super::device::{staging, wrap_native, DeviceTensor, NATIVE_DEVICE};

pub use linear::LinearView;
pub use params::Params;

/// A resolved ff-layer variant: dense or DYAD with parsed permutation
/// variants (including a per-layer §4 heterogeneous schedule).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub dense: bool,
    pub n_dyad: usize,
    pub base: Variant,
    pub schedule: Vec<Variant>,
    /// Weight-stream precision for the ff swap-site linears (fwd +
    /// dx; attention, embeddings and the tied head stay f32). Set by
    /// the backend's `--precision` plumbing; defaults to f32.
    pub precision: Precision,
}

impl VariantSpec {
    pub fn resolve(cfg: &VariantCfg) -> Result<VariantSpec> {
        let base = Variant::from_str(&cfg.dyad_variant)?;
        let schedule = cfg
            .layer_schedule
            .iter()
            .map(|s| Variant::from_str(s))
            .collect::<Result<Vec<_>>>()?;
        Ok(VariantSpec {
            dense: cfg.kind == "dense",
            n_dyad: cfg.n_dyad,
            base,
            schedule,
            precision: Precision::F32,
        })
    }

    pub fn for_layer(&self, layer: usize) -> Variant {
        if self.schedule.is_empty() {
            self.base
        } else {
            self.schedule[layer % self.schedule.len()]
        }
    }

    /// Build a [`LinearView`] over named parameters (`prefix.w`/`.b`
    /// for dense, `prefix.wl`/`.wu`/`.b` for DYAD).
    pub fn linear_view<'a>(
        &self,
        p: &Params<'a>,
        prefix: &str,
        f_in: usize,
        f_out: usize,
        layer: usize,
    ) -> Result<LinearView<'a>> {
        if self.dense {
            Ok(LinearView::Dense {
                w: p.f32(&format!("{prefix}.w"))?,
                b: p.f32(&format!("{prefix}.b"))?,
                f_in,
                f_out,
                precision: self.precision,
            })
        } else {
            Ok(LinearView::Dyad {
                wl: p.f32(&format!("{prefix}.wl"))?,
                wu: p.f32(&format!("{prefix}.wu"))?,
                b: p.f32(&format!("{prefix}.b"))?,
                dims: crate::dyad::DyadDims::new(self.n_dyad, f_in, f_out)?,
                variant: self.for_layer(layer),
                precision: self.precision,
            })
        }
    }
}

/// What a loaded native artifact executes.
enum Prog {
    Score { arch: ArchCfg, var: VariantSpec },
    Features { arch: ArchCfg, var: VariantSpec },
    NextLogits { arch: ArchCfg, var: VariantSpec },
    DecodeStep { arch: ArchCfg, var: VariantSpec },
    EvalLoss { arch: ArchCfg, var: VariantSpec },
    TrainStep { arch: ArchCfg, var: VariantSpec },
    MnistTrain { var: VariantSpec },
    MnistAccuracy { var: VariantSpec },
    MnistHiddenFwd { var: VariantSpec },
    FfFwd { d: usize, ff: usize, var: VariantSpec },
    FfFwdBwd { d: usize, ff: usize, var: VariantSpec },
}

/// Interior-mutable payload of a decode-cache handle
/// ([`Executable::make_decode_cache`]): `run_bound` appends K/V rows
/// into the wrapped [`transformer::DecodeState`] **in place**, so the
/// cache stays backend-resident across the whole generation —
/// `runtime::staging` counts only the per-step token ids and logits.
struct DecodeCacheCell(RefCell<transformer::DecodeState>);

pub struct NativeBackend {
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<NativeExe>>>,
    /// Weight-stream precision applied to every ff/swap-site linear
    /// this backend resolves (`--precision`). F32 is bitwise-identical
    /// to the pre-precision backend.
    precision: Precision,
    /// Worker-pool size every program this backend loads runs on.
    /// Defaults to [`crate::dyad::kernel::num_threads`]; serve workers
    /// pass their per-worker share so N workers don't oversubscribe
    /// the machine N-fold.
    threads: usize,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend::with_precision(Precision::F32)
    }

    /// A backend whose resolved programs run their DYAD/dense ff
    /// linears with quantized weight streams (fwd + dx; dw and all
    /// non-swap-site math stay f32).
    pub fn with_precision(precision: Precision) -> NativeBackend {
        NativeBackend::with_precision_and_threads(
            precision,
            crate::dyad::kernel::num_threads(),
        )
    }

    /// A backend on an explicit worker-pool size — the [`num_threads`]
    /// `OnceLock` cache only pins the *default*; this constructor
    /// always honors the caller's count.
    ///
    /// [`num_threads`]: crate::dyad::kernel::num_threads
    pub fn with_precision_and_threads(precision: Precision, threads: usize) -> NativeBackend {
        NativeBackend {
            manifest: catalog::native_manifest(),
            cache: RefCell::new(HashMap::new()),
            precision,
            threads: threads.max(1),
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<Rc<dyn Executable>> {
        if let Some(hit) = self.cache.borrow().get(name) {
            let as_dyn: Rc<dyn Executable> = hit.clone();
            return Ok(as_dyn);
        }
        let spec = self.manifest.artifact(name)?.clone();
        let prog = resolve(&spec, &self.manifest, self.precision)
            .with_context(|| format!("native backend: load {name}"))?;
        let exe = Rc::new(NativeExe { spec, prog, threads: self.threads });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn platform(&self) -> String {
        let threads = self.threads;
        if self.precision == Precision::F32 {
            format!("native-cpu ({threads} threads)")
        } else {
            format!("native-cpu ({threads} threads, {})", self.precision)
        }
    }

    /// Zero-copy: the host tensor (and its element buffer) is moved
    /// into the handle's `Rc`; no element-wise copy happens, so
    /// residency is free on this backend.
    fn upload(&self, t: Tensor) -> Result<DeviceTensor> {
        staging::note_upload(t.size_bytes());
        Ok(wrap_native(t))
    }

    fn download(&self, t: &DeviceTensor) -> Result<Tensor> {
        let host = t.payload::<Tensor>().with_context(|| {
            format!(
                "download: handle belongs to the {:?} backend, not {NATIVE_DEVICE:?}",
                t.device()
            )
        })?;
        staging::note_download(t.size_bytes());
        Ok(host.clone())
    }

    fn alloc(&self, shape: &[usize], dtype: DType) -> Result<DeviceTensor> {
        Ok(wrap_native(Tensor::zeros(shape, dtype)))
    }

    /// Sole-owner handles (every fresh `run_bound` output) give the
    /// buffer back without an element copy.
    fn take(&self, t: DeviceTensor) -> Result<Tensor> {
        staging::note_download(t.size_bytes());
        let device = t.device();
        t.try_unwrap_payload::<Tensor>().with_context(|| {
            format!(
                "take: handle belongs to the {device:?} backend, not {NATIVE_DEVICE:?}"
            )
        })
    }
}

fn resolve(spec: &ArtifactSpec, manifest: &Manifest, precision: Precision) -> Result<Prog> {
    let var_of = |key: &str| -> Result<VariantSpec> {
        let vname = spec.meta.req(key)?.as_str()?;
        let mut var = VariantSpec::resolve(manifest.variant(vname)?)?;
        var.precision = precision;
        Ok(var)
    };
    let arch_of = || -> Result<ArchCfg> {
        let aname = spec.meta.req("arch")?.as_str()?;
        Ok(manifest.arch(aname)?.clone())
    };
    Ok(match spec.kind.as_str() {
        "score" => Prog::Score { arch: arch_of()?, var: var_of("variant")? },
        "features" => Prog::Features { arch: arch_of()?, var: var_of("variant")? },
        "next_logits" => Prog::NextLogits { arch: arch_of()?, var: var_of("variant")? },
        "decode_step" => Prog::DecodeStep { arch: arch_of()?, var: var_of("variant")? },
        "eval_loss" => Prog::EvalLoss { arch: arch_of()?, var: var_of("variant")? },
        "mnist_train" => Prog::MnistTrain { var: var_of("variant")? },
        "mnist_accuracy" => Prog::MnistAccuracy { var: var_of("variant")? },
        "mnist_hidden_fwd" => Prog::MnistHiddenFwd { var: var_of("variant")? },
        "ff_fwd" => Prog::FfFwd {
            d: spec.meta_usize("d_model")?,
            ff: spec.meta_usize("d_ff")?,
            var: var_of("variant")?,
        },
        "ff_fwdbwd" => Prog::FfFwdBwd {
            d: spec.meta_usize("d_model")?,
            ff: spec.meta_usize("d_ff")?,
            var: var_of("variant")?,
        },
        "train_step" => Prog::TrainStep { arch: arch_of()?, var: var_of("variant")? },
        k => bail!("native backend cannot execute artifact kind {k:?}"),
    })
}

pub struct NativeExe {
    spec: ArtifactSpec,
    prog: Prog,
    /// Worker-pool size inherited from the owning backend at load.
    threads: usize,
}

impl NativeExe {
    fn data<'a>(&self, inputs: &'a [&'a Tensor]) -> Vec<&'a Tensor> {
        self.spec
            .inputs
            .iter()
            .zip(inputs)
            .filter(|(io, _)| io.role == Role::Data)
            .map(|(_, t)| *t)
            .collect()
    }

    fn scalar(&self, inputs: &[&Tensor], name: &str) -> Result<f32> {
        for (io, t) in self.spec.inputs.iter().zip(inputs) {
            if io.role == Role::Scalar && io.name == name {
                return t.scalar_value_f32();
            }
        }
        bail!("{}: no scalar input {name:?}", self.spec.name)
    }
}

impl Executable for NativeExe {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        validate_inputs(&self.spec, inputs)?;
        // the whole positional set crosses the host boundary per call
        note_legacy_staging(inputs);
        let out = self.exec(inputs)?;
        if cfg!(debug_assertions) {
            validate_outputs(&self.spec, &out)?;
        }
        Ok(out)
    }

    /// Handles wrap host tensors on this backend, so the bound path is
    /// the host path minus any per-call staging: borrow the wrapped
    /// buffers, execute, wrap the fresh outputs (a move, not a copy).
    fn run_bound(&self, inputs: &[&DeviceTensor]) -> Result<Vec<DeviceTensor>> {
        validate_bound_inputs(&self.spec, inputs)?;
        if let Prog::DecodeStep { arch, var } = &self.prog {
            // the kv_cache slot is a stateful cell, not a host tensor —
            // decode has its own bound path
            return self.run_decode(arch, var, inputs);
        }
        let host: Vec<&Tensor> = inputs
            .iter()
            .enumerate()
            .map(|(i, d)| d.expect_payload::<Tensor>(&self.spec.name, i, NATIVE_DEVICE))
            .collect::<Result<_>>()?;
        let out = self.exec(&host)?;
        if cfg!(debug_assertions) {
            validate_outputs(&self.spec, &out)?;
        }
        Ok(out.into_iter().map(wrap_native).collect())
    }

    /// The decode-step K/V cache, all lanes empty, resident on this
    /// backend. Bind it to the `kv_cache` input once; every call then
    /// advances it in place.
    fn make_decode_cache(&self) -> Result<DeviceTensor> {
        let Prog::DecodeStep { arch, .. } = &self.prog else {
            bail!("{}: this artifact has no decode cache", self.spec.name);
        };
        let idx = self.spec.input_index("kv_cache")?;
        let io = &self.spec.inputs[idx];
        let lanes = self.spec.meta_usize("batch")?;
        let st = transformer::DecodeState::new(arch, lanes);
        Ok(DeviceTensor::from_payload(
            io.shape.clone(),
            io.dtype,
            NATIVE_DEVICE,
            Rc::new(DecodeCacheCell(RefCell::new(st))),
        ))
    }
}

impl NativeExe {
    /// Execute on validated positional host tensors (shared by both
    /// trait entry points).
    fn exec(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let p = Params::new(&self.spec, inputs);
        let data = self.data(inputs);
        match &self.prog {
            Prog::Score { arch, var } => {
                let (b, s) = (data[0].shape[0], data[0].shape[1]);
                let lm = transformer::Lm { arch, var, p };
                let (sums, counts) = lm.score_with_threads(
                    data[0].as_i32()?,
                    data[1].as_f32()?,
                    b,
                    s,
                    self.threads,
                )?;
                Ok(vec![Tensor::from_f32(&[b], sums)?, Tensor::from_f32(&[b], counts)?])
            }
            Prog::Features { arch, var } => {
                let (b, s) = (data[0].shape[0], data[0].shape[1]);
                let lm = transformer::Lm { arch, var, p };
                let feats = lm.features_with_threads(
                    data[0].as_i32()?,
                    data[1].as_f32()?,
                    b,
                    s,
                    self.threads,
                )?;
                Ok(vec![Tensor::from_f32(&[b, arch.d_model], feats)?])
            }
            Prog::NextLogits { arch, var } => {
                let (b, s) = (data[0].shape[0], data[0].shape[1]);
                let lm = transformer::Lm { arch, var, p };
                let logits = lm.next_logits_with_threads(
                    data[0].as_i32()?,
                    data[1].as_i32()?,
                    b,
                    s,
                    self.threads,
                )?;
                Ok(vec![Tensor::from_f32(&[b, arch.vocab], logits)?])
            }
            Prog::EvalLoss { arch, var } => {
                let (b, s) = (data[0].shape[0], data[0].shape[1]);
                let lm = transformer::Lm { arch, var, p };
                let loss =
                    lm.eval_loss_with_threads(data[0].as_i32()?, b, s, self.threads)?;
                Ok(vec![Tensor::scalar_f32(loss)])
            }
            Prog::DecodeStep { .. } => bail!(
                "{}: decode_step is stateful — run it through run_bound with a \
                 make_decode_cache handle bound to kv_cache",
                self.spec.name
            ),
            Prog::TrainStep { arch, var } => self.run_lm_train(arch, var, inputs, &data),
            Prog::MnistTrain { var } => self.run_mnist_train(var, inputs, &data),
            Prog::MnistAccuracy { var } => {
                let b = data[0].shape[0];
                let mlp = mlp::Mlp { var, p };
                let n = mlp.n_correct(data[0].as_f32()?, data[1].as_i32()?, b)?;
                Ok(vec![Tensor::scalar_i32(n)])
            }
            Prog::MnistHiddenFwd { var } => {
                let b = data[0].shape[0];
                let mlp = mlp::Mlp { var, p };
                let h = mlp.hidden(data[0].as_f32()?, b)?;
                Ok(vec![Tensor::from_f32(&self.spec.outputs[0].shape, h)?])
            }
            Prog::FfFwd { d, ff, var } => {
                let t = data[0].shape[0];
                let f = ff::Ff { d: *d, ff: *ff, var, p };
                let y = f.forward(data[0].as_f32()?, t)?;
                Ok(vec![Tensor::from_f32(&[t, *d], y)?])
            }
            Prog::FfFwdBwd { d, ff, var } => {
                let t = data[0].shape[0];
                let f = ff::Ff { d: *d, ff: *ff, var, p };
                let (loss, grads) = f.fwdbwd(data[0].as_f32()?, data[1].as_f32()?, t)?;
                let mut out = vec![Tensor::scalar_f32(loss)];
                for (g, io) in grads.into_iter().zip(self.spec.outputs.iter().skip(1)) {
                    out.push(Tensor::from_f32(&io.shape, g)?);
                }
                Ok(out)
            }
        }
    }
}

/// The flat `(names, params, m, v)` optimizer state of a train-step
/// artifact, split out of the positional input set by role.
type TrainStateVecs = (Vec<String>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

impl NativeExe {
    fn split_train_state(&self, inputs: &[&Tensor]) -> Result<TrainStateVecs> {
        let mut names: Vec<String> = Vec::new();
        let mut params: Vec<Vec<f32>> = Vec::new();
        let mut m: Vec<Vec<f32>> = Vec::new();
        let mut v: Vec<Vec<f32>> = Vec::new();
        for (io, t) in self.spec.inputs.iter().zip(inputs) {
            match io.role {
                Role::Param => {
                    names.push(io.name.clone());
                    params.push(t.as_f32()?.to_vec());
                }
                Role::OptM => m.push(t.as_f32()?.to_vec()),
                Role::OptV => v.push(t.as_f32()?.to_vec()),
                _ => {}
            }
        }
        Ok((names, params, m, v))
    }

    /// Pack the train-step state machine's outputs:
    /// `params ++ m ++ v ++ step ++ losses`, at spec shapes.
    fn pack_train_outputs(
        &self,
        params: Vec<Vec<f32>>,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
        step: f32,
        losses: Vec<f32>,
    ) -> Result<Vec<Tensor>> {
        let spec = &self.spec;
        let k = losses.len();
        let mut out = Vec::with_capacity(spec.outputs.len());
        for (i, vals) in params.into_iter().chain(m).chain(v).enumerate() {
            out.push(Tensor::from_f32(&spec.outputs[i].shape, vals)?);
        }
        out.push(Tensor::scalar_f32(step));
        out.push(Tensor::from_f32(&[k], losses)?);
        Ok(out)
    }

    /// The bound decode path: one incremental token step per call.
    /// The `kv_cache` input is the interior-mutable [`DecodeCacheCell`]
    /// from [`Executable::make_decode_cache`] — it is advanced in
    /// place and never copied, so per-call staging is the token/reset
    /// ids in and one logits row per lane out. `resets[lane] != 0`
    /// frees that lane before the step (continuous-batching admission);
    /// `tokens[lane] < 0` leaves the lane idle (its logits row is
    /// zeroed and no compute is spent on it).
    fn run_decode(
        &self,
        arch: &ArchCfg,
        var: &VariantSpec,
        inputs: &[&DeviceTensor],
    ) -> Result<Vec<DeviceTensor>> {
        let cache_idx = self.spec.input_index("kv_cache")?;
        let cell = inputs[cache_idx].expect_payload::<DecodeCacheCell>(
            &self.spec.name,
            cache_idx,
            NATIVE_DEVICE,
        )?;
        // every other input is an ordinary resident host tensor; the
        // cache slot gets a placeholder (`Params` keeps `Role::Param`
        // entries only, and the data reads below skip it)
        let placeholder = Tensor::scalar_f32(0.0);
        let host: Vec<&Tensor> = inputs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                if i == cache_idx {
                    Ok(&placeholder)
                } else {
                    d.expect_payload::<Tensor>(&self.spec.name, i, NATIVE_DEVICE)
                }
            })
            .collect::<Result<_>>()?;
        let p = Params::new(&self.spec, &host);
        let data = self.data(&host);
        let (tokens, resets) = (data[1].as_i32()?, data[2].as_i32()?);
        let lm = transformer::Lm { arch, var, p };
        let mut st = cell.0.borrow_mut();
        for (lane, &r) in resets.iter().enumerate() {
            if r != 0 {
                st.reset_lane(lane);
            }
        }
        let vocab = arch.vocab;
        let lanes = st.lanes();
        let mut logits = vec![0.0f32; lanes * vocab];
        lm.decode_step_with_threads(&mut st, tokens, &mut logits, self.threads)?;
        let out = Tensor::from_f32(&[lanes, vocab], logits)?;
        if cfg!(debug_assertions) {
            validate_outputs(&self.spec, std::slice::from_ref(&out))?;
        }
        Ok(vec![wrap_native(out)])
    }

    /// The transformer train-step state machine: K microbatches of
    /// full-decoder loss/grads (layer-module autodiff) + global-norm
    /// grad clip + Adam, mirroring `model.py::make_train_step` —
    /// uniform lr across the K inner steps, schedule recomputed by the
    /// coordinator between calls.
    fn run_lm_train(
        &self,
        arch: &ArchCfg,
        var: &VariantSpec,
        inputs: &[&Tensor],
        data: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let (names, mut params, mut m, mut v) = self.split_train_state(inputs)?;
        let mut step = self.scalar(inputs, "step")?;
        let lr = self.scalar(inputs, "lr")?;
        let tokens = data[0];
        let (k, b, s) = (tokens.shape[0], tokens.shape[1], tokens.shape[2]);
        let tok = tokens.as_i32()?;
        // the backend's pool size, not a fresh num_threads() — a
        // threads-aware open (serve workers) is honored here
        let threads = self.threads;
        let mut losses = Vec::with_capacity(k);
        for ki in 0..k {
            let batch = &tok[ki * b * s..(ki + 1) * b * s];
            losses.push(transformer::train_microbatch(
                arch, var, &names, &mut params, &mut m, &mut v, batch, b, s, &mut step, lr,
                threads,
            )?);
        }
        self.pack_train_outputs(params, m, v, step, losses)
    }

    /// The MNIST train-step state machine: K microbatches of
    /// loss/grads + Adam, mirroring `mnist.py::make_mnist_train_step`
    /// (bias-corrected Adam, no grad clip, uniform lr across the K
    /// inner steps).
    fn run_mnist_train(
        &self,
        var: &VariantSpec,
        inputs: &[&Tensor],
        data: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let (names, mut params, mut m, mut v) = self.split_train_state(inputs)?;
        let mut step = self.scalar(inputs, "step")?;
        let lr = self.scalar(inputs, "lr")?;
        let images = data[0];
        let labels = data[1];
        let (k, b) = (images.shape[0], images.shape[1]);
        let img = images.as_f32()?;
        let lab = labels.as_i32()?;
        let mut losses = Vec::with_capacity(k);
        for ki in 0..k {
            let x = &img[ki * b * MNIST_IN..(ki + 1) * b * MNIST_IN];
            let y = &lab[ki * b..(ki + 1) * b];
            let (loss, grads) = mlp::mnist_loss_and_grads(var, &names, &params, x, y, b)?;
            losses.push(loss);
            step += 1.0;
            adam_update(&mut params, &mut m, &mut v, &grads, step, lr);
        }
        self.pack_train_outputs(params, m, v, step, losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance-criterion proof that native residency is
    /// zero-copy: the uploaded handle's payload still owns the exact
    /// element allocation the caller built — upload moved the buffer,
    /// it did not copy elements.
    #[test]
    fn upload_is_zero_copy() {
        let backend = NativeBackend::new();
        let values: Vec<f32> = (0..2048).map(|i| i as f32).collect();
        let ptr = values.as_ptr();
        let t = Tensor::from_f32(&[2048], values).unwrap();
        let dev = backend.upload(t).unwrap();
        let inner = dev.payload::<Tensor>().expect("native payload");
        assert_eq!(inner.as_f32().unwrap().as_ptr(), ptr, "buffer was copied");
        // run_bound outputs are wrapped the same way: fresh tensors
        // move into handles, so downstream residency is also free
        let host = inner.as_f32().unwrap();
        assert_eq!(host[2047], 2047.0);
    }

    /// `take` on a sole-owner handle (what every fresh `run_bound`
    /// output is) recovers the exact buffer — no element copy on the
    /// way back out either.
    #[test]
    fn take_unwraps_unique_handle_without_copy() {
        let backend = NativeBackend::new();
        let values: Vec<f32> = vec![1.5; 512];
        let ptr = values.as_ptr();
        let dev = backend
            .upload(Tensor::from_f32(&[512], values).unwrap())
            .unwrap();
        let t = backend.take(dev).unwrap();
        assert_eq!(t.as_f32().unwrap().as_ptr(), ptr, "buffer was copied");
        // shared handles fall back to a clone instead of failing
        let dev = backend.upload(t).unwrap();
        let keep = dev.clone();
        let copied = backend.take(dev).unwrap();
        let kept = keep.payload::<Tensor>().unwrap();
        assert_eq!(copied.as_f32().unwrap(), kept.as_f32().unwrap());
    }

    /// `run_bound` borrows the wrapped inputs in place — executing a
    /// bound artifact uploads nothing further.
    #[test]
    fn run_bound_stages_nothing() {
        let backend = NativeBackend::new();
        let art = Backend::load(&backend, "mnist/dyad_it/hidden_fwd").unwrap();
        let mut rng = crate::util::rng::Rng::new(3);
        let dev: Vec<DeviceTensor> = art
            .spec()
            .inputs
            .iter()
            .map(|io| {
                let n: usize = io.shape.iter().product();
                let vals = (0..n).map(|_| rng.uniform(-0.1, 0.1)).collect();
                backend.upload(Tensor::from_f32(&io.shape, vals).unwrap()).unwrap()
            })
            .collect();
        let refs: Vec<&DeviceTensor> = dev.iter().collect();
        let before = staging::snapshot();
        let out = art.run_bound(&refs).unwrap();
        let delta = staging::snapshot().since(&before);
        assert_eq!(delta.upload_bytes, 0);
        assert_eq!(delta.legacy_run_bytes, 0);
        assert_eq!(out.len(), art.spec().outputs.len());
        assert_eq!(out[0].shape(), art.spec().outputs[0].shape.as_slice());
    }

    /// `with_precision` flows from the backend through `resolve` into
    /// the executed program: an i8 backend produces activations close
    /// to (but not bitwise equal to) the f32 backend on the same
    /// inputs, and the platform string advertises the tag.
    #[test]
    fn backend_precision_flows_into_programs() {
        let f32_backend = NativeBackend::new();
        let i8_backend = NativeBackend::with_precision(Precision::I8);
        assert!(!f32_backend.platform().contains("i8"));
        assert!(i8_backend.platform().contains("i8"));
        let name = "mnist/dyad_it/hidden_fwd";
        let art_f32 = Backend::load(&f32_backend, name).unwrap();
        let art_i8 = Backend::load(&i8_backend, name).unwrap();
        let mut rng = crate::util::rng::Rng::new(11);
        let inputs: Vec<Tensor> = art_f32
            .spec()
            .inputs
            .iter()
            .map(|io| {
                let n: usize = io.shape.iter().product();
                let vals = (0..n).map(|_| rng.uniform(-0.2, 0.2)).collect();
                Tensor::from_f32(&io.shape, vals).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let y32 = art_f32.run(&refs).unwrap();
        let y8 = art_i8.run(&refs).unwrap();
        let a = y32[0].as_f32().unwrap();
        let b = y8[0].as_f32().unwrap();
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        let den: f32 = a.iter().map(|x| x * x).sum::<f32>().max(1e-12);
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "i8 backend drifted {rel} rel-L2 from f32");
        assert!(rel > 0.0, "i8 backend was bitwise equal to f32 — precision not applied");
    }
}

/// One bias-corrected Adam step over every parameter tensor (shared
/// by the MNIST and transformer train-step state machines).
pub(crate) fn adam_update(
    params: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    step: f32,
    lr: f32,
) {
    let (b1, b2, eps) = (ADAM.b1 as f32, ADAM.b2 as f32, ADAM.eps as f32);
    let ms = (1.0 / (1.0 - ADAM.b1.powf(step as f64))) as f32;
    let vs = (1.0 / (1.0 - ADAM.b2.powf(step as f64))) as f32;
    for ((p, mi), (vi, g)) in params
        .iter_mut()
        .zip(m.iter_mut())
        .zip(v.iter_mut().zip(grads))
    {
        for ((pv, mv), (vv, gv)) in
            p.iter_mut().zip(mi.iter_mut()).zip(vi.iter_mut().zip(g))
        {
            *mv = b1 * *mv + (1.0 - b1) * gv;
            *vv = b2 * *vv + (1.0 - b2) * gv * gv;
            *pv -= lr * (*mv * ms) / ((*vv * vs).sqrt() + eps);
        }
    }
}
